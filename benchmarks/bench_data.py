"""Data-pipeline efficiency: packing tokens-kept ratio + prefetch steps/s.

Two trajectory metrics (consolidated into BENCH_ci.json by benchmarks/run.py
and guarded by benchmarks/diff_baseline.py):

* ``packed_kept`` — fraction of a variable-length SFT corpus' completion
  tokens that train correctly supervised under greedy segment packing,
  vs ``drop_remainder_kept`` (the legacy concat/reshape layout: remainder
  dropped, boundary-straddling examples corrupted) and ``unpacked_kept``
  (per-example padded rows). Deterministic — any change is a packer change.
* ``prefetch_on_vs_off`` — steps/s of the packed pipeline with the async
  prefetcher (depth 2) as a multiple of the synchronous loop, same model
  same corpus. A ratio of two timings on one runner, so CI noise largely
  cancels; << 1 means the prefetch thread started hurting the step loop.

Run directly (``python -m benchmarks.bench_data``) or via benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.data import loader
from repro.data.pipeline import JsonlSftRecords, packing
from repro.data.tokenizer import VOCAB_SIZE
from repro.train.trainer import Trainer

SEQ_LEN = 256
BATCH = 4

DATA_MODEL = ModelConfig(
    name="bench-data", family="dense", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=2, head_dim=16, d_ff=256,
    vocab_size=VOCAB_SIZE, dtype="float32", remat="none")

# last collected table (read by benchmarks/run.py --json)
LAST_TABLE: dict | None = None


def _write_corpus(path: str, n: int = 60, seed: int = 7):
    """Deterministic variable-length prompt/completion corpus."""
    rng = np.random.default_rng(seed)
    with open(path, "w") as f:
        for _ in range(n):
            p = "Q: " + " ".join(str(rng.integers(1000))
                                 for _ in range(int(rng.integers(3, 20))))
            c = "A: " + " ".join(str(rng.integers(1000))
                                 for _ in range(int(rng.integers(4, 40))))
            f.write(json.dumps({"prompt": p, "completion": c}) + "\n")


def _tcfg(steps: int) -> TrainConfig:
    return TrainConfig(
        model=DATA_MODEL, method="adagradselect",
        select=SelectConfig(k_percent=33, steps_per_epoch=max(1, steps // 3)),
        optimizer=OptimizerConfig(lr=1e-3, schedule="constant",
                                  warmup_steps=0, total_steps=steps),
        seq_len=SEQ_LEN, global_batch=BATCH, steps=steps, log_every=0)


def _steps_per_s(path: str, steps: int, depth: int) -> float:
    pipe = loader.make_source("jsonl_sft", seq_len=SEQ_LEN,
                              global_batch=BATCH, path=path)
    tr = Trainer(_tcfg(steps), data_source=pipe, prefetch_depth=depth)
    tr.train(steps=2)  # compile + warm the pipeline
    t0 = time.perf_counter()
    tr.train(steps=steps, start_step=2)
    return steps / (time.perf_counter() - t0)


def run(steps: int | None = None) -> list:
    global LAST_TABLE
    steps = steps or int(os.environ.get("REPRO_BENCH_STEPS", "30"))
    rows = []

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "sft.jsonl")
        _write_corpus(path)

        stats = packing.packing_stats(JsonlSftRecords(path), SEQ_LEN, BATCH)
        rows.append(("data/packed_kept", 0.0,
                     f"{stats['packed_kept']:.4f}"))
        rows.append(("data/drop_remainder_kept", 0.0,
                     f"{stats['drop_remainder_kept']:.4f}"))
        rows.append(("data/packed_slot_util", 0.0,
                     f"{stats['packed_slot_util']:.4f}"))
        rows.append(("data/unpacked_slot_util", 0.0,
                     f"{stats['unpacked_slot_util']:.4f}"))

        off = _steps_per_s(path, steps, depth=0)
        on = _steps_per_s(path, steps, depth=2)
        rows.append(("data/prefetch_off", 1e6 / off, f"{off:.2f} steps/s"))
        rows.append(("data/prefetch_on", 1e6 / on, f"{on:.2f} steps/s"))
        rows.append(("data/prefetch_on_vs_off", 0.0, f"{on / off:.3f}x"))

    LAST_TABLE = {
        **{k: stats[k] for k in ("packed_kept", "drop_remainder_kept",
                                 "unpacked_kept", "packed_slot_util",
                                 "unpacked_slot_util")},
        "prefetch_off_steps_per_s": off,
        "prefetch_on_steps_per_s": on,
        "prefetch_on_vs_off": on / off,
    }
    return rows


def main():
    rows = run()
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print(json.dumps(LAST_TABLE, indent=2))


if __name__ == "__main__":
    main()
