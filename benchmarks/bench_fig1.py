"""Paper Fig. 1 proxy: training time vs optimizer-memory frontier per
method. Time = measured steady-state step wall-clock; memory = the paper's
deterministic 3.3 model (2*P*B device-resident moments)."""
from __future__ import annotations

from benchmarks.common import run_method

ROWS = [
    ("adagradselect_10", dict(method="adagradselect", k_percent=10)),
    ("adagradselect_30", dict(method="adagradselect", k_percent=30)),
    ("lora_r8", dict(method="lora", lora_rank=8)),
    ("full_ft", dict(method="all")),
]


def run(steps: int = 80):
    out = []
    for name, kw in ROWS:
        r = run_method(steps=steps, eval_problems=8, **kw)
        out.append((f"fig1/{name}", r.step_time_us,
                    f"opt_bytes={r.opt_bytes_modeled}"))
    return out
