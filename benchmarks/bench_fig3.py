"""Paper Fig. 3 proxy: accuracy vs %-of-blocks-selected sweep (gradient-
guided selection, Alg. 1)."""
from __future__ import annotations

from benchmarks.common import run_method

KS = (10, 20, 30, 50, 75, 100)


def run(steps: int = 150):
    out = []
    for k in KS:
        method = "all" if k == 100 else "topk_grad"
        r = run_method(method=method, k_percent=k, steps=steps)
        out.append((f"fig3/k{k}", r.step_time_us,
                    f"acc={r.accuracy:.3f};loss={r.final_loss:.4f}"))
    return out
