"""Paper Fig. 4 proxy: loss-convergence curves for AdaGradSelect (10-30%),
LoRA, full FT. Full curves land in results/fig4_curves.json; the CSV rows
carry the final loss."""
from __future__ import annotations

import json
import os

from benchmarks.common import run_method

ROWS = [
    ("adagradselect_10", dict(method="adagradselect", k_percent=10)),
    ("adagradselect_20", dict(method="adagradselect", k_percent=20)),
    ("adagradselect_30", dict(method="adagradselect", k_percent=30)),
    ("lora_r8", dict(method="lora", lora_rank=8)),
    ("full_ft", dict(method="all")),
]


def run(steps: int = 150, out_dir: str = "results"):
    os.makedirs(out_dir, exist_ok=True)
    curves = {}
    out = []
    for name, kw in ROWS:
        r = run_method(steps=steps, eval_problems=8, **kw)
        curves[name] = r.losses
        out.append((f"fig4/{name}", r.step_time_us, f"loss={r.final_loss:.4f}"))
    with open(os.path.join(out_dir, "fig4_curves.json"), "w") as f:
        json.dump(curves, f)
    return out
