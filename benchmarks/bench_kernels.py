"""Kernel micro-benches.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-clock timing there is meaningless; we time the COMPILED jnp oracle
path (what the XLA baseline does on-chip) and report the kernel's HBM-bytes
model as ``derived`` — the quantity the fused kernel actually optimizes.
Kernel-vs-oracle allclose is enforced in tests/test_kernels.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(fn, *args, iters=20):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    out = []
    key = jax.random.PRNGKey(0)

    # block_grad_norm: one pass over grads
    g = jax.random.normal(key, (16, 1 << 18), jnp.float32)
    f = jax.jit(ref.block_grad_sq_norms)
    out.append(("kernels/block_grad_norm", _time(f, g),
                f"hbm_bytes={g.size * 4}"))

    # masked adamw: 5 reads + 3 writes per param
    p = jax.random.normal(key, (16, 1 << 16), jnp.float32)
    args = (p, p * 0.1, p * 0.01, jnp.abs(p) * 0.01,
            jnp.ones(16), jnp.ones(16), 1e-3, 0.9, 0.999, 1e-8, 0.01)
    f = jax.jit(lambda *a: ref.masked_adamw(*a))
    out.append(("kernels/masked_adamw", _time(f, *args),
                f"hbm_bytes={p.size * 4 * 8}"))

    # flash attention fwd
    q = jax.random.normal(key, (1, 4, 1024, 64), jnp.float32) * 0.5
    f = jax.jit(lambda q: ref.flash_attention(q, q, q))
    out.append(("kernels/flash_attention_1k", _time(f, q),
                f"flops={2 * 2 * 4 * 1024 * 1024 * 64}"))

    # decode attention over a 32k cache
    kc = jax.random.normal(key, (1, 4, 32768, 64), jnp.float32) * 0.5
    qd = jax.random.normal(key, (1, 4, 64), jnp.float32)
    f = jax.jit(lambda q, k: ref.decode_attention(q, k, k, 32768))
    out.append(("kernels/decode_attention_32k", _time(f, qd, kc),
                f"hbm_bytes={2 * kc.size * 4}"))

    # rmsnorm
    x = jax.random.normal(key, (4096, 2048), jnp.bfloat16)
    sc = jnp.ones((2048,), jnp.bfloat16)
    f = jax.jit(lambda x, s: ref.rmsnorm(x, s))
    out.append(("kernels/rmsnorm", _time(f, x, sc),
                f"hbm_bytes={x.size * 2 * 2}"))
    return out
