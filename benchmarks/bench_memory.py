"""Resident optimizer-state memory + banked-swap overhead (paper §3.3).

The paper's headline efficiency claim is that only *selected* blocks' AdamW
moments occupy accelerator memory. This bench measures it on the actual
TrainState rather than the deterministic model: full fine-tuning vs dense
AdaGradSelect (full moments, the trajectory oracle) vs banked AdaGradSelect
(compact [k]-slot device banks + host-resident full store) vs LoRA.

Columns per method: measured device-resident bytes / host-resident bytes
(``core.offload.resident_opt_bytes`` over ``state["opt"]``), the §3.3 model
``2 * P_sel * B``, and steady-state step time — the banked row's step-time
delta vs the dense row is the host<->device moment-streaming overhead the
paper accepts for the memory win. Banked rows additionally break the step
down (phase A / swap-or-dispatch / phase B host µs from
``step_fn.swap_stats``) and report the async planner's predicted-admission
hit rate; ``--async-swap off`` benches the synchronous boundary for
comparison.

A final ``adagradselect_dense_obs`` row reruns the dense row with the obs
layer fully enabled (span tracing + selection telemetry) and reports
``obs_overhead`` — obs-on steps/s as a fraction of obs-off (1.0 = free);
``diff_baseline`` gates it at 3%.

Run directly (``python -m benchmarks.bench_memory [--json out.json]
[--smoke]``) or through ``benchmarks/run.py`` (``--json`` there embeds this
table for trajectory tracking).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import BENCH_MODEL, GLOBAL_BATCH, SEQ_LEN
from repro import obs
from repro.configs.base import OptimizerConfig, SelectConfig, TrainConfig
from repro.core import offload
from repro.train.trainer import Trainer

# deeper stack than the other benches: the memory win scales with the number
# of stacked blocks not selected (14 blocks, k=33% -> 5 resident)
MEM_MODEL = BENCH_MODEL.replace(name="bench-mem", num_layers=12)
K_PERCENT = 33.0

ROWS = (
    # (row name, method, moment_residency, offload)
    ("full_ft", "full", "device", "none"),
    ("adagradselect_dense", "adagradselect", "device", "none"),
    ("adagradselect_banked", "adagradselect", "banked", "host"),
    ("lora_r8", "lora", "device", "none"),
)

# last collected table (read by benchmarks/run.py --json)
LAST_TABLE: list | None = None


def _tcfg(method: str, residency: str, offload_policy: str,
          steps: int, async_swap: bool = True) -> TrainConfig:
    return TrainConfig(
        model=MEM_MODEL, method=method,
        select=SelectConfig(k_percent=K_PERCENT,
                            steps_per_epoch=max(1, steps // 3),
                            epsilon_decay=0.05),
        optimizer=OptimizerConfig(lr=3e-3, schedule="constant",
                                  warmup_steps=0, lora_rank=8,
                                  moment_residency=residency,
                                  offload=offload_policy,
                                  async_swap=async_swap,
                                  total_steps=steps),
        seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH, steps=steps,
        log_every=0, seed=0)


def collect(steps: int = 30, async_swap: bool = True) -> list[dict]:
    """-> one dict per method: measured residency, §3.3 model, step time;
    banked rows add the phase breakdown + predicted-admission hit rate."""
    global LAST_TABLE
    table = []
    for name, method, residency, offload_policy in ROWS:
        tr = Trainer(_tcfg(method, residency, offload_policy, steps,
                           async_swap))
        log = tr.train()
        res = offload.resident_opt_bytes(tr.state["opt"])
        rep = tr.method.trainable_param_report(MEM_MODEL, tr.state)
        row = {
            "name": name, "method": method, "residency": residency,
            "offload": offload_policy,
            "device_bytes": res["device"], "host_bytes": res["host"],
            "modeled_bytes": rep.opt_bytes,
            "step_time_us": float(np.mean(log.step_times[3:])) * 1e6,
            "final_loss": float(log.losses[-1]),
        }
        stats = getattr(tr.step_fn, "swap_stats", None)
        if stats is not None and stats.steps:
            row.update({
                "async_swap": async_swap,
                "phase_a_us": stats.phase_a_us / stats.steps,
                "swap_us": stats.swap_us / stats.steps,
                "phase_b_us": stats.phase_b_us / stats.steps,
                "predicted_hit_rate": stats.predicted_hit_rate,
                "swap_boundaries": stats.boundaries,
            })
        table.append(row)
    full = next(r for r in table if r["name"] == "full_ft")
    for r in table:
        r["device_vs_full"] = r["device_bytes"] / max(1, full["device_bytes"])
        r["step_time_vs_full"] = (r["step_time_us"]
                                  / max(1e-9, full["step_time_us"]))

    # obs-overhead row: the dense AdaGradSelect run again with the FULL obs
    # layer on (span tracing + per-step selection telemetry, i.e. the
    # worst-case host-sync path). obs_overhead = obs-on steps/s as a
    # fraction of the obs-off dense row's (1.0 = free); diff_baseline gates
    # it at 3%, which also pins the always-on registry cost in the obs-off
    # rows — both ends of the "no measurable step-time cost" contract.
    dense = next(r for r in table if r["name"] == "adagradselect_dense")
    obs.enable()
    try:
        tr = Trainer(_tcfg("adagradselect", "device", "none", steps,
                           async_swap))
        log = tr.train()
    finally:
        obs.disable()
    obs_us = float(np.mean(log.step_times[3:])) * 1e6
    table.append({
        "name": "adagradselect_dense_obs", "method": "adagradselect",
        "residency": "device", "offload": "none",
        "step_time_us": obs_us, "final_loss": float(log.losses[-1]),
        "obs_overhead": dense["step_time_us"] / max(1e-9, obs_us),
    })
    LAST_TABLE = table
    return table


def run(steps: int = 30):
    """benchmarks/run.py rows: name, step_us, derived (memory columns)."""
    out = []
    for r in collect(steps):
        if "obs_overhead" in r:  # obs row: timing ratio only, no residency
            out.append((f"memory/{r['name']}", r["step_time_us"],
                        f"obs_overhead={r['obs_overhead']:.3f};"
                        f"loss={r['final_loss']:.4f}"))
            continue
        derived = (f"dev_bytes={r['device_bytes']};"
                   f"host_bytes={r['host_bytes']};"
                   f"dev_vs_full={r['device_vs_full']:.3f};"
                   f"loss={r['final_loss']:.4f}")
        if "swap_us" in r:
            derived += (f";phase_a_us={r['phase_a_us']:.1f}"
                        f";swap_us={r['swap_us']:.1f}"
                        f";phase_b_us={r['phase_b_us']:.1f}"
                        f";hit_rate={r['predicted_hit_rate']:.3f}")
        out.append((f"memory/{r['name']}", r["step_time_us"], derived))
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("REPRO_BENCH_STEPS", "30")))
    ap.add_argument("--smoke", action="store_true",
                    help="few steps + assert the banked residency win")
    ap.add_argument("--json", default=None,
                    help="write the memory table as JSON")
    ap.add_argument("--async-swap", choices=("on", "off"), default="on",
                    help="overlapped (predictive) vs synchronous banked "
                         "swap boundary")
    args = ap.parse_args()
    steps = min(args.steps, 8) if args.smoke else args.steps

    table = collect(steps, async_swap=args.async_swap == "on")
    hdr = (f"{'method':24s} {'device MiB':>11s} {'host MiB':>9s} "
           f"{'model MiB':>10s} {'vs full':>8s} {'step us':>9s}")
    print(hdr)
    mib = 1 << 20
    for r in table:
        if "obs_overhead" in r:
            print(f"{r['name']:24s} {'—':>11s} {'—':>9s} {'—':>10s} "
                  f"{'—':>8s} {r['step_time_us']:9.1f}   "
                  f"obs_overhead={r['obs_overhead']:.3f} "
                  f"(obs-on steps/s vs obs-off)")
            continue
        print(f"{r['name']:24s} {r['device_bytes']/mib:11.2f} "
              f"{r['host_bytes']/mib:9.2f} {r['modeled_bytes']/mib:10.2f} "
              f"{r['device_vs_full']:8.3f} {r['step_time_us']:9.1f}")
        if "swap_us" in r:
            print(f"{'':24s} phase_a={r['phase_a_us']:.0f}us "
                  f"swap={r['swap_us']:.0f}us "
                  f"phase_b={r['phase_b_us']:.0f}us "
                  f"hit_rate={r['predicted_hit_rate']:.2f} "
                  f"boundaries={r['swap_boundaries']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"model": MEM_MODEL.name, "k_percent": K_PERCENT,
                       "steps": steps, "rows": table}, f, indent=2)
        print(f"wrote {args.json}")
    if args.smoke:
        banked = next(r for r in table if r["residency"] == "banked")
        assert banked["device_vs_full"] <= 0.5, (
            f"banked device-resident bytes {banked['device_vs_full']:.3f} "
            f"of full-FT — expected <= 0.5 at k~1/3")
        print("smoke OK: banked device-resident "
              f"{banked['device_vs_full']:.3f} of full-FT")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
