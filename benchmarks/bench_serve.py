"""Serve-engine benchmark: continuous batching vs legacy static batching.

Two workloads on the same smoke arch (CPU, random weights):

  uniform    -- B same-length prompts, all present at t=0, no EOS: the
                engine's chunked decode must be at least as fast as the
                legacy per-token loop (tok/s).
  staggered  -- mixed prompt lengths, arrivals spread over engine steps,
                early-EOS rows (EOS = the model's greedy attractor token):
                goodput (useful generated tokens / wall second). Legacy
                static batching pads every prompt to the longest and decodes
                the full budget for every row even after EOS; the engine
                frees slots at EOS and backfills, so its goodput must be
                strictly higher.

A third section (paged KV) reruns the staggered workload with long mixed
prompts (16/96 at max_len 128) on a page pool sized at 0.375x the dense
cache: goodput must still beat legacy while the allocated KV bytes shrink
below half of the dense layout. The paged section also pins the radix
prefix cache (on vs off), same-start grouped admission (one [rows, bucket]
prefill per wave vs one call per request), cross-engine prefix persistence
through a ``PrefixStore`` (warm-sweep hit rate), and preempt-and-requeue
vs backpressure.

  PYTHONPATH=src python benchmarks/bench_serve.py --arch llama3.2-1b
"""
from __future__ import annotations

import argparse
import time
from collections import Counter

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import registry
from repro.serve._oracle import generate_legacy
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine, generate
from repro.serve.prefix_store import PrefixStore
from repro.serve.scheduler import Request


def _tokens(rng, n, s, vocab):
    return rng.integers(1, vocab, (n, s)).astype(np.int32)


def bench_uniform(cfg, params, *, batch, prompt_len, new_tokens, chunk,
                  repeats):
    rng = np.random.default_rng(0)
    b = {"tokens": _tokens(rng, batch, prompt_len, cfg.vocab_size)}
    max_len = prompt_len + new_tokens
    kw = dict(max_new_tokens=new_tokens, max_len=max_len)

    generate_legacy(params, cfg, b, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        generate_legacy(params, cfg, b, **kw)
    t_leg = (time.perf_counter() - t0) / repeats

    generate(params, cfg, b, decode_chunk=chunk, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        generate(params, cfg, b, decode_chunk=chunk, **kw)
    t_eng = (time.perf_counter() - t0) / repeats

    toks = batch * new_tokens
    return toks / t_leg, toks / t_eng


def _attractor_token(cfg, params, prompt_len, new_tokens):
    """Greedy decoding with random weights collapses to a repeated token;
    use it as EOS so staggered rows genuinely terminate early."""
    rng = np.random.default_rng(7)
    b = {"tokens": _tokens(rng, 4, prompt_len, cfg.vocab_size)}
    raw = generate_legacy(params, cfg, b, max_new_tokens=new_tokens,
                          max_len=prompt_len + new_tokens)
    return int(Counter(raw.flatten().tolist()).most_common(1)[0][0])


def bench_staggered(cfg, params, *, num_requests, prompt_lens, new_tokens,
                    chunk, num_slots, stagger, repeats, engine_kw=None,
                    attractor_len=None):
    rng = np.random.default_rng(1)
    lens = [prompt_lens[i % len(prompt_lens)] for i in range(num_requests)]
    prompts = [_tokens(rng, 1, ln, cfg.vocab_size)[0] for ln in lens]
    max_prompt = max(lens)
    max_len = max_prompt + new_tokens
    # greedy attractors are prompt-length dependent: sample the EOS token at
    # ``attractor_len`` (default: the longest prompt) so the caller controls
    # which length class terminates early
    eos = _attractor_token(cfg, params, attractor_len or max_prompt,
                           new_tokens)

    def make_requests():
        return [Request(uid=i, tokens=prompts[i], max_new_tokens=new_tokens,
                        arrival=i * stagger) for i in range(num_requests)]

    def run_engine():
        eng = ServeEngine(cfg, params, ServeConfig(
            max_len=max_len, num_slots=num_slots, eos_id=eos,
            decode_chunk=chunk, **(engine_kw or {})))
        res = eng.run(make_requests())
        return sum(len(v) for v in res.values())

    run_engine()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        useful_eng = run_engine()
    t_eng = (time.perf_counter() - t0) / repeats

    # legacy static batching: every prompt right-padded to the longest, the
    # whole set as back-to-back full batches of num_slots, full budget
    # decoded for every row (EOS only masked post-hoc)
    padded = np.stack([np.pad(p, (0, max_prompt - len(p))) for p in prompts])

    def run_legacy():
        useful = 0
        for start in range(0, num_requests, num_slots):
            rows = padded[start:start + num_slots]
            out = generate_legacy(params, cfg, {"tokens": rows},
                                  max_new_tokens=new_tokens, max_len=max_len,
                                  eos_id=eos)
            for row in out:
                hits = np.flatnonzero(row == eos)
                useful += int(hits[0]) + 1 if len(hits) else new_tokens
        return useful

    run_legacy()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        useful_leg = run_legacy()
    t_leg = (time.perf_counter() - t0) / repeats

    return (useful_leg / t_leg, useful_eng / t_eng, useful_leg, useful_eng,
            eos)


# last collected structured table (read by benchmarks/run.py --json for the
# consolidated trajectory artifact; ratios are what the baseline diff pins)
LAST_TABLE: dict | None = None


# long-prompt staggered workload for the paged-KV comparison: mostly-short
# traffic (prompt 16, budget 4) with one long request (prompt 96, budget 32)
# per wave of eight, arriving in waves of four, at max_len 128. Decode-length
# mixing is expressed through per-request max_new_tokens — deterministic,
# unlike greedy-attractor EOS, whose token is prompt-length- and padding-
# dependent. Legacy static batching pads every row to the long prompt and
# decodes the batch-max budget for all of them; the engine retires each
# short at its 4-token budget and backfills from the queue. The page pool
# is 31/64 of the dense cache (8 slots x 128 positions = 64 pages of 16):
# three longs plus a working set of shorts fit concurrently.
PAGED_WORKLOAD = dict(num_requests=24, prompt_lens=[16] * 7 + [96],
                      new_tokens=[4] * 7 + [32], chunk=8, num_slots=8,
                      stagger=0.25)
PAGED_KW = dict(kv_layout="paged", page_size=16, num_pages=31)


def bench_paged_goodput(cfg, params, *, num_requests, prompt_lens,
                        new_tokens, chunk, num_slots, stagger, repeats,
                        engine_kw):
    """Goodput (requested tokens / wall s) of the paged engine vs legacy
    static batching on mixed prompt AND decode lengths. Legacy pads every
    prompt to the longest and decodes its batch's max budget for every row;
    the engine retires short-budget rows at their budget and backfills.
    Both produce exactly sum(budgets) useful tokens."""
    rng = np.random.default_rng(1)
    lens = [prompt_lens[i % len(prompt_lens)] for i in range(num_requests)]
    budgets = [new_tokens[i % len(new_tokens)] for i in range(num_requests)]
    prompts = [_tokens(rng, 1, ln, cfg.vocab_size)[0] for ln in lens]
    max_prompt, max_budget = max(lens), max(budgets)
    max_len = max_prompt + max_budget
    useful = sum(budgets)

    def run_engine():
        eng = ServeEngine(cfg, params, ServeConfig(
            max_len=max_len, num_slots=num_slots, decode_chunk=chunk,
            **engine_kw))
        res = eng.run([Request(uid=i, tokens=prompts[i],
                               max_new_tokens=budgets[i],
                               arrival=int(i * stagger))
                       for i in range(num_requests)])
        assert sum(len(v) for v in res.values()) == useful
        return eng

    run_engine()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        eng = run_engine()
    t_eng = (time.perf_counter() - t0) / repeats

    padded = np.stack([np.pad(p, (0, max_prompt - len(p))) for p in prompts])

    def run_legacy():
        for start in range(0, num_requests, num_slots):
            generate_legacy(params, cfg, {"tokens": padded[start:start
                                                           + num_slots]},
                            max_new_tokens=max_budget, max_len=max_len)

    run_legacy()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        run_legacy()
    t_leg = (time.perf_counter() - t0) / repeats

    return useful / t_leg, useful / t_eng, eng


# shared-prefix workload for the radix-cache comparison: every request is
# the same 496-token few-shot prefix (31 full pages of 16) plus a distinct
# 12-token question, with a tiny 4-token decode budget — prefill dominates,
# which is exactly the regime prefix caching targets. The prefix is long
# enough (bucket 512) that prefill FLOPs dwarf per-dispatch overhead on the
# smoke model; the first num_slots requests miss (the tree is empty until a
# completion inserts its prompt pages); every later admission aliases the
# 31 cached pages and prefills only its 12-token suffix.
PREFIX_WORKLOAD = dict(num_requests=12, prefix_len=496, suffix_len=12,
                       new_tokens=4, chunk=4, num_slots=4)
PREFIX_KW = dict(kv_layout="paged", page_size=16, num_pages=136)


def bench_prefix_goodput(cfg, params, *, num_requests, prefix_len,
                         suffix_len, new_tokens, chunk, num_slots, repeats):
    """Goodput of the paged engine with the radix prefix cache ON vs OFF on
    a shared-prefix workload. Both runs produce exactly the same tokens
    (prefix reuse is exact, not approximate); the ratio is pure prefill
    savings."""
    rng = np.random.default_rng(2)
    prefix = _tokens(rng, 1, prefix_len, cfg.vocab_size)[0]
    prompts = [np.concatenate([prefix,
                               _tokens(rng, 1, suffix_len, cfg.vocab_size)[0]])
               for _ in range(num_requests)]
    max_len = prefix_len + suffix_len + new_tokens
    useful = num_requests * new_tokens

    def run_one(prefix_cache):
        eng = ServeEngine(cfg, params, ServeConfig(
            max_len=max_len, num_slots=num_slots, decode_chunk=chunk,
            prefix_cache=prefix_cache, **PREFIX_KW))
        res = eng.run([Request(uid=i, tokens=prompts[i],
                               max_new_tokens=new_tokens)
                       for i in range(num_requests)])
        assert sum(len(v) for v in res.values()) == useful
        return eng

    run_one(False)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        run_one(False)
    t_off = (time.perf_counter() - t0) / repeats

    eng = run_one(True)  # warmup/compile
    assert eng.stats["prefix_hits"] > 0, eng.stats
    t0 = time.perf_counter()
    for _ in range(repeats):
        eng = run_one(True)
    t_on = (time.perf_counter() - t0) / repeats

    return useful / t_off, useful / t_on, eng


# short-prefix many-request workload for the grouped-admission comparison:
# 24 requests sharing a 112-token prefix (7 full pages) with distinct
# 12-token questions at max_len 128. Grouped admission's win is DISPATCH
# COUNT — each wave of four same-start requests lands as one [4, bucket]
# suffix prefill instead of four [1, bucket] calls (6 prefills vs 24) — so
# the workload keeps the scratch small: per-dispatch overhead then
# dominates the per-row compute and the saving is visible on CPU. (At the
# 496-token PREFIX_WORKLOAD scratch, XLA-CPU's batched prefill attention
# costs ~3x the equivalent batch-1 calls, an artifact that buries the
# dispatch saving; on accelerators the fewer-launches win is the point.)
GROUP_WORKLOAD = dict(num_requests=24, prefix_len=112, suffix_len=12,
                      new_tokens=4, chunk=4, num_slots=4)


def bench_prefix_group_goodput(cfg, params, *, num_requests, prefix_len,
                               suffix_len, new_tokens, chunk, num_slots,
                               repeats):
    """Goodput of same-start GROUPED prefix admission (prefill_rows =
    num_slots: each admission wave lands as one [rows, bucket] suffix
    prefill) vs one-request-per-call admission (prefill_rows=1), both with
    the radix cache on. Per-slot key streams make admission grouping
    invisible to the sampled tokens (greedy here), so the outputs are
    asserted identical and the ratio is pure prefill batching/dispatch
    savings on shared-prefix traffic."""
    rng = np.random.default_rng(4)
    prefix = _tokens(rng, 1, prefix_len, cfg.vocab_size)[0]
    prompts = [np.concatenate([prefix,
                               _tokens(rng, 1, suffix_len,
                                       cfg.vocab_size)[0]])
               for _ in range(num_requests)]
    max_len = prefix_len + suffix_len + new_tokens
    useful = num_requests * new_tokens

    def run_one(rows):
        eng = ServeEngine(cfg, params, ServeConfig(
            max_len=max_len, num_slots=num_slots, decode_chunk=chunk,
            prefix_cache=True, prefill_rows=rows, **PREFIX_KW))
        res = eng.run([Request(uid=i, tokens=prompts[i],
                               max_new_tokens=new_tokens)
                       for i in range(num_requests)])
        assert sum(len(v) for v in res.values()) == useful
        return eng, res

    _, res_one = run_one(1)          # warmup/compile both arms
    eng, res_grp = run_one(num_slots)
    # grouped admission is token-exact vs one-per-call, and its per-row
    # prefill work is suffix-only (the same token count either way)
    assert all(np.array_equal(res_grp[u], res_one[u]) for u in res_one)
    assert eng.stats["prefills"] < num_requests, eng.stats

    t0 = time.perf_counter()
    for _ in range(repeats):
        run_one(1)
    t_one = (time.perf_counter() - t0) / repeats
    t0 = time.perf_counter()
    for _ in range(repeats):
        eng, _ = run_one(num_slots)
    t_grp = (time.perf_counter() - t0) / repeats
    return useful / t_one, useful / t_grp, eng


def bench_persistent_prefix(cfg, params, *, num_requests, prefix_len,
                            suffix_len, new_tokens, chunk, num_slots,
                            repeats):
    """Cross-engine prefix persistence: sequential eval sweeps over the
    SAME prompts, each in its own ServeEngine, all sharing one PrefixStore.
    Each engine's close() hands its radix tree + page pool to the store and
    the next engine adopts them warm, so every admission after the first
    sweep aliases cached prefix pages and prefills suffix-only. The gated
    metric is the warm sweep's hit rate (prefix hits / requests) — a
    deterministic 1.0 when cross-engine adoption works, so CI pins it with
    zero tolerance."""
    rng = np.random.default_rng(5)
    prefix = _tokens(rng, 1, prefix_len, cfg.vocab_size)[0]
    prompts = [np.concatenate([prefix,
                               _tokens(rng, 1, suffix_len,
                                       cfg.vocab_size)[0]])
               for _ in range(num_requests)]
    scfg = ServeConfig(max_len=prefix_len + suffix_len + new_tokens,
                       num_slots=num_slots, decode_chunk=chunk,
                       prefix_cache=True, prefix_store=PrefixStore(),
                       **PREFIX_KW)
    useful = num_requests * new_tokens

    def sweep():
        eng = ServeEngine(cfg, params, scfg)
        res = eng.run([Request(uid=i, tokens=prompts[i],
                               max_new_tokens=new_tokens)
                       for i in range(num_requests)])
        assert sum(len(v) for v in res.values()) == useful
        stats = dict(eng.stats)
        eng.close()  # hands the radix tree to scfg.prefix_store
        return res, stats

    res1, _ = sweep()   # cold sweep populates the store
    res2, s2 = sweep()  # warm sweep also compiles the suffix-only path
    assert all(np.array_equal(res1[u], res2[u]) for u in res1)
    t0 = time.perf_counter()
    for _ in range(repeats):
        _, s2 = sweep()
    t_warm = (time.perf_counter() - t0) / repeats
    return s2["prefix_hits"] / num_requests, useful / t_warm, s2


# oversubscribed-pool workload for the preemption comparison: a 112-token-
# budget hog arrives FIRST and reserves 8 of the pool's 21 pages; 15 short
# requests queue behind it and oversubscribe the rest — the head-of-line-
# blocking shape. With preempt=False the engine backpressures: shorts only
# enter as pages free. With preempt=True the first short that cannot fit
# evicts the hog (it has strictly the most budget left, see the damped
# victim policy in engine._preempt_one), and the hog re-admits through the
# radix tree where its context pages survive eviction. Both arms run with
# the prefix cache on, so the ratio isolates the scheduling policy.
#
# Under strict FCFS requeue-at-head (the token-exactness/fairness contract)
# preemption cannot beat work-conserving backpressure on AGGREGATE goodput:
# it defers the hog's tokens and re-prefills its context, buying
# head-of-line fairness (shorts stop waiting on the hog's full budget).
# The pinned ratio is therefore a parity guard — preemption's goodput cost
# must stay small and bounded — not a speedup claim; the regression this
# row catches is the requeue path decaying back into preempt/re-admit
# thrash (unconditional victim selection measured 0.50x here).
PREEMPT_WORKLOAD = dict(num_requests=16, prompt_len=16,
                        new_tokens=[112] + [16] * 15, chunk=8, num_slots=8)
PREEMPT_KW = dict(kv_layout="paged", page_size=16, num_pages=21,
                  prefix_cache=True, prefix_cache_pages=12)


def bench_preempt_goodput(cfg, params, *, num_requests, prompt_len,
                          new_tokens, chunk, num_slots, repeats):
    """Goodput of preempt-and-requeue vs plain backpressure on a pool too
    small for the offered load. Token outputs are identical (preemption is
    token-exact); the ratio isolates the scheduling policy."""
    rng = np.random.default_rng(3)
    budgets = [new_tokens[i % len(new_tokens)] for i in range(num_requests)]
    prompts = [_tokens(rng, 1, prompt_len, cfg.vocab_size)[0]
               for _ in range(num_requests)]
    max_len = prompt_len + max(budgets)
    useful = sum(budgets)

    def run_one(preempt):
        eng = ServeEngine(cfg, params, ServeConfig(
            max_len=max_len, num_slots=num_slots, decode_chunk=chunk,
            preempt=preempt, **PREEMPT_KW))
        res = eng.run([Request(uid=i, tokens=prompts[i],
                               max_new_tokens=budgets[i])
                       for i in range(num_requests)])
        assert sum(len(v) for v in res.values()) == useful
        return eng

    run_one(False)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        run_one(False)
    t_bp = (time.perf_counter() - t0) / repeats

    run_one(True)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        eng = run_one(True)
    t_pre = (time.perf_counter() - t0) / repeats

    return useful / t_bp, useful / t_pre, eng


def _paged_supported(cfg) -> bool:
    return (cfg.family in ("dense", "moe") and not cfg.use_mla
            and cfg.moe_impl != "ep")


def _cache_bytes(cfg, params, *, max_len, num_slots, engine_kw=None):
    """Allocated KV bytes for an (un-run) engine at the given capacity."""
    eng = ServeEngine(cfg, params, ServeConfig(
        max_len=max_len, num_slots=num_slots, **(engine_kw or {})))
    return eng.kv_cache_bytes()


def run(arch: str = "llama3.2-1b", **_):
    """CSV rows for benchmarks/run.py: µs per generated token + tok/s."""
    global LAST_TABLE
    cfg = get_smoke_config(arch).replace(ssm_chunk=16)
    params = registry.get(cfg).init(jax.random.PRNGKey(0), cfg)
    leg, eng = bench_uniform(cfg, params, batch=4, prompt_len=16,
                             new_tokens=16, chunk=8, repeats=2)
    gl, ge, _, _, _ = bench_staggered(cfg, params, num_requests=8,
                                      prompt_lens=[8, 12, 16], new_tokens=16,
                                      chunk=8, num_slots=4, stagger=1,
                                      repeats=2)
    LAST_TABLE = {
        "arch": arch,
        "uniform_legacy_tok_s": leg, "uniform_engine_tok_s": eng,
        "uniform_engine_vs_legacy": eng / max(1e-9, leg),
        "staggered_legacy_tok_s": gl, "staggered_engine_tok_s": ge,
        "staggered_engine_vs_legacy": ge / max(1e-9, gl),
    }
    rows = [
        ("serve/uniform_legacy", 1e6 / leg, f"{leg:.1f} tok/s"),
        ("serve/uniform_engine", 1e6 / eng, f"{eng:.1f} tok/s"),
        ("serve/staggered_legacy", 1e6 / gl, f"{gl:.1f} useful tok/s"),
        ("serve/staggered_engine", 1e6 / ge, f"{ge:.1f} useful tok/s"),
    ]
    if _paged_supported(cfg):
        gl2, gp2, _ = bench_paged_goodput(cfg, params, repeats=2,
                                          engine_kw=PAGED_KW,
                                          **PAGED_WORKLOAD)
        cap = dict(max_len=max(PAGED_WORKLOAD["prompt_lens"])
                   + max(PAGED_WORKLOAD["new_tokens"]),
                   num_slots=PAGED_WORKLOAD["num_slots"])
        dense_b = _cache_bytes(cfg, params, **cap)
        paged_b = _cache_bytes(cfg, params, engine_kw=PAGED_KW, **cap)
        LAST_TABLE.update({
            "staggered_paged_tok_s": gp2,
            "staggered_paged_vs_legacy": gp2 / max(1e-9, gl2),
            "serve_cache_bytes_dense": dense_b,
            "serve_cache_bytes_paged": paged_b,
            "paged_vs_dense_cache_bytes": paged_b / max(1, dense_b),
        })
        rows += [
            ("serve/staggered_paged", 1e6 / gp2, f"{gp2:.1f} useful tok/s"),
            ("serve/cache_bytes_dense", dense_b, f"{dense_b/1e6:.2f} MB"),
            ("serve/cache_bytes_paged", paged_b,
             f"{paged_b/1e6:.2f} MB ({paged_b/dense_b:.2f}x dense)"),
        ]
        goff, gon, pfx_eng = bench_prefix_goodput(cfg, params, repeats=2,
                                                  **PREFIX_WORKLOAD)
        gone, ggrp, grp_eng = bench_prefix_group_goodput(
            cfg, params, repeats=2, **GROUP_WORKLOAD)
        hit_rate, gwarm, ps_stats = bench_persistent_prefix(
            cfg, params, repeats=2, **PREFIX_WORKLOAD)
        gbp, gpre, pre_eng = bench_preempt_goodput(cfg, params, repeats=2,
                                                   **PREEMPT_WORKLOAD)
        LAST_TABLE.update({
            "prefix_off_tok_s": goff, "prefix_on_tok_s": gon,
            "prefix_shared_goodput": gon / max(1e-9, goff),
            "prefix_hits": pfx_eng.stats["prefix_hits"],
            "prefix_pages_shared": pfx_eng.stats["prefix_pages_shared"],
            "prefix_ungrouped_tok_s": gone, "prefix_grouped_tok_s": ggrp,
            "prefix_group_admission_goodput": ggrp / max(1e-9, gone),
            "prefix_grouped_prefills": grp_eng.stats["prefills"],
            "persistent_prefix_hit_rate": hit_rate,
            "persistent_warm_tok_s": gwarm,
            "persistent_prefill_tokens": ps_stats["prefill_tokens"],
            "backpressure_tok_s": gbp, "preempt_tok_s": gpre,
            "preempt_vs_backpressure_goodput": gpre / max(1e-9, gbp),
            "preempted": pre_eng.stats["preempted"],
        })
        rows += [
            ("serve/prefix_cache_off", 1e6 / goff, f"{goff:.1f} tok/s"),
            ("serve/prefix_cache_on", 1e6 / gon,
             f"{gon:.1f} tok/s ({gon/goff:.2f}x off, "
             f"{pfx_eng.stats['prefix_hits']} hits)"),
            ("serve/prefix_grouped_admission", 1e6 / ggrp,
             f"{ggrp:.1f} tok/s ({ggrp/gone:.2f}x one-per-call, "
             f"{grp_eng.stats['prefills']} prefill calls)"),
            ("serve/persistent_prefix_warm", 1e6 / gwarm,
             f"{gwarm:.1f} tok/s (hit rate {hit_rate:.2f}, "
             f"{ps_stats['prefill_tokens']} tokens prefilled)"),
            ("serve/preempt_requeue", 1e6 / gpre,
             f"{gpre:.1f} tok/s ({gpre/gbp:.2f}x backpressure, "
             f"{pre_eng.stats['preempted']} preempted)"),
        ]
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--stagger", type=int, default=1)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(ssm_chunk=16)
    params = registry.get(cfg).init(jax.random.PRNGKey(0), cfg)

    leg, eng = bench_uniform(cfg, params, batch=args.batch,
                             prompt_len=args.prompt_len,
                             new_tokens=args.new_tokens,
                             chunk=args.decode_chunk, repeats=args.repeats)
    print(f"[{args.arch}] uniform arrivals: batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    print(f"  legacy static batch: {leg:9.1f} tok/s")
    print(f"  engine:              {eng:9.1f} tok/s   ({eng / leg:.2f}x)  "
          f"{'OK (>= legacy)' if eng >= leg else 'REGRESSION'}")

    # halves keep ssm prefill chunking valid (len <= ssm_chunk or divisible)
    lens = sorted({args.prompt_len, args.prompt_len // 2})
    gl, ge, ul, ue, eos = bench_staggered(
        cfg, params, num_requests=args.requests, prompt_lens=lens,
        new_tokens=args.new_tokens, chunk=args.decode_chunk,
        num_slots=args.batch, stagger=args.stagger, repeats=args.repeats)
    print(f"[{args.arch}] staggered arrivals: {args.requests} requests, "
          f"prompt lens {lens}, eos={eos} (attractor), slots={args.batch}")
    print(f"  legacy static batch: {gl:9.1f} useful tok/s "
          f"({ul} useful tokens)")
    print(f"  engine:              {ge:9.1f} useful tok/s "
          f"({ue} useful tokens)  ({ge / gl:.2f}x)  "
          f"{'OK (goodput > legacy)' if ge > gl else 'REGRESSION'}")

    paged_ok = True
    if _paged_supported(cfg):
        gl2, gp2, _ = bench_paged_goodput(
            cfg, params, repeats=args.repeats, engine_kw=PAGED_KW,
            **PAGED_WORKLOAD)
        cap = dict(max_len=max(PAGED_WORKLOAD["prompt_lens"])
                   + max(PAGED_WORKLOAD["new_tokens"]),
                   num_slots=PAGED_WORKLOAD["num_slots"])
        dense_b = _cache_bytes(cfg, params, **cap)
        paged_b = _cache_bytes(cfg, params, engine_kw=PAGED_KW, **cap)
        paged_ok = gp2 > gl2 and paged_b < dense_b
        print(f"[{args.arch}] paged KV, mixed prompts "
              f"{PAGED_WORKLOAD['prompt_lens']} budgets "
              f"{PAGED_WORKLOAD['new_tokens']} "
              f"(pool {PAGED_KW['num_pages']} pages of "
              f"{PAGED_KW['page_size']}):")
        print(f"  legacy static batch: {gl2:9.1f} useful tok/s")
        print(f"  paged engine:        {gp2:9.1f} useful tok/s "
              f"({gp2 / gl2:.2f}x)")
        print(f"  kv cache: dense {dense_b/1e6:.2f} MB, paged "
              f"{paged_b/1e6:.2f} MB ({paged_b/dense_b:.2f}x)  "
              f"{'OK' if paged_ok else 'REGRESSION'}")
        goff, gon, pfx_eng = bench_prefix_goodput(
            cfg, params, repeats=args.repeats, **PREFIX_WORKLOAD)
        prefix_ok = gon >= 1.3 * goff
        print(f"[{args.arch}] radix prefix cache, "
              f"{PREFIX_WORKLOAD['num_requests']} requests sharing a "
              f"{PREFIX_WORKLOAD['prefix_len']}-token prefix:")
        print(f"  prefix cache off:    {goff:9.1f} tok/s")
        print(f"  prefix cache on:     {gon:9.1f} tok/s ({gon/goff:.2f}x, "
              f"{pfx_eng.stats['prefix_hits']} hits, "
              f"{pfx_eng.stats['prefix_pages_shared']} pages shared)  "
              f"{'OK (>= 1.3x)' if prefix_ok else 'REGRESSION'}")
        gone, ggrp, grp_eng = bench_prefix_group_goodput(
            cfg, params, repeats=args.repeats, **GROUP_WORKLOAD)
        group_ok = ggrp >= 0.9 * gone  # grouped must not lose to one-per-call
        print(f"[{args.arch}] same-start grouped admission "
              f"(prefill_rows={GROUP_WORKLOAD['num_slots']}):")
        print(f"  one prefill/request: {gone:9.1f} tok/s")
        print(f"  grouped prefills:    {ggrp:9.1f} tok/s ({ggrp/gone:.2f}x, "
              f"{grp_eng.stats['prefills']} prefill calls for "
              f"{GROUP_WORKLOAD['num_requests']} requests)  "
              f"{'OK' if group_ok else 'REGRESSION'}")
        hit_rate, gwarm, ps_stats = bench_persistent_prefix(
            cfg, params, repeats=args.repeats, **PREFIX_WORKLOAD)
        persist_ok = hit_rate >= 1.0
        print(f"[{args.arch}] persistent prefix store, two engines, "
              f"{PREFIX_WORKLOAD['num_requests']} repeated prompts:")
        print(f"  warm sweep:          {gwarm:9.1f} tok/s, hit rate "
              f"{hit_rate:.2f}, {ps_stats['prefill_tokens']} tokens "
              f"prefilled (suffix-only)  "
              f"{'OK (all hits)' if persist_ok else 'REGRESSION'}")
        gbp, gpre, pre_eng = bench_preempt_goodput(
            cfg, params, repeats=args.repeats, **PREEMPT_WORKLOAD)
        preempt_ok = gpre >= 0.7 * gbp  # parity guard, see PREEMPT_WORKLOAD
        print(f"[{args.arch}] preempt-and-requeue, "
              f"{PREEMPT_KW['num_pages']}-page pool, budgets "
              f"{PREEMPT_WORKLOAD['new_tokens']}:")
        print(f"  backpressure only:   {gbp:9.1f} tok/s")
        print(f"  preempt+requeue:     {gpre:9.1f} tok/s ({gpre/gbp:.2f}x, "
              f"{pre_eng.stats['preempted']} preempted)  "
              f"{'OK' if preempt_ok else 'REGRESSION'}")
        paged_ok = (paged_ok and prefix_ok and preempt_ok and group_ok
                    and persist_ok)
    return 0 if (eng >= leg and ge > gl and paged_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
