"""Paper Table 1 proxy: AdaGradSelect (10/20/30%) vs LoRA (2 ranks) vs full
fine-tuning — accuracy on the held-out synthetic-math eval (GSM8K-protocol:
zero-shot greedy decoding, exact match)."""
from __future__ import annotations

from benchmarks.common import run_method

ROWS = [
    ("adagradselect_10", dict(method="adagradselect", k_percent=10)),
    ("adagradselect_20", dict(method="adagradselect", k_percent=20)),
    ("adagradselect_30", dict(method="adagradselect", k_percent=30)),
    ("lora_r4", dict(method="lora", lora_rank=4)),
    ("lora_r8", dict(method="lora", lora_rank=8)),
    ("full_ft", dict(method="all")),
]


def run(steps: int = 150):
    out = []
    for name, kw in ROWS:
        r = run_method(steps=steps, **kw)
        out.append((f"table1/{name}", r.step_time_us,
                    f"acc={r.accuracy:.3f};loss={r.final_loss:.4f}"))
    return out
