"""Shared benchmark harness utilities.

Benchmarks run REDUCED models on CPU (the full-scale numbers come from the
dry-run/roofline pipeline); every paper table/figure has a corresponding
bench that reproduces its experimental SHAPE (methods x metrics) on the
synthetic math task, with wall-clock step time and the paper's memory model
as the efficiency axes. Methods are resolved through the repro.methods
registry, so any registered method name works as a bench row.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.data.synthetic import MathTaskConfig
from repro.train.evaluate import math_accuracy
from repro.train.trainer import Trainer

BENCH_MODEL = ModelConfig(
    name="bench-slm", family="dense", num_layers=6, d_model=96, num_heads=4,
    num_kv_heads=2, head_dim=24, d_ff=384, vocab_size=32, dtype="float32",
    remat="none", tie_embeddings=True, attn_bias=True)  # qwen-family shape

SEQ_LEN = 64
GLOBAL_BATCH = 16
TASK = MathTaskConfig(digits=3, seq_len=SEQ_LEN)


@dataclass
class MethodResult:
    name: str
    final_loss: float
    accuracy: float
    step_time_us: float
    opt_bytes_modeled: int
    losses: list


def run_method(method: str, *, k_percent: float = 20.0, lora_rank: int = 8,
               steps: int = 150, lr: float = 3e-3, seed: int = 0,
               model: ModelConfig = BENCH_MODEL,
               eval_problems: int = 48) -> MethodResult:
    tcfg = TrainConfig(
        model=model,
        method=method,
        select=SelectConfig(k_percent=k_percent,
                            steps_per_epoch=max(1, steps // 3),
                            epsilon_decay=0.05),
        optimizer=OptimizerConfig(lr=lr, schedule="cosine", warmup_steps=10,
                                  total_steps=steps, lora_rank=lora_rank),
        seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH, steps=steps, log_every=0,
        seed=seed)
    tr = Trainer(tcfg)
    t0 = time.perf_counter()
    log = tr.train()
    # steady-state step time (exclude compile)
    st = float(np.mean(log.step_times[3:])) * 1e6

    params = tr.method.eval_params(model, tcfg.optimizer, tr.state)
    acc = math_accuracy(params, model, TASK, num_problems=eval_problems)
    report = tr.method.trainable_param_report(model, tr.state)
    return MethodResult(method, float(log.losses[-1]), acc, st,
                        report.opt_bytes, log.losses)
