"""Diff a consolidated benchmark JSON (benchmarks/run.py --json) against the
committed baseline and fail on regressions of the key trajectory metrics.

Key metrics (direction-aware, default tolerance 20%, per-metric overrides):

  * ``banked_device_vs_full`` — banked residency's device-resident optimizer
    bytes as a fraction of full FT (memory table; lower is better). This is
    deterministic, so any growth means the residency machinery regressed.
  * ``banked_step_time_vs_full`` — banked step time as a multiple of the
    full-FT step (memory table; lower is better; a ratio of two timings on
    the same runner, so CI noise largely cancels). Tight 10% tolerance: the
    async swap planner's whole point is keeping the boundary off the
    critical path, and a regression here means the overlap broke.
  * ``obs_overhead`` — obs-on steps/s as a fraction of obs-off on the dense
    AdaGradSelect row (memory table; higher is better; a same-process timing
    ratio, so CI noise largely cancels). The baseline is capped at 1.0 with
    a tight 3% tolerance: the observability contract is "fully-enabled
    tracing + selection telemetry costs < ~3% of a step, disabled mode
    costs nothing measurable" — growth here means a host sync or hot-path
    allocation crept into the instrumented step.
  * ``uniform_engine_vs_legacy`` / ``staggered_engine_vs_legacy`` — the
    serve engine's tok/s (goodput) as a multiple of the legacy static-batch
    loop (serve table; higher is better). Ratios of two timings on the same
    runner, so CI noise largely cancels.
  * ``staggered_paged_vs_legacy`` — paged-KV engine goodput on the mixed
    prompt/budget workload as a multiple of legacy static batching (higher
    is better). The baseline is capped at 2.0 before comparing: the guard
    is "paged serving stays >= ~2x legacy", not "reproduce the margin an
    unloaded runner happened to measure".
  * ``paged_vs_dense_cache_bytes`` — allocated KV bytes of the paged layout
    as a fraction of the dense layout at the same capacity (lower is
    better). Deterministic (pure allocation arithmetic), so the tolerance
    is a tight 3%: with the committed pool at 31/64 pages (~0.485x) this
    keeps the ratio under the 0.5x contract.
  * ``prefix_shared_goodput`` — engine goodput with the radix prefix cache
    ON as a multiple of OFF, on a shared-prefix workload (serve table;
    higher is better). The baseline is capped at 1.3 with a 0% tolerance:
    the hard contract is "prefix sharing buys >= 1.3x on shared-prefix
    traffic" (the committed run measures ~1.9x, so the floor has real
    headroom), and being a ratio of two timings, CI noise largely cancels.
  * ``prefix_group_admission_goodput`` — engine goodput with same-start
    grouped admission (prefill_rows = num_slots: one [rows, bucket] suffix
    prefill per admission wave) as a multiple of one-prefill-per-request
    admission, on short-suffix shared-prefix traffic (serve table; higher
    is better). The baseline is capped at 1.1 before comparing: the guard
    is "grouped admission does not lose to one-per-call", not the exact
    dispatch-overhead margin an unloaded CPU runner happened to measure.
  * ``persistent_prefix_hit_rate`` — fraction of a warm eval sweep's
    requests that hit the radix tree a PREVIOUS engine instance built and
    handed over through the ``PrefixStore`` (serve table; higher is
    better). Deterministic — every repeated prompt must hit, so the rate
    is exactly 1.0 and the tolerance is 0%: any drop means cross-engine
    adoption (fingerprint keying, close() handoff, or pool re-slotting)
    regressed.
  * ``preempt_vs_backpressure_goodput`` — engine goodput with
    preempt-and-requeue vs plain backpressure on an oversubscribed page
    pool (serve table; higher is better). Under strict FCFS requeue-at-head
    preemption buys head-of-line fairness, not aggregate throughput, so
    this is a parity guard against the requeue path decaying into
    preempt/re-admit thrash (an undamped victim policy measured ~0.5x;
    the damped one holds ~0.9x).
  * ``data_packed_kept`` — correctly-supervised completion-token fraction
    under greedy segment packing (data table; higher is better).
    Deterministic: any drop means the packer regressed.
  * ``data_prefetch_on_vs_off`` — packed-pipeline steps/s with the async
    prefetcher as a multiple of the synchronous loop (data table; higher is
    better; a timing ratio, noise cancels). The baseline is capped at 1.0
    before comparing: the guard is "prefetch must never make training >20%
    slower than the synchronous loop", not "reproduce the speedup an
    unloaded runner happened to measure" — on a saturated CI box the
    prefetch thread can legitimately win nothing.

Usage:  python -m benchmarks.diff_baseline BENCH_ci.json BENCH_baseline.json
Exit codes: 0 ok, 1 regression, 2 missing metric/file.
"""
from __future__ import annotations

import argparse
import json
import sys

# (name, extractor, direction, baseline_cap, tolerance) — direction +1:
# higher is better, -1: lower; baseline_cap (optional) bounds the committed
# baseline before comparison, for metrics whose headroom is machine-
# dependent; tolerance (optional) overrides the CLI/default tolerance for
# that one metric
_MEM_ROW = "adagradselect_banked"
_OBS_ROW = "adagradselect_dense_obs"


def _mem_col(col: str, row_name: str = _MEM_ROW):
    def extract(payload: dict):
        table = payload.get("memory_table") or []
        rows = table["rows"] if isinstance(table, dict) else table
        for row in rows or []:
            if row.get("name") == row_name:
                return row.get(col)
        return None
    return extract


KEY_METRICS = (
    ("banked_device_vs_full", _mem_col("device_vs_full"), -1, None, None),
    ("banked_step_time_vs_full", _mem_col("step_time_vs_full"),
     -1, None, 0.10),
    ("obs_overhead", _mem_col("obs_overhead", _OBS_ROW), +1, 1.0, 0.03),
    ("uniform_engine_vs_legacy",
     lambda p: (p.get("serve_table") or {}).get("uniform_engine_vs_legacy"),
     +1, None, None),
    ("staggered_engine_vs_legacy",
     lambda p: (p.get("serve_table") or {}).get("staggered_engine_vs_legacy"),
     +1, None, None),
    ("staggered_paged_vs_legacy",
     lambda p: (p.get("serve_table") or {}).get("staggered_paged_vs_legacy"),
     +1, 2.0, None),
    ("paged_vs_dense_cache_bytes",
     lambda p: (p.get("serve_table") or {}).get("paged_vs_dense_cache_bytes"),
     -1, None, 0.03),
    ("prefix_shared_goodput",
     lambda p: (p.get("serve_table") or {}).get("prefix_shared_goodput"),
     +1, 1.3, 0.0),
    ("prefix_group_admission_goodput",
     lambda p: (p.get("serve_table") or {})
     .get("prefix_group_admission_goodput"),
     +1, 1.1, None),
    ("persistent_prefix_hit_rate",
     lambda p: (p.get("serve_table") or {})
     .get("persistent_prefix_hit_rate"),
     +1, None, 0.0),
    ("preempt_vs_backpressure_goodput",
     lambda p: (p.get("serve_table") or {})
     .get("preempt_vs_backpressure_goodput"),
     +1, None, None),
    ("data_packed_kept",
     lambda p: (p.get("data_table") or {}).get("packed_kept"),
     +1, None, None),
    ("data_prefetch_on_vs_off",
     lambda p: (p.get("data_table") or {}).get("prefetch_on_vs_off"),
     +1, 1.0, None),
)


def diff(current: dict, baseline: dict, tolerance: float = 0.20) -> list[str]:
    """-> list of human-readable regression messages (empty = pass)."""
    failures = []
    for name, extract, direction, base_cap, metric_tol in KEY_METRICS:
        cur, base = extract(current), extract(baseline)
        if base is None:
            continue  # metric not in the committed baseline yet
        if cur is None:
            failures.append(f"{name}: missing from current run "
                            f"(baseline {base:.4f})")
            continue
        if base_cap is not None:
            base = min(base, base_cap)
        tol = tolerance if metric_tol is None else metric_tol
        if direction > 0:
            regressed = cur < base * (1.0 - tol)
            verdict = f"{cur:.4f} < {base:.4f} * {1 - tol:.2f}"
        else:
            regressed = cur > base * (1.0 + tol)
            verdict = f"{cur:.4f} > {base:.4f} * {1 + tol:.2f}"
        status = "REGRESSION" if regressed else "ok"
        print(f"{name:32s} current={cur:10.4f} baseline={base:10.4f} "
              f"[{status}]")
        if regressed:
            failures.append(f"{name}: {verdict}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="BENCH_ci.json from this run")
    ap.add_argument("baseline", help="committed BENCH_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed relative regression (default 0.20)")
    args = ap.parse_args(argv)
    try:
        with open(args.current) as f:
            current = json.load(f)
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"diff_baseline: cannot load inputs: {e}", file=sys.stderr)
        return 2
    failures = diff(current, baseline, args.tolerance)
    if failures:
        print("\nbenchmark regressions vs committed baseline:",
              file=sys.stderr)
        for msg in failures:
            print(f"  - {msg}", file=sys.stderr)
        return 1
    print("benchmark trajectory within tolerance of baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
