"""Perf-iteration driver: relower a hillclimb cell with a named variant and
record the roofline delta (EXPERIMENTS.md §Perf evidence).

  PYTHONPATH=src python -m benchmarks.hillclimb <cell> <variant>

Cells:    llama | chatglm | deepseek
Variants: baseline | kvshard | kvshard_dots | gather_ep | bf16mom |
          bf16mom_mb16 | dots
"""
import json
import os
import sys


def main():
    cell, variant = sys.argv[1], sys.argv[2]
    arch, shape = {
        "llama": ("llama3.2-1b", "train_4k"),
        "chatglm": ("chatglm3-6b", "train_4k"),
        "deepseek": ("deepseek-v3-671b", "train_4k"),
    }[cell]

    from repro.configs import get_config
    from repro.launch.dryrun import run_cell

    cfg = get_config(arch)
    kw = {}
    if "kvshard" in variant:
        cfg = cfg.replace(seq_shard_kv=True)
    if "dots" in variant:
        cfg = cfg.replace(remat="dots")
    if "bf16mom" in variant:
        kw["moment_dtype"] = "bfloat16"
    if "mb16" in variant:
        kw["microbatch"] = 16
    # "gather_ep" / "baseline": code state as-is

    r = run_cell(arch, shape, "single", cfg_override=cfg,
                 hlo_dir="results/perf/hlo", **kw)
    os.makedirs("results/perf", exist_ok=True)
    with open(f"results/perf/{cell}_{variant}.json", "w") as f:
        json.dump(r, f, indent=2)
    if r["status"] == "ok":
        rf = r["roofline"]
        print(f"{cell}/{variant}: compute={rf['compute_s']:.2f}s "
              f"memory={rf['memory_s']:.2f}s coll={rf['collective_s']:.2f}s "
              f"peak={r['memory']['peak_per_device']/2**30:.1f}GiB "
              f"useful={rf['useful_flops_frac']:.2f}")
    else:
        print(r["error"])
        print(r["traceback"][-800:])


if __name__ == "__main__":
    main()
