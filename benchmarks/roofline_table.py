"""Aggregate dry-run JSONs into the roofline table (EXPERIMENTS.md source).

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [results_dir]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(results_dir: str = "results"):
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "dryrun_*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_ms(s):
    return f"{s * 1e3:9.2f}"


def table(rows, mesh: str = "single"):
    out = []
    hdr = (f"{'arch':22s} {'shape':12s} {'compute_ms':>10s} {'memory_ms':>10s} "
           f"{'coll_ms':>9s} {'bound':>10s} {'useful%':>8s} {'peak_GiB':>9s} "
           f"{'status':>7s}")
    out.append(hdr)
    out.append("-" * len(hdr))
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"{r['arch']:22s} {r['shape']:12s} {'':>42s} "
                       f"{'':>8s} {'':>9s}  ERROR")
            continue
        rf = r["roofline"]
        peak = r["memory"]["peak_per_device"] / (1 << 30)
        out.append(
            f"{r['arch']:22s} {r['shape']:12s} {fmt_ms(rf['compute_s']):>10s} "
            f"{fmt_ms(rf['memory_s']):>10s} {fmt_ms(rf['collective_s']):>9s} "
            f"{rf['bottleneck']:>10s} {100 * rf['useful_flops_frac']:7.1f}% "
            f"{peak:9.2f} {'ok':>7s}")
    return "\n".join(out)


def run(results_dir: str = "results"):
    """benchmarks.run hook: emit one CSV row per dry-run cell."""
    rows = load(results_dir)
    out = []
    for r in rows:
        if r["status"] != "ok":
            out.append((f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", -1,
                        "ERROR"))
            continue
        rf = r["roofline"]
        dominant = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        out.append((
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            dominant * 1e6,
            f"bound={rf['bottleneck']};useful={rf['useful_flops_frac']:.3f}"))
    return out


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "results"
    rows = load(d)
    for mesh in ("single", "multi"):
        if any(r.get("mesh") == mesh for r in rows):
            print(f"\n=== mesh: {mesh} ===")
            print(table(rows, mesh))
