"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set REPRO_BENCH_STEPS to shrink the
training benches (CI); roofline rows appear when results/dryrun_*.json exist
(produced by repro.launch.dryrun).
"""
from __future__ import annotations

import os


def main() -> None:
    steps = int(os.environ.get("REPRO_BENCH_STEPS", "150"))
    rows = []

    from benchmarks import (bench_fig1, bench_fig3, bench_fig4, bench_kernels,
                            bench_serve, bench_table1, roofline_table)

    for mod, kwargs in (
        (bench_kernels, {}),
        (bench_table1, {"steps": steps}),
        (bench_fig1, {"steps": max(40, steps // 2)}),
        (bench_fig3, {"steps": steps}),
        (bench_fig4, {"steps": steps}),
        (bench_serve, {}),
        (roofline_table, {}),
    ):
        try:
            rows.extend(mod.run(**kwargs))
        except Exception as e:  # noqa: BLE001
            rows.append((f"{mod.__name__}/FAILED", -1.0,
                         f"{type(e).__name__}:{e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
