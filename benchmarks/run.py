"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set REPRO_BENCH_STEPS to shrink the
training benches (CI); roofline rows appear when results/dryrun_*.json exist
(produced by repro.launch.dryrun). ``--json PATH`` additionally emits the
rows plus the structured optimizer-memory and serve tables as one
consolidated JSON for trajectory tracking across PRs — CI runs
``--only memory,serve --json BENCH_ci.json`` and diffs the result against
the committed ``BENCH_baseline.json`` via ``benchmarks/diff_baseline.py``.
"""
from __future__ import annotations

import argparse
import json
import os


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write rows + structured tables as JSON")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benches to run "
                         "(kernels,table1,fig1,fig3,fig4,memory,serve,"
                         "roofline); default: all")
    args = ap.parse_args(argv)

    steps = int(os.environ.get("REPRO_BENCH_STEPS", "150"))
    rows = []

    from benchmarks import (bench_data, bench_fig1, bench_fig3, bench_fig4,
                            bench_kernels, bench_memory, bench_serve,
                            bench_table1, roofline_table)

    suite = (
        ("kernels", bench_kernels, {}),
        ("table1", bench_table1, {"steps": steps}),
        ("fig1", bench_fig1, {"steps": max(40, steps // 2)}),
        ("fig3", bench_fig3, {"steps": steps}),
        ("fig4", bench_fig4, {"steps": steps}),
        ("memory", bench_memory, {"steps": max(10, steps // 5)}),
        ("serve", bench_serve, {}),
        ("data", bench_data, {"steps": max(6, steps // 5)}),
        ("roofline", roofline_table, {}),
    )
    only = ({s.strip() for s in args.only.split(",") if s.strip()}
            if args.only else None)
    if only:
        unknown = only - {key for key, _, _ in suite}
        if unknown:
            raise SystemExit(f"--only: unknown bench keys {sorted(unknown)}; "
                             f"known: {[key for key, _, _ in suite]}")

    for key, mod, kwargs in suite:
        if only is not None and key not in only:
            continue
        try:
            rows.extend(mod.run(**kwargs))
        except Exception as e:  # noqa: BLE001
            rows.append((f"{mod.__name__}/FAILED", -1.0,
                         f"{type(e).__name__}:{e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        payload = {
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
            "memory_table": bench_memory.LAST_TABLE,
            "serve_table": bench_serve.LAST_TABLE,
            "data_table": bench_data.LAST_TABLE,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
