"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Set REPRO_BENCH_STEPS to shrink the
training benches (CI); roofline rows appear when results/dryrun_*.json exist
(produced by repro.launch.dryrun). ``--json PATH`` additionally emits the
rows plus the optimizer-memory table (bench_memory) as JSON for trajectory
tracking across PRs.
"""
from __future__ import annotations

import argparse
import json
import os


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write rows + memory table as JSON")
    args = ap.parse_args(argv)

    steps = int(os.environ.get("REPRO_BENCH_STEPS", "150"))
    rows = []

    from benchmarks import (bench_fig1, bench_fig3, bench_fig4, bench_kernels,
                            bench_memory, bench_serve, bench_table1,
                            roofline_table)

    for mod, kwargs in (
        (bench_kernels, {}),
        (bench_table1, {"steps": steps}),
        (bench_fig1, {"steps": max(40, steps // 2)}),
        (bench_fig3, {"steps": steps}),
        (bench_fig4, {"steps": steps}),
        (bench_memory, {"steps": max(10, steps // 5)}),
        (bench_serve, {}),
        (roofline_table, {}),
    ):
        try:
            rows.extend(mod.run(**kwargs))
        except Exception as e:  # noqa: BLE001
            rows.append((f"{mod.__name__}/FAILED", -1.0,
                         f"{type(e).__name__}:{e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        payload = {
            "rows": [{"name": n, "us_per_call": us, "derived": d}
                     for n, us, d in rows],
            "memory_table": bench_memory.LAST_TABLE,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
