"""End-to-end fine-tuning driver (deliverable b): train a decoder LM on the
synthetic MetaMathQA-proxy with AdaGradSelect, evaluate GSM8K-protocol exact
match, compare against full fine-tuning, checkpoint + resume.

  PYTHONPATH=src python examples/finetune_math.py --preset ci      (~3 min CPU)
  PYTHONPATH=src python examples/finetune_math.py --preset full    (~100M model,
      300 steps — the paper-scale configuration; expect hours on CPU,
      minutes on one accelerator)
"""
import argparse
import tempfile

import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.data.synthetic import MathTaskConfig
from repro.train.evaluate import math_accuracy
from repro.train.trainer import Trainer

PRESETS = {
    # ~1M params: CI-scale sanity
    "ci": dict(model=ModelConfig(
        name="math-ci", family="dense", num_layers=6, d_model=96, num_heads=4,
        num_kv_heads=2, head_dim=24, d_ff=384, vocab_size=32, dtype="float32",
        remat="none", tie_embeddings=True), steps=200, batch=16),
    # ~100M params: the end-to-end configuration
    "full": dict(model=ModelConfig(
        name="math-100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32,
        dtype="float32", remat="none", tie_embeddings=True), steps=300,
        batch=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=list(PRESETS))
    ap.add_argument("--k", type=float, default=25.0)
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--methods", default="adagradselect,full",
                    help="comma-separated repro.methods registry names "
                         "(e.g. adagradselect,lisa,grass,lora,full)")
    args = ap.parse_args()
    preset = PRESETS[args.preset]
    model, steps = preset["model"], args.steps or preset["steps"]
    methods_to_run = [m.strip() for m in args.methods.split(",") if m.strip()]

    task = MathTaskConfig(digits=3, seq_len=64)
    results = {}
    for method in methods_to_run:
        ckdir = tempfile.mkdtemp(prefix=f"ft_{method}_")
        tcfg = TrainConfig(
            model=model,
            method=method,
            select=SelectConfig(k_percent=args.k,
                                steps_per_epoch=max(1, steps // 3),
                                epsilon_decay=0.05),
            optimizer=OptimizerConfig(lr=3e-3, schedule="cosine",
                                      warmup_steps=15, total_steps=steps),
            seq_len=task.seq_len, global_batch=preset["batch"], steps=steps,
            log_every=max(1, steps // 5), checkpoint_dir=ckdir,
            checkpoint_every=max(1, steps // 2))
        tr = Trainer(tcfg)
        log = tr.train()
        params = tr.method.eval_params(model, tcfg.optimizer, tr.state)
        acc = math_accuracy(params, model, task, num_problems=64)
        st = float(np.mean(log.step_times[3:]))
        rep = tr.method.trainable_param_report(model, tr.state)
        results[method] = (log.losses[-1], acc, st)
        print(f"[{method}] loss {log.losses[0]:.3f}->{log.losses[-1]:.4f} "
              f"exact-match {acc:.2%}  step {st*1e3:.0f}ms  "
              f"trainable {rep.trainable_fraction:.0%}  (ckpt: {ckdir})")

    if "adagradselect" in results and "full" in results:
        a, f = results["adagradselect"], results["full"]
        print(f"\nAdaGradSelect vs full-FT: accuracy {a[1]:.2%} vs {f[1]:.2%}, "
              f"step time {a[2]/f[2]:.2f}x, "
              f"optimizer-state residency {args.k:.0f}% of blocks "
              f"(paper: ~equal accuracy, faster + 35% less memory)")


if __name__ == "__main__":
    main()
