"""SFT on a real prompt/completion corpus through the streaming pipeline:
segment-aware packing, async device prefetch, cursor-exact resume.

  PYTHONPATH=src python examples/finetune_sft.py            (~1 min CPU)

The ``jsonl_sft`` record schema is one JSON object per line:

    {"prompt": "Q: What is 17 + 25?\\n", "completion": "A: 42"}

* ``prompt`` is context: byte-tokenized with a leading BOS, loss-masked 0.
* ``completion`` is supervised: loss-masked 1, terminated with EOS.

The packer places several records per [B, L] row (segment_ids 1..n, 0 for
padding; positions restart at each segment) and the model attends
block-diagonally — the packed loss is exactly the per-example loss, but a
variable-length corpus wastes far fewer token slots than one-example-per-row
padding (and, unlike the legacy concat/reshape layout, never trains across
example boundaries or on prompts). ``prefetch_depth > 0`` builds and
device_puts batches on a background thread; the trajectory is bit-identical
with prefetch on or off. The data cursor rides along in checkpoints, so an
interrupted run resumes the record stream with no skipped/repeated examples.
"""
import json
import os
import tempfile

import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.data import loader
from repro.data.pipeline import JsonlSftRecords, packing
from repro.data.tokenizer import VOCAB_SIZE
from repro.train.trainer import Trainer

MODEL = ModelConfig(
    name="sft-demo", family="dense", num_layers=4, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=VOCAB_SIZE,
    dtype="float32", remat="none", tie_embeddings=True)

SEQ_LEN, BATCH, STEPS = 256, 8, 60


def write_demo_corpus(path: str, n: int = 200, seed: int = 0):
    """Arithmetic word problems as {"prompt", "completion"} lines."""
    rng = np.random.default_rng(seed)
    with open(path, "w") as f:
        for _ in range(n):
            a, b = int(rng.integers(10, 500)), int(rng.integers(10, 500))
            f.write(json.dumps({
                "prompt": f"Q: What is {a} + {b}?\n",
                "completion": f"A: {a + b}",
            }) + "\n")


def main():
    workdir = tempfile.mkdtemp(prefix="sft_demo_")
    corpus = os.path.join(workdir, "train.jsonl")
    write_demo_corpus(corpus)

    stats = packing.packing_stats(JsonlSftRecords(corpus), SEQ_LEN, BATCH)
    print(f"corpus: {stats['num_records']} records, "
          f"{stats['corpus_tokens']} tokens | packed slot util "
          f"{stats['packed_slot_util']:.0%} vs unpacked "
          f"{stats['unpacked_slot_util']:.0%} | supervised-token retention: "
          f"packed {stats['packed_kept']:.0%}, legacy drop-remainder "
          f"{stats['drop_remainder_kept']:.0%}")

    tcfg = TrainConfig(
        model=MODEL, method="adagradselect",
        select=SelectConfig(k_percent=30, steps_per_epoch=STEPS // 3),
        optimizer=OptimizerConfig(lr=3e-3, schedule="cosine",
                                  warmup_steps=10, total_steps=STEPS),
        seq_len=SEQ_LEN, global_batch=BATCH, steps=STEPS,
        log_every=STEPS // 4,
        checkpoint_dir=os.path.join(workdir, "ckpt"),
        checkpoint_every=STEPS // 2)

    pipe = loader.make_source("jsonl_sft", seq_len=SEQ_LEN,
                              global_batch=BATCH, path=corpus)
    trainer = Trainer(tcfg, data_source=pipe, prefetch_depth=2)
    start = trainer.maybe_restore()
    log = trainer.train(steps=STEPS - start, start_step=start)
    print(f"loss {log.losses[0]:.3f} -> {log.losses[-1]:.3f} | "
          f"mean step {np.mean(log.step_times[3:]) * 1e3:.0f} ms | "
          f"data cursor {pipe.cursor()} (saved in checkpoint meta — rerun "
          f"with the same workdir to resume the stream exactly)")


if __name__ == "__main__":
    main()
