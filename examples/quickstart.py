"""Quickstart: fine-tune a small LM with AdaGradSelect on the synthetic
math task and watch the bandit concentrate on high-impact blocks.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.core import build_partition
from repro.train.trainer import Trainer

model = ModelConfig(name="quickstart", family="dense", num_layers=6,
                    d_model=96, num_heads=4, num_kv_heads=2, head_dim=24,
                    d_ff=384, vocab_size=32, dtype="float32", remat="none",
                    tie_embeddings=True)

tcfg = TrainConfig(
    model=model,
    select=SelectConfig(policy="adagradselect", k_percent=25,
                        steps_per_epoch=60, epsilon_decay=0.05),
    optimizer=OptimizerConfig(lr=3e-3, schedule="cosine", total_steps=120,
                              warmup_steps=10),
    seq_len=64, global_batch=16, steps=120, log_every=20)

trainer = Trainer(tcfg, method="adagradselect")
log = trainer.train()

part = build_partition(model)
freq = np.asarray(trainer.state["sel"]["freq"]).astype(int)
print(f"\nloss: {log.losses[0]:.3f} -> {log.losses[-1]:.3f}")
print(f"selected {tcfg.select.num_selected(part.num_blocks)} of "
      f"{part.num_blocks} blocks per step")
print("\nper-block update frequency (the bandit's learned arm statistics):")
for name, f in zip(part.block_names, freq):
    print(f"  {name:16s} {'#' * int(30 * f / max(freq.max(), 1)):30s} {f}")
