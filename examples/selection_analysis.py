"""Reproduce the paper's selection analyses on one run:
  * Fig. 3 — accuracy vs %-blocks-selected sweep (Alg. 1 gradient-guided)
  * 3.1's update-frequency claim — early blocks dominate the distribution

  PYTHONPATH=src python examples/selection_analysis.py
"""
import numpy as np

from benchmarks.common import BENCH_MODEL, run_method
from repro.configs.base import (OptimizerConfig, SelectConfig, TrainConfig)
from repro.core import build_partition
from repro.train.trainer import Trainer

print("== Fig.3 sweep: accuracy vs % blocks selected (gradient-guided) ==")
for k in (10, 25, 50, 100):
    method = "all" if k == 100 else "topk_grad"
    r = run_method(method=method, k_percent=k, steps=120, eval_problems=32)
    print(f"  k={k:3d}%  loss={r.final_loss:.4f}  exact-match={r.accuracy:.2%}"
          f"  step={r.step_time_us/1e3:.0f}ms")

print("\n== update-frequency distribution (AdaGradSelect, 60 steps) ==")
tcfg = TrainConfig(
    model=BENCH_MODEL,
    select=SelectConfig(policy="adagradselect", k_percent=25,
                        steps_per_epoch=30, epsilon_decay=0.05),
    optimizer=OptimizerConfig(lr=3e-3, schedule="constant", warmup_steps=5),
    seq_len=64, global_batch=16, steps=60, log_every=0)
tr = Trainer(tcfg, method="adagradselect")
tr.train()
part = build_partition(BENCH_MODEL)
freq = np.asarray(tr.state["sel"]["freq"]).astype(int)
norms = np.asarray(tr.state["sel"]["cum_norms"])
for name, f, n in zip(part.block_names, freq, norms):
    print(f"  {name:16s} freq={f:3d}  cum_grad_norm={n:8.2f} "
          f"{'#' * int(25 * f / max(freq.max(), 1))}")
print("\n(paper 3.1: a few blocks — typically early ones — dominate)")
