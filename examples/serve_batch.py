"""Batched serving demo: continuous-batching engine (prefill into slots +
chunked decode with a persistent KV cache), report tokens/sec; runs any
smoke arch (--arch).

  PYTHONPATH=src python examples/serve_batch.py --arch llama3.2-1b
  PYTHONPATH=src python examples/serve_batch.py --arch mamba2-2.7b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import registry
from repro.serve.engine import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--decode-chunk", type=int, default=8)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(ssm_chunk=32)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    batch = {"tokens": np.asarray(jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size), np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = np.asarray(0.02 * jax.random.normal(
            rng, (args.batch, cfg.num_frontend_tokens, cfg.d_model)))
    if cfg.family == "encdec":
        batch["src_embeds"] = np.asarray(0.02 * jax.random.normal(
            rng, (args.batch, args.prompt_len // cfg.frontend_len_ratio,
                  cfg.d_model)))

    prefix = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
    max_len = args.prompt_len + prefix + args.new_tokens
    kw = dict(max_new_tokens=args.new_tokens, max_len=max_len,
              decode_chunk=args.decode_chunk)

    # warmup (compile) with the SAME max_len/shapes so the timed call is
    # pure steady state
    generate(params, cfg, batch, **kw)
    t0 = time.perf_counter()
    out = generate(params, cfg, batch, **kw)
    dt = time.perf_counter() - t0
    print(f"[{args.arch}] batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    print(f"  {args.batch * args.new_tokens / dt:8.1f} tok/s "
          f"({dt*1e3/args.new_tokens:.1f} ms/step)")
    print(f"  sample: {out[0][:16].tolist()}")


if __name__ == "__main__":
    main()
