"""Batched serving demo: continuous-batching engine (prefill into slots +
chunked decode with a persistent KV cache), report tokens/sec and page-pool
utilization; runs any smoke arch (--arch).

  PYTHONPATH=src python examples/serve_batch.py --arch llama3.2-1b
  PYTHONPATH=src python examples/serve_batch.py --arch mamba2-2.7b
  PYTHONPATH=src python examples/serve_batch.py --kv-layout paged --page-size 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import registry
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--kv-layout", choices=["dense", "paged"],
                    default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(ssm_chunk=32)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    batch = {"tokens": np.asarray(jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size), np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = np.asarray(0.02 * jax.random.normal(
            rng, (args.batch, cfg.num_frontend_tokens, cfg.d_model)))
    if cfg.family == "encdec":
        batch["src_embeds"] = np.asarray(0.02 * jax.random.normal(
            rng, (args.batch, args.prompt_len // cfg.frontend_len_ratio,
                  cfg.d_model)))

    prefix = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
    max_len = args.prompt_len + prefix + args.new_tokens
    engine_kw = dict(max_len=max_len, num_slots=args.batch,
                     decode_chunk=args.decode_chunk,
                     kv_layout=args.kv_layout, page_size=args.page_size)

    # warmup (compile) with the SAME max_len/shapes so the timed call is
    # pure steady state
    ServeEngine(cfg, params, **engine_kw).generate(
        batch, max_new_tokens=args.new_tokens)
    engine = ServeEngine(cfg, params, **engine_kw)
    t0 = time.perf_counter()
    out = engine.generate(batch, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    print(f"[{args.arch}] batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens} kv_layout={args.kv_layout}")
    pool = engine.page_pool_stats()
    util = (f"  pool {pool['peak_live_pages']}/{pool['num_pages']} pages "
            f"({pool['peak_live_pages'] / pool['num_pages']:.0%} peak)"
            if pool is not None else "  pool n/a (dense layout)")
    print(f"  {args.batch * args.new_tokens / dt:8.1f} tok/s "
          f"({dt*1e3/args.new_tokens:.1f} ms/step)"
          f"  | cache {engine.kv_cache_bytes() / 1e6:.2f} MB |{util}")
    print(f"  sample: {out[0][:16].tolist()}")


if __name__ == "__main__":
    main()
