"""Batched serving demo: continuous-batching engine (prefill into slots +
chunked decode with a persistent KV cache), report tokens/sec plus the
engine's consolidated ``stats_snapshot()`` (counters, per-request latency
histograms, page pool, fn-cache); runs any smoke arch (--arch).

  PYTHONPATH=src python examples/serve_batch.py --arch llama3.2-1b
  PYTHONPATH=src python examples/serve_batch.py --arch mamba2-2.7b
  PYTHONPATH=src python examples/serve_batch.py --kv-layout paged --page-size 8

With ``--kv-layout paged`` a second section runs a GSM8K-style few-shot
workload — every request shares the same long "few-shot examples" prefix
and differs only in its short question — once with the radix prefix cache
off and once on. On the cached run, each admission after the first aliases
the shared prefix's pages copy-on-write and prefills only its question, so
the prefix-hit counters and the prefill-token saving are directly visible.

A third section runs the same workload as TWO eval sweeps over two separate
``ServeEngine`` instances sharing one ``PrefixStore``: the first engine's
``close()`` hands its radix tree (and page pool) to the store, the second
engine adopts it warm, and its admissions alias the cached pages from
request one — the cross-engine reuse pattern of repeated eval sweeps over
the same few-shot prompts.
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import registry
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.prefix_store import PrefixStore
from repro.serve.scheduler import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=48)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--kv-layout", choices=["dense", "paged"],
                    default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(ssm_chunk=32)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    batch = {"tokens": np.asarray(jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size), np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = np.asarray(0.02 * jax.random.normal(
            rng, (args.batch, cfg.num_frontend_tokens, cfg.d_model)))
    if cfg.family == "encdec":
        batch["src_embeds"] = np.asarray(0.02 * jax.random.normal(
            rng, (args.batch, args.prompt_len // cfg.frontend_len_ratio,
                  cfg.d_model)))

    prefix = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
    max_len = args.prompt_len + prefix + args.new_tokens
    serve_cfg = ServeConfig(max_len=max_len, num_slots=args.batch,
                            decode_chunk=args.decode_chunk,
                            kv_layout=args.kv_layout,
                            page_size=args.page_size)

    # warmup (compile) with the SAME max_len/shapes so the timed call is
    # pure steady state
    ServeEngine(cfg, params, serve_cfg).generate(
        batch, max_new_tokens=args.new_tokens)
    engine = ServeEngine(cfg, params, serve_cfg)
    t0 = time.perf_counter()
    out = engine.generate(batch, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    print(f"[{args.arch}] batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens} kv_layout={args.kv_layout}")
    print(f"  {args.batch * args.new_tokens / dt:8.1f} tok/s "
          f"({dt*1e3/args.new_tokens:.1f} ms/step)"
          f"  | cache {engine.kv_cache_bytes() / 1e6:.2f} MB")
    print(f"  sample: {out[0][:16].tolist()}")
    # one consolidated dump (engine counters, latency histograms, page pool,
    # scheduler, fn-cache) — key structure documented in serve/engine.py
    print("  stats_snapshot:")
    print("  " + json.dumps(engine.stats_snapshot(), indent=2)
          .replace("\n", "\n  "))

    pool = engine.page_pool_stats()
    if args.kv_layout == "paged" and pool is not None:
        shared_prefix_demo(cfg, params, page_size=args.page_size)
        two_sweep_demo(cfg, params, page_size=args.page_size)


def shared_prefix_demo(cfg, params, *, page_size, num_requests=8,
                       prefix_pages=6, question_len=7, new_tokens=8):
    """GSM8K-style few-shot serving: one shared few-shot prefix, distinct
    short questions, prefix cache off vs on (same tokens, fewer prefilled).

    num_slots=2 keeps admissions trailing completions, so all but the first
    couple of requests find the shared prefix already in the radix tree.
    """
    rng = np.random.default_rng(5)
    fewshot = rng.integers(1, cfg.vocab_size,
                           (prefix_pages * page_size,)).astype(np.int32)
    questions = [rng.integers(1, cfg.vocab_size,
                              (question_len,)).astype(np.int32)
                 for _ in range(num_requests)]
    prompts = [np.concatenate([fewshot, q]) for q in questions]
    max_len = len(prompts[0]) + new_tokens
    kw = dict(max_len=max_len, num_slots=2, decode_chunk=4,
              kv_layout="paged", page_size=page_size, min_bucket=8)

    def run(prefix_cache):
        eng = ServeEngine(cfg, params,
                          ServeConfig(prefix_cache=prefix_cache, **kw))
        t0 = time.perf_counter()
        res = eng.run([Request(uid=i, tokens=prompts[i],
                               max_new_tokens=new_tokens, arrival=i)
                       for i in range(num_requests)])
        return res, eng, time.perf_counter() - t0

    run(False)  # warmup/compile both paths once
    run(True)
    off, off_eng, t_off = run(False)
    on, on_eng, t_on = run(True)
    assert all(np.array_equal(on[u], off[u]) for u in off)  # token-exact
    s = on_eng.stats
    print(f"[shared-prefix] {num_requests} requests x "
          f"({prefix_pages * page_size} shared few-shot tokens + "
          f"{question_len}-token question), identical outputs:")
    print(f"  prefix cache off: {off_eng.stats['prefill_tokens']:5d} tokens "
          f"prefilled, {sum(map(len, off.values())) / t_off:8.1f} tok/s")
    print(f"  prefix cache on:  {s['prefill_tokens']:5d} tokens "
          f"prefilled, {sum(map(len, on.values())) / t_on:8.1f} tok/s  "
          f"({s['prefix_hits']} hits, {s['prefix_pages_shared']} pages "
          f"aliased, pool high water "
          f"{on_eng.page_pool_stats()['high_water_pages']} pages)")


def two_sweep_demo(cfg, params, *, page_size, num_requests=6,
                   prefix_pages=6, question_len=7, new_tokens=8):
    """Cross-engine prefix persistence: two eval sweeps over the SAME
    few-shot prompts, each in its own ``ServeEngine``, sharing one
    ``PrefixStore``. Sweep 1 prefills everything and ``close()`` hands the
    radix tree to the store; sweep 2's engine adopts it warm, so every one
    of its admissions is a prefix hit and only suffixes (questions + the
    COW tail token) are prefilled."""
    rng = np.random.default_rng(9)
    fewshot = rng.integers(1, cfg.vocab_size,
                           (prefix_pages * page_size,)).astype(np.int32)
    prompts = [np.concatenate([fewshot,
                               rng.integers(1, cfg.vocab_size,
                                            (question_len,)).astype(np.int32)])
               for _ in range(num_requests)]
    store = PrefixStore()
    scfg = ServeConfig(max_len=len(prompts[0]) + new_tokens, num_slots=2,
                       decode_chunk=4, kv_layout="paged",
                       page_size=page_size, min_bucket=8, prefix_cache=True,
                       prefix_store=store)

    def sweep():
        eng = ServeEngine(cfg, params, scfg)
        res = eng.run([Request(uid=i, tokens=prompts[i],
                               max_new_tokens=new_tokens)
                       for i in range(num_requests)])
        stats = dict(eng.stats)
        eng.close()  # hands the tree + pool to the store
        return res, stats

    res1, s1 = sweep()
    res2, s2 = sweep()
    assert all(np.array_equal(res1[u], res2[u]) for u in res1)
    print(f"[two-sweep] {num_requests} prompts, two engines, one "
          f"PrefixStore ({store.stats['adoptions']} adoption):")
    print(f"  sweep 1 (cold tree): {s1['prefill_tokens']:5d} tokens "
          f"prefilled, {s1['prefix_hits']} hits")
    print(f"  sweep 2 (adopted):   {s2['prefill_tokens']:5d} tokens "
          f"prefilled, {s2['prefix_hits']} hits "
          f"({s2['prefix_pages_shared']} pages re-aliased across engines)")


if __name__ == "__main__":
    main()
