"""Telemetry walkthrough: produce Perfetto traces of a banked train run and
a serve workload, plus the selection heatmap and a metrics snapshot.

  PYTHONPATH=src python examples/trace_walkthrough.py --out-dir /tmp/traces

Writes:
  train_trace.json  — open at https://ui.perfetto.dev (or chrome://tracing).
      The main thread shows train_step spans nesting phase_a (fwd/bwd +
      selection) / swap (bank residency fix-up) / phase_b (banked update +
      dispatch); the "swap-planner_0" track shows the background boundary
      dispatch overlapping the next step's compute — the async-swap overlap
      is directly visible as parallel lanes. Mispredicted boundaries appear
      as swap_mispredict instants.
  serve_trace.json  — admission/prefill_chunk/decode_chunk spans on the
      engine thread and one synthetic "request <uid>" track per request
      carrying its retroactive ttft / e2e spans.
  metrics.json      — the obs registry snapshot; render with
      python -m repro.launch.inspect metrics.json

The walkthrough also prints the selection-frequency heatmap: shade = how
often each block was selected in each step window, bottom row = selection
entropy. AdaGradSelect's epsilon-decay shows the exploration->exploitation
transition as entropy falling over time.
"""
import argparse
import json
import os

import jax
import numpy as np

from repro import obs
from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig, SelectConfig, TrainConfig
from repro.models import registry
from repro.obs import report
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request
from repro.train.trainer import Trainer


def train_trace(out_dir: str, arch: str, steps: int) -> None:
    mcfg = get_smoke_config(arch)
    tcfg = TrainConfig(
        model=mcfg, method="adagradselect",
        select=SelectConfig(k_percent=25, steps_per_epoch=max(2, steps // 4)),
        optimizer=OptimizerConfig(lr=1e-3, total_steps=steps, offload="host",
                                  moment_residency="banked", async_swap=True),
        seq_len=64, global_batch=4, steps=steps, seed=0, log_every=0)
    obs.enable()
    try:
        trainer = Trainer(tcfg)
        trainer.train()
        path = os.path.join(out_dir, "train_trace.json")
        obs.export_trace(path)
        print(f"[train] banked adagradselect, {steps} steps -> {path}")
        print(report.render_selection_trace(obs.selection_trace()))
        with open(os.path.join(out_dir, "metrics.json"), "w") as f:
            json.dump(obs.snapshot(), f, indent=2)
    finally:
        obs.disable()


def serve_trace(out_dir: str, arch: str, num_requests: int) -> None:
    cfg = get_smoke_config(arch)
    params = registry.get(cfg).init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size,
                                        (16 + 2 * i,)).astype(np.int32),
                    max_new_tokens=12, arrival=i)
            for i in range(num_requests)]
    obs.enable(selection=False)
    try:
        eng = ServeEngine(cfg, params,
                          ServeConfig(max_len=64, num_slots=2,
                                      decode_chunk=4))
        eng.run(reqs)
        path = os.path.join(out_dir, "serve_trace.json")
        obs.export_trace(path)
        print(f"[serve] {num_requests} staggered requests -> {path}")
        print("  " + json.dumps(eng.stats_snapshot()["latency_us"]["ttft"]))
    finally:
        obs.disable()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--out-dir", default="/tmp/repro_traces")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--requests", type=int, default=4)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    train_trace(args.out_dir, args.arch, args.steps)
    serve_trace(args.out_dir, args.arch, args.requests)
    print(f"open the traces at https://ui.perfetto.dev "
          f"(Open trace file -> {args.out_dir}/*.json)")


if __name__ == "__main__":
    main()
