"""repro: AdaGradSelect (adaptive gradient-guided block selection) as a
production multi-pod JAX framework. See README.md / DESIGN.md.

Public API surface:
    repro.configs        -- get_config / get_smoke_config / SHAPES / dataclasses
    repro.core           -- build_partition, block_grad_norms, selection-policy
                            registry (register_policy/select), masked AdamW
    repro.methods        -- fine-tuning method registry: build(name, tcfg) ->
                            FinetuneMethod (full/adagradselect/topk_grad/
                            random/lora/lisa/grass)
    repro.models         -- registry.get(cfg): init/apply_train/prefill/decode_step
    repro.train          -- Trainer (method-agnostic loop), shared loss/accum
    repro.serve          -- engine.generate
    repro.launch         -- mesh / dryrun / train / serve entry points
"""

__version__ = "1.0.0"
