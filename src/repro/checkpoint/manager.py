"""Fault-tolerant checkpointing.

Design (per DESIGN.md §7, sized for 1000+ node operation):
  * atomic   — write to <dir>/tmp.<step>, fsync, rename to <dir>/step_<N>
  * async    — a jitted device->host snapshot is taken synchronously (cheap),
               serialization runs on a background thread so the train loop
               never blocks on storage
  * keep-k   — old steps garbage-collected after a successful save
  * elastic  — restore() reshards to whatever mesh/device-count the *current*
               process runs (shardings are applied at device_put time, not
               baked into the file), so a job can come back on a different
               slice size
  * complete — the TrainState (params, AdamW moments — dense m/v or the
               banked layout's device banks + slot_map + host-resident full
               store, per-block counts, AdaGradSelect freq/cum_norms/step/
               PRNG, data cursor) round-trips bit-exactly; the bandit's
               learned arm statistics and the moment residency map survive
               preemption. Host-resident numpy leaves (the banked full
               store) are copied at snapshot time: the train step mutates
               them in place, and the async writer needs a consistent view
  * multi-host — every process writes its own <step>/proc_<i>.npz with its
               addressable shards (single-host writes one file; the format
               is identical)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro.utils.trees import tree_leaves_with_path


def _flatten(state) -> dict[str, np.ndarray]:
    return {path: np.asarray(leaf) for path, leaf in tree_leaves_with_path(state)}


def _unflatten_into(target, flat: dict):
    """Rebuild arrays in the structure of ``target`` from the flat dict."""
    def pick(path, leaf):
        try:
            arr = flat[path]
        except KeyError:
            raise KeyError(
                f"checkpoint has no leaf {path!r} — the saved TrainState "
                f"predates the current state schema (e.g. checkpoints from "
                f"before the banked-optimizer / selection-indices layout); "
                f"restart from scratch or migrate the checkpoint") from None
        assert arr.shape == tuple(leaf.shape), (path, arr.shape, leaf.shape)
        return arr
    from repro.utils.trees import tree_map_with_path
    return tree_map_with_path(pick, target)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save
    def save(self, step: int, state, extra_meta: dict | None = None):
        """Snapshot to host synchronously, serialize asynchronously."""
        self.wait()  # one in-flight save at a time
        # np.array copies EVERY leaf (device_get yields numpy, sometimes
        # aliasing donated buffers; the banked optimizer's host store is
        # mutated in place by later train steps) — the async writer must
        # own a consistent snapshot, so do not optimize the copy away.
        # Sharded jax.Arrays gather to full shape here (gather-on-save).
        host_state = jax.tree.map(np.array, jax.device_get(state))
        meta = {"step": int(step), "time": time.time(),
                "process_count": jax.process_count(), **(extra_meta or {})}

        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_state, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host_state, meta)

    def _write(self, step: int, host_state, meta):
        try:
            tmp = os.path.join(self.dir, f"tmp.{step}.{os.getpid()}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            proc = jax.process_index()
            np.savez(os.path.join(tmp, f"proc_{proc}.npz"), **_flatten(host_state))
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_meta(self, step: int | None = None) -> dict:
        """The meta.json saved next to a step's shards (save()'s
        ``extra_meta`` lands here — e.g. the trainer's data-pipeline cursor,
        which must resume alongside the TrainState)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}", "meta.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, target, step: int | None = None, shardings=None):
        """``target``: pytree of arrays or ShapeDtypeStructs defining the
        structure/shapes. ``shardings``: optional matching pytree — this is
        where elastic resharding happens (device_put onto the new mesh).
        Entries that are not ``jax.sharding.Sharding`` instances (e.g. the
        trainer's HOST_RESIDENT markers for the banked slot_map / host
        store) leave the restored leaf as numpy in host RAM. Sharded leaves
        were gathered to full shape at save time (``jax.device_get``), so a
        restore may land on any device count — including re-sharding a
        ZeRO-1 store onto a different dp degree."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        flat: dict[str, np.ndarray] = {}
        for name in sorted(os.listdir(path)):
            if name.endswith(".npz"):
                with np.load(os.path.join(path, name)) as z:
                    flat.update({k: z[k] for k in z.files})
        state = _unflatten_into(target, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s)
                if isinstance(s, jax.sharding.Sharding) else x,
                state, shardings)
        return state, step
