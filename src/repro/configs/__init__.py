"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    MULTI_POD,
    SHAPES,
    SINGLE_POD,
    TINY_MESH,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    SelectConfig,
    ShapeConfig,
    TrainConfig,
)

# arch id -> module name
_ARCH_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "chatglm3-6b": "chatglm3_6b",
    "yi-9b": "yi_9b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen2.5-32b": "qwen2_5_32b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-2.7b": "mamba2_2_7b",
    "paligemma-3b": "paligemma_3b",
    # paper's own models
    "qwen2.5-0.5b": "qwen2_5_0_5b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
}

ASSIGNED_ARCHS = tuple(list(_ARCH_MODULES)[:10])
PAPER_ARCHS = ("qwen2.5-0.5b", "llama3.2-1b", "phi4-mini-3.8b")
ALL_ARCHS = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.SMOKE


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def shapes_for(cfg: ModelConfig) -> list[ShapeConfig]:
    """The shape cells that apply to this arch (long_500k only for
    sub-quadratic prefill families and decode-against-long-KV families;
    see DESIGN.md section 6)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.family in ("ssm", "hybrid"):
        out.append(SHAPES["long_500k"])
    return out
