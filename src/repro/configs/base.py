"""Config dataclasses for models, shapes, selection, training, and meshes.

Every assigned architecture is expressed as a ``ModelConfig``; the config
system is plain frozen dataclasses (hashable, so they can be closed over by
jitted functions as static structure).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """One config type covering every family in the assigned pool.

    family:
      dense   -- decoder-only transformer (GQA / MHA attention)
      moe     -- decoder-only with mixture-of-experts FFN (optionally MLA attention)
      ssm     -- attention-free Mamba2 (SSD) stack
      hybrid  -- Mamba2 backbone with a single *shared* attention block applied
                 every ``shared_attn_period`` layers (zamba2-style)
      encdec  -- encoder-decoder (seamless-m4t style; frontend stubbed)
      vlm     -- decoder-only backbone consuming a stub vision-patch prefix
    """

    name: str = "unnamed"
    family: str = "dense"
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0            # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    tie_embeddings: bool = False

    # --- attention variants ---
    attn_bias: bool = False               # qwen-style QKV bias
    rope_theta: float = 10000.0
    partial_rotary_factor: float = 1.0    # chatglm "2d rope" = 0.5
    attn_logit_softcap: float = 0.0       # gemma-style softcap (0 = off)

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert intermediate width
    first_k_dense: int = 0       # deepseek: first k layers use dense FFN
    moe_impl: str = "dense"      # "dense" (oracle; all-experts weighted) | "ep" (shard_map all-to-all)
    ep_axes: tuple = ("model",)  # mesh axes the expert dim shards over
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001
    mtp_depth: int = 0           # deepseek multi-token-prediction extra blocks
    mtp_loss_weight: float = 0.3

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0  # apply shared attn block after every N ssm layers

    # --- encoder-decoder ---
    num_encoder_layers: int = 0
    frontend_len_ratio: int = 1  # src_len = seq_len // ratio (audio frame downsampling)

    # --- frontend stubs (audio / vision) ---
    frontend: str = ""           # "" | "audio" | "vision"
    num_frontend_tokens: int = 0  # vlm: number of patch-embedding prefix tokens

    # --- TP-alignment padding (exactness-preserving; see models/lm.py) ---
    pad_heads_to: int = 0        # pad q-heads to this count (zero-masked)
    pad_vocab_multiple: int = 1  # pad embed/head rows (logit-bias masked)

    # --- numerics / structure ---
    norm_eps: float = 1e-5
    act: str = "silu"
    dtype: str = "bfloat16"
    remat: str = "full"          # "none" | "full" | "dots"
    logits_softcap: float = 0.0
    use_pallas: str = "auto"     # "auto" | "never" | "always"
    seq_shard_kv: bool = False   # constrain k/v activations S-sharded over
                                 # "model" (stops GSPMD split-contraction
                                 # all-reduces; see EXPERIMENTS.md Perf)
    gate_weight_grads: bool = False  # lax.cond-gated dW for frozen blocks (DESIGN 3.3)
    scan_layers: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def quadratic_attention(self) -> bool:
        """True if *prefill/train* cost is quadratic in sequence length and
        there is no sub-quadratic path (used to skip long_500k)."""
        return self.family in ("dense", "encdec", "vlm", "moe")

    @property
    def padded_vocab_size(self) -> int:
        m = max(1, self.pad_vocab_multiple)
        return -(-self.vocab_size // m) * m

    @property
    def padded_heads(self) -> int:
        return max(self.num_heads, self.pad_heads_to)

    @property
    def num_blocks(self) -> int:
        """Paper's block count: embed + transformer blocks + final norm
        (+ shared attn block for hybrids, + encoder blocks for encdec,
        + untied lm head counted with final norm, + MTP blocks)."""
        n = self.num_layers + 2
        if self.family == "hybrid" and self.shared_attn_period:
            n += 1
        if self.family == "encdec":
            n += self.num_encoder_layers + 1   # + enc_norm
        if not self.tie_embeddings:
            n += 1                              # untied lm head
        n += self.mtp_depth
        return n

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: what gets lowered in the dry-run."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class SelectConfig:
    """Selection-policy hyper-parameters (paper §3.2 + baseline policies).

    ``policy`` names an entry in the core/adagradselect.py policy registry
    ("adagradselect" | "topk_grad" | "random" | "all" | "lisa" | "grass" |
    any runtime-registered policy — validated at lookup, not here)."""

    policy: str = "adagradselect"
    k_percent: float = 20.0        # percentage of blocks updated per step
    epsilon0: float = 1.0          # initial exploration rate
    epsilon_decay: float = 0.01    # lambda in eps_t = eps0 * exp(-lambda * t)
    dirichlet_delta: float = 1.0   # smoothing constant delta (alpha = f + delta)
    steps_per_epoch: int = 1000    # after this, epoch>=2 -> pure exploitation
    always_include: tuple = ()     # block indices always selected (e.g. embed)
    lisa_interval: int = 20        # "lisa": steps between mask resamples
    grass_temperature: float = 1.0  # "grass": sampling ∝ cum_norms^T

    def __post_init__(self):
        if not 0.0 < self.k_percent <= 100.0:
            raise ValueError(f"k_percent must be in (0, 100], got "
                             f"{self.k_percent}")
        if self.epsilon0 < 0.0 or self.epsilon_decay < 0.0:
            raise ValueError("epsilon0/epsilon_decay must be >= 0")
        if self.dirichlet_delta <= 0.0:
            raise ValueError("dirichlet_delta must be > 0")
        if self.steps_per_epoch < 1:
            raise ValueError("steps_per_epoch must be >= 1")
        if self.lisa_interval < 1:
            raise ValueError("lisa_interval must be >= 1")
        if self.grass_temperature < 0.0:
            raise ValueError("grass_temperature must be >= 0")

    def num_selected(self, num_blocks: int) -> int:
        # paper guideline: min% >= 100/B  => at least one block per step
        return max(1, int(round(num_blocks * self.k_percent / 100.0)))


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 2e-5
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 20
    schedule: str = "cosine"       # "constant" | "cosine" | "linear"
    total_steps: int = 1000
    # paper 3.3 adaptation: where do AdamW moments live?
    #   moment_residency "device": full m/v for every parameter stay on the
    #     accelerator (dense masked-AdamW, the trajectory oracle); ``offload``
    #     then shards/places those dense moments ("zero1" / "host" memory
    #     kinds / "none").
    #   moment_residency "banked": only selected blocks' moments are device-
    #     resident, in compact [k]-slot banks; ``offload`` governs the full
    #     backing store instead ("host" -> host RAM, streamed at selection
    #     changes; "none" -> replicated device store; "zero1" -> device
    #     store sharded 1/dp over the mesh's data axis — requires a mesh).
    moment_residency: str = "device"  # "device" | "banked"
    offload: str = "none"          # "none" | "host" | "zero1"
    # banked only: overlap the selection-change boundary with compute — a
    # background thread prefetches the policy's *predicted* next admit set
    # and writes predicted evictions back while phase B runs; mispredicts
    # fall back to the synchronous swap (bit-identical either way).
    async_swap: bool = True
    moment_dtype: str = "float32"  # "float32" | "bfloat16" (halves m/v HBM)
    accum_dtype: str = "float32"   # microbatch grad-accumulation buffer
    # LoRA baseline
    lora_rank: int = 0
    lora_alpha: float = 16.0
    # distributed-optimization knobs
    grad_compression: str = "none"  # "none" | "bf16"
    microbatch: int = 0             # >0 -> gradient accumulation over microbatches


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple = (16, 16)
    axes: tuple = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def batch_axes(self) -> tuple:
        return tuple(a for a in self.axes if a in ("pod", "data"))


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))
TINY_MESH = MeshConfig((2, 4), ("data", "model"))  # subprocess tests


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = field(default_factory=ModelConfig)
    select: SelectConfig = field(default_factory=SelectConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    # fine-tuning method: an entry in the repro.methods registry ("full",
    # "adagradselect", "topk_grad", "random", "lora", "lisa", "grass", ...).
    # Validated at Trainer construction against the runtime registry so
    # externally registered methods work too.
    method: str = "adagradselect"
    seq_len: int = 512
    global_batch: int = 8
    steps: int = 100
    seed: int = 0
    log_every: int = 10
    eval_every: int = 0
    checkpoint_every: int = 0
    checkpoint_dir: str = ""
    checkpoint_keep: int = 3
    straggler_tau: float = 3.0     # abort threshold: step_time > tau * EWMA
