"""chatglm3-6b: dense decoder, GQA kv=2, 2d (partial) RoPE. [arXiv:2406.12793]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    partial_rotary_factor=0.5,   # "RoPE 2d": rotary on half the head dim
    rope_theta=10000.0,
    attn_bias=True,              # chatglm uses QKV bias,
    pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
