"""deepseek-v3-671b: MLA attention + MoE (1 shared + 256 routed, top-8) + MTP.
[arXiv:2412.19437]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: KV latent shared; head count for attention math
    d_ff=18432,              # dense-FFN width (first_k_dense layers)
    vocab_size=129280,
    # MLA
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    # MoE
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=3,
    moe_impl="ep",
    ep_axes=("model", "data"),   # 256 experts over 256 chips (1 expert/chip)
    # MTP
    mtp_depth=1,
    rope_theta=10000.0,
    pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=160, vocab_size=256,
    q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
    qk_rope_head_dim=8, v_head_dim=16, head_dim=24,
    num_experts=8, num_experts_per_tok=2, moe_d_ff=32, first_k_dense=1,
    moe_impl="dense", mtp_depth=1, dtype="float32",
)
