"""llama3.2-1b: small llama3, GQA kv=8, tied embeddings.
[hf:meta-llama/Llama-3.2-1B] -- also one of the paper's own eval models."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500000.0,
    pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
