"""mamba2-2.7b: attention-free SSD (state-space duality) stack. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,             # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_ngroups=1,
    tie_embeddings=True,
    pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=4, d_model=64, vocab_size=256, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=32, dtype="float32",
)
