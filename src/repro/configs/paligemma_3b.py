"""paligemma-3b: gemma decoder backbone + SigLIP vision frontend (STUB).
[arXiv:2407.07726]

``input_specs`` provides precomputed patch embeddings (num_frontend_tokens x
d_model) as the image prefix; only the gemma backbone is implemented.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    frontend="vision",
    num_frontend_tokens=256,
    tie_embeddings=True,
    act="gelu",
    rope_theta=10000.0,
    pad_heads_to=16, pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256, num_frontend_tokens=8, dtype="float32",
)
