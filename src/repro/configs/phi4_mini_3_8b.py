"""phi4-mini-3.8b: the paper's largest eval model. [hf:microsoft/Phi-4-mini-instruct]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    tie_embeddings=True,
    rope_theta=10000.0,
    pad_heads_to=32, pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
