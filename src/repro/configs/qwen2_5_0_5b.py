"""qwen2.5-0.5b: the paper's primary SLM (25-block count incl. embed/norm).
[hf:Qwen/Qwen2.5-0.5B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    attn_bias=True,
    tie_embeddings=True,
    rope_theta=1000000.0,
    pad_heads_to=16, pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
