"""qwen2.5-32b: dense decoder, GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-32B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab_size=152064,
    attn_bias=True,
    rope_theta=1000000.0,
    pad_heads_to=48, pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
