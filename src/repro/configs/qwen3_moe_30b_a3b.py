"""qwen3-moe-30b-a3b: 128 experts, top-8, GQA kv=4. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=6144,               # dense width unused (first_k_dense=0); kept for reference
    vocab_size=151936,
    num_experts=128,
    num_experts_per_tok=8,
    moe_d_ff=768,
    first_k_dense=0,
    moe_impl="ep",
    rope_theta=1000000.0,
    pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, num_experts=8, num_experts_per_tok=2,
    moe_d_ff=32, moe_impl="dense", dtype="float32",
)
