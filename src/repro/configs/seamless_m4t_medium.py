"""seamless-m4t-medium: encoder-decoder multimodal backbone. [arXiv:2308.11596]

The audio frontend (w2v-BERT feature extractor) is a STUB: ``input_specs``
provides precomputed frame embeddings of length seq_len // frontend_len_ratio.
Only the transformer backbone is implemented/selected, per the assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,           # decoder layers
    num_encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    frontend_len_ratio=4,    # src frames = seq_len // 4
    act="gelu",
    rope_theta=10000.0,
    pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=2, num_encoder_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, dtype="float32",
)
