"""yi-9b: llama-architecture dense decoder, GQA kv=4. [arXiv:2403.04652]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=10000.0,
    pad_vocab_multiple=16
)

SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32",
)
