"""zamba2-7b: Mamba2 backbone + shared attention block. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    shared_attn_period=6,   # shared attn+mlp block applied after every 6 mamba layers
    rope_theta=10000.0,
    act="gelu",
    pad_vocab_multiple=16
)

# Reduced config for CPU smoke tests (same family / same code paths).
SMOKE = CONFIG.replace(
    pad_heads_to=0, pad_vocab_multiple=1,
    num_layers=7, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
    shared_attn_period=3, dtype="float32",
)
