"""AdaGradSelect core: block partitioning, selection policies, masked AdamW,
optimizer-state residency (the paper's primary contribution)."""
from repro.core.adagradselect import (  # noqa: F401
    SelectionPolicy,
    available_policies,
    get_policy,
    init_state,
    register_policy,
    select,
)
from repro.core.partition import (  # noqa: F401
    BlockPartition,
    block_grad_norms,
    build_partition,
    layer_masks_dict,
    leaf_masks,
    params_per_block,
)
