"""Layer-selection controller: a registry of ``SelectionPolicy`` objects.

The paper's Algorithm 2 (the ``adagradselect`` policy) is one entry in a
string-keyed policy registry; the baselines it is compared against
(``topk_grad`` = Alg. 1, ``random``, ``all`` = full FT) and the beyond-paper
policies (``lisa`` = interval-resampled random layers, ``grass`` =
gradient-norm importance sampling) are sibling entries. Each policy declares
its own state pytree (``extra_state``) on top of four common fields —

    {"step": i32, "key": PRNGKey, "mask": bool[num_blocks],
     "indices": i32[k]}

``indices`` is the static-shape selected-block-id vector alongside the
boolean mask (ascending ids, padded with ``num_blocks``) — the contract the
banked optimizer state indexes through (see ``selected_indices``).

so e.g. only ``adagradselect`` carries ``freq`` (Dirichlet posterior counts)
and only the cumulative-signal policies carry ``cum_norms``. The whole
controller runs inside the compiled train step: masks are runtime vectors,
never recompile triggers.

Selection is deterministic given (seed, step): the PRNG key is folded with
the step counter, so replicas/restarts reproduce the same arm sequence. The
named sub-keys ("eps", "dir", "gum", "rnd") are split in a fixed order to
keep trajectories reproducible across policy additions.

Adding a policy: subclass ``SelectionPolicy``, decorate with
``@register_policy("name")``, declare ``extra_state`` if it is stateful —
the train step, trainer, and method registry pick it up untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SelectConfig
from repro.core import selection

# --------------------------------------------------------------- registry

_POLICIES: dict[str, "SelectionPolicy"] = {}


def register_policy(name: str):
    """Class decorator: instantiate and register a SelectionPolicy."""
    def deco(cls):
        cls.name = name
        _POLICIES[name] = cls()
        return cls
    return deco


def get_policy(name: str) -> "SelectionPolicy":
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown selection policy {name!r}; "
                         f"available: {available_policies()}") from None


def available_policies() -> tuple:
    return tuple(sorted(_POLICIES))


class SelectionPolicy:
    """One mask-proposal rule. Policies are stateless singletons; all
    trajectory state lives in the (per-policy) state pytree."""

    name = "base"

    def extra_state(self, num_blocks: int) -> dict:
        """Policy-specific state fields (beyond step/key/mask)."""
        return {}

    def propose(self, cfg: SelectConfig, state: dict, keys: dict,
                block_norms: jax.Array, k: int, num_blocks: int) -> jax.Array:
        """-> bool mask [num_blocks] with exactly k True entries."""
        raise NotImplementedError

    def update(self, cfg: SelectConfig, state: dict, mask: jax.Array,
               block_norms: jax.Array) -> dict:
        """New values for this policy's ``extra_state`` fields."""
        return {}

    def observe(self, cfg: SelectConfig, state: dict,
                block_norms: jax.Array) -> dict:
        """Post-hoc norm observation (gate mode: the mask was decided before
        backward, so cumulative signals are fed after the fact)."""
        if "cum_norms" in state:
            return {**state, "cum_norms": state["cum_norms"] + block_norms}
        return state

    def predict_next(self, cfg: SelectConfig, state: dict, keys: dict,
                     num_blocks: int, k: int) -> jax.Array:
        """Predicted mask for the NEXT ``select`` call, computed from the
        post-select state only (the next step's gradient norms are unknown).

        Default: re-run ``propose`` with zero instantaneous norms — exact
        for every policy whose rule does not read this step's norms
        (``random``, ``lisa``, ``all``: the PRNG keys are deterministic in
        (key, step)), and the cumulative-signal approximation for
        ``adagradselect``/``grass`` (their ``cum_norms`` dominates a single
        step's norms, which is exactly the slow selection drift BlockLLM
        exploits). Stays pure: never mutates ``state``."""
        zeros = jnp.zeros((num_blocks,), jnp.float32)
        return self.propose(cfg, state, keys, zeros, k, num_blocks)


@register_policy("all")
class FullPolicy(SelectionPolicy):
    """Every block, every step — full fine-tuning."""

    def propose(self, cfg, state, keys, block_norms, k, num_blocks):
        return jnp.ones((num_blocks,), jnp.bool_)


@register_policy("random")
class RandomPolicy(SelectionPolicy):
    """Uniform k-subset, redrawn every step."""

    def propose(self, cfg, state, keys, block_norms, k, num_blocks):
        return selection.random_mask(keys["rnd"], num_blocks, k)


@register_policy("topk_grad")
class TopKGradPolicy(SelectionPolicy):
    """Paper Alg. 1: rank by this step's instantaneous gradient norms."""

    def propose(self, cfg, state, keys, block_norms, k, num_blocks):
        return selection.topk_mask(block_norms, k)

    def predict_next(self, cfg, state, keys, num_blocks, k):
        # rank-by-instantaneous-norms has no state to predict from; the best
        # guess is that selection drifts slowly (BlockLLM's observation):
        # predict the current mask verbatim.
        return state["mask"]


@register_policy("adagradselect")
class AdaGradSelectPolicy(SelectionPolicy):
    """Paper Alg. 2: eps-greedy exploration over the cumulative-norm top-k,
    Dirichlet(freq + delta) exploitation via Gumbel-top-k sampling."""

    def extra_state(self, num_blocks):
        return {"freq": jnp.zeros((num_blocks,), jnp.float32),
                "cum_norms": jnp.zeros((num_blocks,), jnp.float32)}

    def propose(self, cfg, state, keys, block_norms, k, num_blocks):
        signal = state["cum_norms"] + block_norms  # cumulative (§3.2)
        explore_mask = selection.topk_mask(signal, k)
        probs = selection.dirichlet_probs(keys["dir"], state["freq"],
                                          cfg.dirichlet_delta)
        exploit_mask = selection.sample_without_replacement(keys["gum"], probs, k)
        eps = epsilon(cfg, state["step"])
        do_explore = jax.random.uniform(keys["eps"]) < eps
        return jnp.where(do_explore, explore_mask, exploit_mask)

    def update(self, cfg, state, mask, block_norms):
        return {"freq": state["freq"] + mask.astype(jnp.float32),
                "cum_norms": state["cum_norms"] + block_norms}


@register_policy("lisa")
class LisaPolicy(SelectionPolicy):
    """LISA-style: a uniform-random k-subset held fixed for
    ``cfg.lisa_interval`` steps, then resampled (arXiv:2403.17919 idiom)."""

    def propose(self, cfg, state, keys, block_norms, k, num_blocks):
        fresh = selection.random_mask(keys["rnd"], num_blocks, k)
        resample = (state["step"] % cfg.lisa_interval) == 0
        return jnp.where(resample, fresh, state["mask"])


@register_policy("grass")
class GrassPolicy(SelectionPolicy):
    """GRASS-style importance sampling: draw k blocks without replacement
    with probability proportional to the cumulative gradient-norm signal
    raised to ``cfg.grass_temperature`` (0 = uniform, 1 = proportional,
    large = greedy top-k)."""

    def extra_state(self, num_blocks):
        return {"cum_norms": jnp.zeros((num_blocks,), jnp.float32)}

    def propose(self, cfg, state, keys, block_norms, k, num_blocks):
        signal = state["cum_norms"] + block_norms
        w = jnp.power(signal + 1e-12, cfg.grass_temperature)
        probs = w / jnp.maximum(jnp.sum(w), 1e-20)
        return selection.sample_without_replacement(keys["gum"], probs, k)

    def update(self, cfg, state, mask, block_norms):
        return {"cum_norms": state["cum_norms"] + block_norms}


# ------------------------------------------------------------- controller


def selected_indices(mask: jax.Array, k: int) -> jax.Array:
    """Static-shape [k] i32 vector of selected block ids (ascending), padded
    with ``num_blocks`` when fewer than k blocks are selected. This is the
    runtime-vector contract the banked optimizer state gathers/scatters
    through: k is static, the ids are data — selection changes never
    recompile."""
    n = mask.shape[0]
    ids = jnp.where(mask, jnp.arange(n, dtype=jnp.int32), n)
    return jnp.sort(ids)[:k]


def init_state(num_blocks: int, seed: int = 0,
               policy: str = "adagradselect", k: int | None = None) -> dict:
    """Per-policy state pytree: common fields + the policy's extras.
    ``k`` fixes the static length of the ``indices`` vector (the number of
    bank slots in banked-residency mode); default: ``num_blocks``."""
    k = num_blocks if k is None else min(k, num_blocks)
    mask0 = jnp.ones((num_blocks,), jnp.bool_)  # step-0 default: all
    return {
        "step": jnp.zeros((), jnp.int32),
        "key": jax.random.PRNGKey(seed),
        "mask": mask0,
        "indices": selected_indices(mask0, k),
        **get_policy(policy).extra_state(num_blocks),
    }


def epsilon(cfg: SelectConfig, step) -> jax.Array:
    """eps_t = eps0 * exp(-lambda * t), zeroed from epoch 2 on."""
    t = step.astype(jnp.float32)
    eps = cfg.epsilon0 * jnp.exp(-cfg.epsilon_decay * t)
    return jnp.where(step < cfg.steps_per_epoch, eps, 0.0)


def select(cfg: SelectConfig, state: dict, block_norms: jax.Array,
           num_blocks: int) -> tuple[jax.Array, dict]:
    """One selection iteration. ``block_norms``: this step's per-block
    gradient L2 norms [num_blocks]. Returns (mask [num_blocks] bool, new
    state). Dispatches on ``cfg.policy`` through the registry."""
    pol = get_policy(cfg.policy)
    k = cfg.num_selected(num_blocks)
    key = jax.random.fold_in(state["key"], state["step"])
    k_eps, k_dir, k_gum, k_rnd = jax.random.split(key, 4)
    keys = {"eps": k_eps, "dir": k_dir, "gum": k_gum, "rnd": k_rnd}

    mask = pol.propose(cfg, state, keys, block_norms, k, num_blocks)
    mask = selection.apply_always_include(mask, cfg.always_include)
    new_state = {
        **state,
        **pol.update(cfg, state, mask, block_norms),
        "step": state["step"] + 1,
        "mask": mask,
    }
    if "indices" in state:  # static-shape selected-id vector alongside mask
        new_state["indices"] = selected_indices(mask,
                                                state["indices"].shape[0])
    return mask, new_state


def observe(cfg: SelectConfig, state: dict, block_norms: jax.Array) -> dict:
    """Feed post-backward norms to the policy without selecting (gate mode)."""
    return get_policy(cfg.policy).observe(cfg, state, block_norms)


def predict_next(cfg: SelectConfig, state: dict,
                 num_blocks: int) -> jax.Array:
    """Predicted NEXT selection as a static-shape ``[k]`` indices vector
    (same contract as ``state["indices"]``: ascending block ids padded with
    ``num_blocks``), derived from the post-``select`` state alone.

    The PRNG keys are folded exactly as the next ``select`` call will fold
    them (``state["step"]`` was already incremented), so any policy whose
    rule ignores the next step's gradient norms is predicted *exactly*;
    norm-dependent policies get their cumulative-signal approximation (see
    ``SelectionPolicy.predict_next``). Deterministic and pure in ``state`` —
    the async swap planner prefetches the predicted admit set through this,
    and a misprediction merely falls back to the synchronous swap."""
    pol = get_policy(cfg.policy)
    k = cfg.num_selected(num_blocks)
    key = jax.random.fold_in(state["key"], state["step"])
    k_eps, k_dir, k_gum, k_rnd = jax.random.split(key, 4)
    keys = {"eps": k_eps, "dir": k_dir, "gum": k_gum, "rnd": k_rnd}
    mask = pol.predict_next(cfg, state, keys, num_blocks, k)
    mask = selection.apply_always_include(mask, cfg.always_include)
    cap = state["indices"].shape[0] if "indices" in state else num_blocks
    return selected_indices(mask, cap)
