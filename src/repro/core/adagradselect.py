"""AdaGradSelect controller — the paper's Algorithm 2, fully in-jit.

State (replicated, tiny) and transition:

  epoch 1 (step < steps_per_epoch), with prob eps_t = eps0 * exp(-lambda t):
      EXPLORATION  — top-k% blocks by gradient-norm signal (cumulative by
                     default, per §3.2; "instant" reproduces Alg. 1 ranking)
  otherwise, and always from epoch 2 on:
      EXPLOITATION — p ~ Dirichlet(freq + delta); draw k% blocks without
                     replacement ∝ p (Gumbel-top-k)

  freq[b] += 1 for every selected block, every step (exploration included),
  so early exploration shapes the later Dirichlet exploitation.

Selection is deterministic given (seed, step): the PRNG key is folded with
the step counter, so replicas/restarts reproduce the same arm sequence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SelectConfig
from repro.core import selection


def init_state(num_blocks: int, seed: int = 0) -> dict:
    return {
        "freq": jnp.zeros((num_blocks,), jnp.float32),
        "cum_norms": jnp.zeros((num_blocks,), jnp.float32),
        "step": jnp.zeros((), jnp.int32),
        "key": jax.random.PRNGKey(seed),
        "mask": jnp.ones((num_blocks,), jnp.bool_),  # step-0 default: all
    }


def epsilon(cfg: SelectConfig, step) -> jax.Array:
    """eps_t = eps0 * exp(-lambda * t), zeroed from epoch 2 on."""
    t = step.astype(jnp.float32)
    eps = cfg.epsilon0 * jnp.exp(-cfg.epsilon_decay * t)
    return jnp.where(step < cfg.steps_per_epoch, eps, 0.0)


def select(cfg: SelectConfig, state: dict, block_norms: jax.Array,
           num_blocks: int) -> tuple[jax.Array, dict]:
    """One Alg. 2 iteration. ``block_norms``: this step's per-block gradient
    L2 norms [num_blocks]. Returns (mask [num_blocks] bool, new state)."""
    k = cfg.num_selected(num_blocks)
    cum = state["cum_norms"] + block_norms
    key = jax.random.fold_in(state["key"], state["step"])
    k_eps, k_dir, k_gum, k_rnd = jax.random.split(key, 4)

    if cfg.policy == "all":
        mask = jnp.ones((num_blocks,), jnp.bool_)
    elif cfg.policy == "random":
        mask = selection.random_mask(k_rnd, num_blocks, k)
    elif cfg.policy == "topk_grad":
        # Alg. 1: rank by this step's gradient norms
        mask = selection.topk_mask(block_norms, k)
    elif cfg.policy == "adagradselect":
        signal = cum  # cumulative gradient norms (§3.2)
        explore_mask = selection.topk_mask(signal, k)
        probs = selection.dirichlet_probs(k_dir, state["freq"], cfg.dirichlet_delta)
        exploit_mask = selection.sample_without_replacement(k_gum, probs, k)
        eps = epsilon(cfg, state["step"])
        do_explore = jax.random.uniform(k_eps) < eps
        mask = jnp.where(do_explore, explore_mask, exploit_mask)
    else:
        raise ValueError(f"unknown selection policy {cfg.policy!r}")

    mask = selection.apply_always_include(mask, cfg.always_include)
    new_state = {
        "freq": state["freq"] + mask.astype(jnp.float32),
        "cum_norms": cum,
        "step": state["step"] + 1,
        "key": state["key"],
        "mask": mask,
    }
    return mask, new_state
