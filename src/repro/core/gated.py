"""Gated weight-gradient computation for frozen blocks (DESIGN.md 3.3).

In eager PyTorch, ``requires_grad=False`` skips dW kernels for frozen blocks.
Under jit the graph is static, so we gate the parameter-cotangent computation
with ``lax.cond`` on the (runtime) selection mask instead: the activation
gradient is always computed (the chain rule needs it to reach earlier
selected blocks), while the ~1/3 of backward FLOPs that produce dW are
skipped at runtime for unselected blocks — lax.cond lowers to real control
flow on TPU.

The forward is rematerialized inside each cotangent branch (jax.vjp closes
over a fresh forward), so this mode implies block-level remat; that matches
the framework default (cfg.remat="full").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_block_apply(apply_fn, params, x, mask_bit):
    """apply_fn(params, x) -> (y, aux). mask_bit: scalar (bool/0-1) — True
    means the block is selected this step and needs dW."""

    @jax.custom_vjp
    def f(params, x, mask_bit):
        return apply_fn(params, x)

    def fwd(params, x, mask_bit):
        y, aux = apply_fn(params, x)
        return (y, aux), (params, x, mask_bit)

    def bwd(res, cts):
        params, x, mask_bit = res
        g_y, g_aux = cts

        # activation cotangent: always needed
        def fx(xx):
            return apply_fn(params, xx)

        _, vjp_x = jax.vjp(fx, x)
        (dx,) = vjp_x((g_y, g_aux))

        def dparams_real(_):
            def fp(pp):
                return apply_fn(pp, x)

            _, vjp_p = jax.vjp(fp, params)
            return vjp_p((g_y, g_aux))[0]

        def dparams_zero(_):
            return jax.tree.map(jnp.zeros_like, params)

        dparams = jax.lax.cond(
            jnp.asarray(mask_bit, jnp.bool_), dparams_real, dparams_zero, None)
        return dparams, dx, None

    f.defvjp(fwd, bwd)
    return f(params, x, mask_bit)
