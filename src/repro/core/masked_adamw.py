"""Block-masked AdamW — the paper's "custom AdamW" (Alg. 1 lines 9-13).

Selected blocks take a standard AdamW step (moments + weight decay);
unselected blocks keep parameters AND moments bit-identical. Bias
correction uses *per-block* step counts (an intermittently-updated block's
Adam timescale is its own update count, not the global step) — with
mask == all-ones this reduces exactly to standard AdamW, which the
equivalence test asserts.

Moments are float32 regardless of param dtype. The fused Pallas kernel
(kernels/masked_adamw.py) implements the same update for the TPU path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig
from repro.core.partition import BlockPartition, leaf_masks


def init_opt_state(partition: BlockPartition, params: dict,
                   moment_dtype=jnp.float32) -> dict:
    zeros = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, moment_dtype), p)
    return {
        "m": zeros(params),
        "v": zeros(params),
        "counts": jnp.zeros((partition.num_blocks,), jnp.float32),
    }


def global_grad_norm(grads) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_grad_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def update(cfg: OptimizerConfig, partition: BlockPartition, params: dict,
           grads: dict, opt_state: dict, mask, lr, use_pallas: bool = False):
    """One masked step. mask: [num_blocks]; lr: scalar (schedule applied by
    the caller). Returns (new_params, new_opt_state)."""
    counts = opt_state["counts"] + mask.astype(jnp.float32)
    masks = leaf_masks(partition, params, mask)
    counts_b = leaf_masks(partition, params, counts)  # per-leaf broadcast

    if use_pallas:
        from repro.kernels import ops as kops

    def upd(p, g, m, v, sel, cnt):
        if use_pallas and p.ndim >= 2:
            return kops.masked_adamw(p, g, m, v, sel, cnt, lr, cfg.b1, cfg.b2,
                                     cfg.eps, cfg.weight_decay)
        mdt = m.dtype
        gf = g.astype(jnp.float32)
        m, v = m.astype(jnp.float32), v.astype(jnp.float32)
        m2 = jnp.where(sel > 0, cfg.b1 * m + (1 - cfg.b1) * gf, m)
        v2 = jnp.where(sel > 0, cfg.b2 * v + (1 - cfg.b2) * gf * gf, v)
        c = jnp.maximum(cnt, 1.0)
        mhat = m2 / (1 - cfg.b1 ** c)
        vhat = v2 / (1 - cfg.b2 ** c)
        pf = p.astype(jnp.float32)
        step = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        p2 = jnp.where(sel > 0, pf - step, pf)
        return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"],
                        masks, counts_b)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "counts": counts}
