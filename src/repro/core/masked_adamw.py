"""Block-masked AdamW — the paper's "custom AdamW" (Alg. 1 lines 9-13).

Selected blocks take a standard AdamW step (moments + weight decay);
unselected blocks keep parameters AND moments bit-identical. Bias
correction uses *per-block* step counts (an intermittently-updated block's
Adam timescale is its own update count, not the global step) — with
mask == all-ones this reduces exactly to standard AdamW, which the
equivalence test asserts.

Moments are float32 regardless of param dtype. The fused Pallas kernel
(kernels/masked_adamw.py) implements the same update for the TPU path.

Two residency layouts share the update arithmetic (row-for-row identical,
so the dense form stays the trajectory oracle):

* **dense** — ``init_opt_state`` / ``update``: full m/v pytrees congruent
  with params.
* **banked** (paper §3.3) — ``init_banked_opt_state`` / ``swap_banked`` /
  ``banked_update``: device-resident moments are compact [cap]-slot banks
  (one per partition group) backed by a full store (host RAM under
  ``offload == "host"``, see core/offload.py). ``swap_banked`` runs at
  selection-change boundaries outside jit: evicted blocks' rows stream back
  to the store, admitted blocks' rows stream in (zeros on first selection).
  Inside the compiled step every bank index is a runtime vector of static
  shape, so per-step selection never recompiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig
from repro.core import partition as part_mod
from repro.core.partition import BlockPartition, leaf_masks


def init_opt_state(partition: BlockPartition, params: dict,
                   moment_dtype=jnp.float32) -> dict:
    zeros = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, moment_dtype), p)
    return {
        "m": zeros(params),
        "v": zeros(params),
        "counts": jnp.zeros((partition.num_blocks,), jnp.float32),
    }


def global_grad_norm(grads) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_grad_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _adamw_rows(cfg: OptimizerConfig, p, g, m, v, sel, cnt, lr,
                pallas_ok: bool):
    """The masked-AdamW formula on one leaf (or gathered bank rows of one
    leaf). ``sel``/``cnt`` broadcast against ``p``. Shared by the dense and
    banked layouts so their arithmetic is identical op for op."""
    if pallas_ok:
        from repro.kernels import ops as kops
        return kops.masked_adamw(p, g, m, v, sel, cnt, lr, cfg.b1, cfg.b2,
                                 cfg.eps, cfg.weight_decay)
    mdt = m.dtype
    gf = g.astype(jnp.float32)
    m, v = m.astype(jnp.float32), v.astype(jnp.float32)
    m2 = jnp.where(sel > 0, cfg.b1 * m + (1 - cfg.b1) * gf, m)
    v2 = jnp.where(sel > 0, cfg.b2 * v + (1 - cfg.b2) * gf * gf, v)
    c = jnp.maximum(cnt, 1.0)
    mhat = m2 / (1 - cfg.b1 ** c)
    vhat = v2 / (1 - cfg.b2 ** c)
    pf = p.astype(jnp.float32)
    step = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
    p2 = jnp.where(sel > 0, pf - step, pf)
    return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)


def _unzip3(flat):
    is_t = lambda t: isinstance(t, tuple)  # noqa: E731
    return tuple(jax.tree.map(lambda t, i=i: t[i], flat, is_leaf=is_t)
                 for i in range(3))


def update(cfg: OptimizerConfig, partition: BlockPartition, params: dict,
           grads: dict, opt_state: dict, mask, lr, use_pallas: bool = False):
    """One masked step. mask: [num_blocks]; lr: scalar (schedule applied by
    the caller). Returns (new_params, new_opt_state)."""
    counts = opt_state["counts"] + mask.astype(jnp.float32)
    masks = leaf_masks(partition, params, mask)
    counts_b = leaf_masks(partition, params, counts)  # per-leaf broadcast

    def upd(p, g, m, v, sel, cnt):
        # Pallas needs a per-row [L, 1, ...] mask — unstacked leaves get a
        # scalar from leaf_masks, so they take the jnp path.
        pallas_ok = use_pallas and p.ndim >= 2 and sel.ndim == p.ndim
        return _adamw_rows(cfg, p, g, m, v, sel, cnt, lr, pallas_ok)

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"],
                        masks, counts_b)
    new_params, new_m, new_v = _unzip3(flat)
    return new_params, {"m": new_m, "v": new_v, "counts": counts}


# ---------------------------------------------------- banked residency (§3.3)


def bank_capacity(group, k_slots: int) -> int:
    """Device slots a stacked group needs: selection places at most
    ``k_slots`` blocks anywhere, and at most ``group.length`` of them here."""
    return max(1, min(group.length, k_slots))


def init_banked_opt_state(partition: BlockPartition, params: dict,
                          k_slots: int, moment_dtype=jnp.float32,
                          store_policy: str | None = "host",
                          mesh=None) -> dict:
    """Compact banked optimizer state:

      banks[key]  — per partition group: ``m``/``v`` pytrees with leading
                    axis ``cap = min(len, k_slots)`` (stacked groups) or
                    full leaf shape (unstacked, cap 1), plus ``slots``
                    [cap] i32 — the local block id each slot holds
                    (``group.length`` = free).
      slot_map    — [num_blocks] i32, block id -> slot in its group's bank
                    (-1 = host-resident only). Host-side numpy: it drives
                    ``swap_banked`` and never enters jit.
      counts      — per-block bias-correction step counts (unchanged from
                    the dense layout; tiny, always device-resident).
      store       — full-shape backing store (core/offload.init_full_store);
                    omitted when ``store_policy`` is None (eval_shape
                    projections of the device-resident footprint). Under
                    ``store_policy == "zero1"`` the store is device-resident
                    but sharded 1/dp over ``mesh``'s data axis.

    Nothing is resident initially; the first ``swap_banked`` admits the
    first selection with zero rows from the store (zero-init on first
    selection, matching ``init_opt_state``'s zeros).
    """
    from repro.core import offload
    banks = {}
    for g in partition.groups:
        sub = params[g.key]
        if g.stacked:
            cap = bank_capacity(g, k_slots)
            zeros = lambda x: jnp.zeros((cap,) + tuple(x.shape[1:]),  # noqa: E731
                                        moment_dtype)
        else:
            cap = 1
            zeros = lambda x: jnp.zeros(x.shape, moment_dtype)  # noqa: E731
        banks[g.key] = {
            "m": jax.tree.map(zeros, sub),
            "v": jax.tree.map(zeros, sub),
            "slots": jnp.full((cap,), g.length, jnp.int32),
        }
    opt = {
        "banks": banks,
        "slot_map": np.full((partition.num_blocks,), -1, np.int32),
        "counts": jnp.zeros((partition.num_blocks,), jnp.float32),
    }
    if store_policy is not None:
        opt["store"] = offload.init_full_store(partition, params,
                                               moment_dtype, store_policy,
                                               mesh=mesh)
    return opt


def swap_banked(partition: BlockPartition, banks: dict, store: dict,
                slot_map, mask):
    """Selection-change boundary (host side, outside jit): evicted blocks'
    bank rows stream back to the full store, admitted blocks' rows stream in
    (zero rows on first selection). Retained blocks keep their slots, so
    within an interval with an unchanged mask this is a no-op. ``mask``:
    host bool [num_blocks]. Returns (banks, slot_map, store) — host (numpy)
    store leaves are updated in place, device leaves functionally.
    """
    from repro.core import offload
    mask = np.asarray(mask).astype(bool)
    slot_map = np.array(slot_map, np.int32)  # fresh copy per boundary
    new_banks = dict(banks)
    new_store = dict(store)
    for g in partition.groups:
        lo = slice(g.start, g.start + g.length)
        gmask, gslots = mask[lo], slot_map[lo]
        resident = gslots >= 0
        ev_blocks = np.nonzero(resident & ~gmask)[0]
        ad_blocks = np.nonzero(gmask & ~resident)[0]
        if not len(ev_blocks) and not len(ad_blocks):
            continue
        bank = banks[g.key]
        slots_vec = np.array(bank["slots"], np.int32)
        cap = slots_vec.shape[0]
        ev_slots = gslots[ev_blocks]
        occupied = np.zeros((cap,), bool)
        occupied[gslots[np.nonzero(resident & gmask)[0]]] = True
        free = np.nonzero(~occupied)[0]
        if len(ad_blocks) > len(free):
            raise RuntimeError(
                f"bank overflow in group {g.key!r}: {len(ad_blocks)} "
                f"admissions for {len(free)} free slots (capacity {cap}); "
                f"the selection selected more blocks than the configured "
                f"slot capacity")
        ad_slots = free[:len(ad_blocks)]

        group_bank, group_store = {}, {}
        for mom in ("m", "v"):
            b_flat, b_def = jax.tree.flatten(bank[mom])
            s_flat, s_def = jax.tree.flatten(store[g.key][mom])
            out_b, out_s = [], []
            for bl, sl in zip(b_flat, s_flat):
                if g.stacked:
                    if len(ev_blocks):
                        rows = np.asarray(part_mod.gather_rows(bl, ev_slots))
                        sl = offload.store_write_rows(sl, ev_blocks, rows)
                    if len(ad_blocks):
                        rows = offload.store_read_rows(sl, ad_blocks)
                        new_bl = part_mod.scatter_rows(bl, ad_slots,
                                                       jnp.asarray(rows))
                        bl = offload._keep_sharding(new_bl, bl)
                else:  # the single block's moments are the whole leaf
                    if len(ev_blocks):
                        sl = offload.store_write_leaf(sl, np.asarray(bl))
                    if len(ad_blocks):
                        bl = offload._keep_sharding(
                            jnp.asarray(np.asarray(sl),
                                        dtype=np.asarray(bl).dtype), bl)
                out_b.append(bl)
                out_s.append(sl)
            group_bank[mom] = jax.tree.unflatten(b_def, out_b)
            group_store[mom] = jax.tree.unflatten(s_def, out_s)

        slots_vec[ev_slots] = g.length
        slots_vec[ad_slots] = ad_blocks
        slot_map[g.start + ev_blocks] = -1
        slot_map[g.start + ad_blocks] = ad_slots
        group_bank["slots"] = offload._keep_sharding(jnp.asarray(slots_vec),
                                                     bank["slots"])
        new_banks[g.key] = group_bank
        new_store[g.key] = group_store
    return new_banks, slot_map, new_store


def banked_update(cfg: OptimizerConfig, partition: BlockPartition,
                  params: dict, grads: dict, banks: dict, counts, mask, lr,
                  use_pallas: bool = False):
    """One masked AdamW step on the compact banks (jit-safe; every index is
    a runtime vector of static shape). Assumes residency == selection —
    ``swap_banked`` ran at the last selection change, so every masked
    block's moments sit in a bank row. The row arithmetic is
    ``_adamw_rows``, identical to the dense ``update``; given the same
    (grads, mask, lr) sequence the two layouts are trajectory-exact, which
    keeps the dense implementation as the oracle. Non-resident blocks'
    params (and their store moments) are untouched bit for bit.
    Returns (new_params, new_banks, new_counts)."""
    mask = jnp.asarray(mask)
    counts = jnp.asarray(counts) + mask.astype(jnp.float32)
    new_params, new_banks = {}, {}
    for g in partition.groups:
        bank = banks[g.key]
        slots = jnp.asarray(bank["slots"])
        if g.stacked:
            valid = slots < g.length
            gids = g.start + jnp.minimum(slots, g.length - 1)
            sel = jnp.where(valid, mask[gids].astype(jnp.float32), 0.0)
            cnt = counts[gids]

            def upd(p, gr, m, v):
                p_rows = part_mod.gather_rows(p, slots)
                g_rows = part_mod.gather_rows(gr, slots)
                shp = (sel.shape[0],) + (1,) * (p_rows.ndim - 1)
                pallas_ok = use_pallas and p_rows.ndim >= 2
                p2, m2, v2 = _adamw_rows(cfg, p_rows, g_rows, m, v,
                                         sel.reshape(shp), cnt.reshape(shp),
                                         lr, pallas_ok)
                # free-slot sentinels (slots == g.length) are dropped
                return part_mod.scatter_rows(p, slots, p2), m2, v2

            flat = jax.tree.map(upd, params[g.key], grads[g.key],
                                bank["m"], bank["v"])
        else:
            resident = slots[0] < g.length
            sel = jnp.where(resident, mask[g.start].astype(jnp.float32), 0.0)
            cnt = counts[g.start]

            def upd(p, gr, m, v):
                # scalar sel/cnt broadcast; no Pallas (kernel wants per-row
                # vectors — same rule as the dense path's unstacked leaves)
                return _adamw_rows(cfg, p, gr, m, v, sel, cnt, lr, False)

            flat = jax.tree.map(upd, params[g.key], grads[g.key],
                                bank["m"], bank["v"])
        p_new, m_new, v_new = _unzip3(flat)
        new_params[g.key] = p_new
        new_banks[g.key] = {"m": m_new, "v": v_new, "slots": slots}
    return new_params, new_banks, counts


def materialize_moments(partition: BlockPartition, opt: dict):
    """Full m/v pytrees reconstructed from banks + store (host sync; for
    tests, checkpoint inspection and reporting — training never needs the
    dense view). Returns (m, v) congruent with params."""
    out = {"m": {}, "v": {}}
    for g in partition.groups:
        bank = opt["banks"][g.key]
        slots = np.asarray(bank["slots"])
        for mom in ("m", "v"):
            def one(store_leaf, bank_leaf):
                full = np.array(store_leaf)
                if g.stacked:
                    valid = np.nonzero(slots < g.length)[0]
                    if len(valid):
                        full[slots[valid]] = np.asarray(bank_leaf)[valid]
                elif slots[0] == 0:
                    full[...] = np.asarray(bank_leaf)
                return full
            out[mom][g.key] = jax.tree.map(one, opt["store"][g.key][mom],
                                           bank[mom])
    return out["m"], out["v"]
