"""Block-masked AdamW — the paper's "custom AdamW" (Alg. 1 lines 9-13).

Selected blocks take a standard AdamW step (moments + weight decay);
unselected blocks keep parameters AND moments bit-identical. Bias
correction uses *per-block* step counts (an intermittently-updated block's
Adam timescale is its own update count, not the global step) — with
mask == all-ones this reduces exactly to standard AdamW, which the
equivalence test asserts.

Moments are float32 regardless of param dtype. The fused Pallas kernel
(kernels/masked_adamw.py) implements the same update for the TPU path.

Two residency layouts share the update arithmetic (row-for-row identical,
so the dense form stays the trajectory oracle):

* **dense** — ``init_opt_state`` / ``update``: full m/v pytrees congruent
  with params.
* **banked** (paper §3.3) — ``init_banked_opt_state`` / ``swap_banked`` /
  ``banked_update``: device-resident moments are compact [cap]-slot banks
  (one per partition group) backed by a full store (host RAM under
  ``offload == "host"``, see core/offload.py). ``swap_banked`` runs at
  selection-change boundaries outside jit: evicted blocks' rows stream back
  to the store, admitted blocks' rows stream in (zeros on first selection).
  Inside the compiled step every bank index is a runtime vector of static
  shape, so per-step selection never recompiles.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import OptimizerConfig
from repro.core import partition as part_mod
from repro.core.partition import BlockPartition, leaf_masks


def init_opt_state(partition: BlockPartition, params: dict,
                   moment_dtype=jnp.float32) -> dict:
    zeros = lambda p: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, moment_dtype), p)
    return {
        "m": zeros(params),
        "v": zeros(params),
        "counts": jnp.zeros((partition.num_blocks,), jnp.float32),
    }


def global_grad_norm(grads) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    norm = global_grad_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _adamw_rows(cfg: OptimizerConfig, p, g, m, v, sel, cnt, lr,
                pallas_ok: bool):
    """The masked-AdamW formula on one leaf (or gathered bank rows of one
    leaf). ``sel``/``cnt`` broadcast against ``p``. Shared by the dense and
    banked layouts so their arithmetic is identical op for op."""
    if pallas_ok:
        from repro.kernels import ops as kops
        return kops.masked_adamw(p, g, m, v, sel, cnt, lr, cfg.b1, cfg.b2,
                                 cfg.eps, cfg.weight_decay)
    mdt = m.dtype
    gf = g.astype(jnp.float32)
    m, v = m.astype(jnp.float32), v.astype(jnp.float32)
    m2 = jnp.where(sel > 0, cfg.b1 * m + (1 - cfg.b1) * gf, m)
    v2 = jnp.where(sel > 0, cfg.b2 * v + (1 - cfg.b2) * gf * gf, v)
    c = jnp.maximum(cnt, 1.0)
    mhat = m2 / (1 - cfg.b1 ** c)
    vhat = v2 / (1 - cfg.b2 ** c)
    pf = p.astype(jnp.float32)
    step = lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
    p2 = jnp.where(sel > 0, pf - step, pf)
    return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)


def _unzip3(flat):
    is_t = lambda t: isinstance(t, tuple)  # noqa: E731
    return tuple(jax.tree.map(lambda t, i=i: t[i], flat, is_leaf=is_t)
                 for i in range(3))


def update(cfg: OptimizerConfig, partition: BlockPartition, params: dict,
           grads: dict, opt_state: dict, mask, lr, use_pallas: bool = False):
    """One masked step. mask: [num_blocks]; lr: scalar (schedule applied by
    the caller). Returns (new_params, new_opt_state)."""
    counts = opt_state["counts"] + mask.astype(jnp.float32)
    masks = leaf_masks(partition, params, mask)
    counts_b = leaf_masks(partition, params, counts)  # per-leaf broadcast

    def upd(p, g, m, v, sel, cnt):
        # Pallas needs a per-row [L, 1, ...] mask — unstacked leaves get a
        # scalar from leaf_masks, so they take the jnp path.
        pallas_ok = use_pallas and p.ndim >= 2 and sel.ndim == p.ndim
        return _adamw_rows(cfg, p, g, m, v, sel, cnt, lr, pallas_ok)

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"],
                        masks, counts_b)
    new_params, new_m, new_v = _unzip3(flat)
    return new_params, {"m": new_m, "v": new_v, "counts": counts}


# ---------------------------------------------------- banked residency (§3.3)


def bank_capacity(group, k_slots: int) -> int:
    """Device slots a stacked group needs: selection places at most
    ``k_slots`` blocks anywhere, and at most ``group.length`` of them here."""
    return max(1, min(group.length, k_slots))


def init_banked_opt_state(partition: BlockPartition, params: dict,
                          k_slots: int, moment_dtype=jnp.float32,
                          store_policy: str | None = "host",
                          mesh=None) -> dict:
    """Compact banked optimizer state:

      banks[key]  — per partition group: ``m``/``v`` pytrees with leading
                    axis ``cap = min(len, k_slots)`` (stacked groups) or
                    full leaf shape (unstacked, cap 1), plus ``slots``
                    [cap] i32 — the local block id each slot holds
                    (``group.length`` = free).
      slot_map    — [num_blocks] i32, block id -> slot in its group's bank
                    (-1 = host-resident only). Host-side numpy: it drives
                    ``swap_banked`` and never enters jit.
      counts      — per-block bias-correction step counts (unchanged from
                    the dense layout; tiny, always device-resident).
      store       — full-shape backing store (core/offload.init_full_store);
                    omitted when ``store_policy`` is None (eval_shape
                    projections of the device-resident footprint). Under
                    ``store_policy == "zero1"`` the store is device-resident
                    but sharded 1/dp over ``mesh``'s data axis.

    Nothing is resident initially; the first ``swap_banked`` admits the
    first selection with zero rows from the store (zero-init on first
    selection, matching ``init_opt_state``'s zeros).
    """
    from repro.core import offload
    banks = {}
    for g in partition.groups:
        sub = params[g.key]
        if g.stacked:
            cap = bank_capacity(g, k_slots)
            zeros = lambda x: jnp.zeros((cap,) + tuple(x.shape[1:]),  # noqa: E731
                                        moment_dtype)
        else:
            cap = 1
            zeros = lambda x: jnp.zeros(x.shape, moment_dtype)  # noqa: E731
        banks[g.key] = {
            "m": jax.tree.map(zeros, sub),
            "v": jax.tree.map(zeros, sub),
            "slots": jnp.full((cap,), g.length, jnp.int32),
        }
    opt = {
        "banks": banks,
        "slot_map": np.full((partition.num_blocks,), -1, np.int32),
        "counts": jnp.zeros((partition.num_blocks,), jnp.float32),
    }
    if store_policy is not None:
        opt["store"] = offload.init_full_store(partition, params,
                                               moment_dtype, store_policy,
                                               mesh=mesh)
    return opt


@dataclasses.dataclass(frozen=True)
class GroupSwapPlan:
    """One partition group's slice of a selection-change boundary: which
    local blocks leave the bank (``ev_*``) and which enter (``ad_*``), with
    the slot each occupies/receives. Pure data, computed by ``plan_swap``
    from (slot_map, mask) alone — the async planner plans against a
    *predicted* mask and the plan is only applied if the real selection
    matches, so nothing here may depend on bank/store contents."""
    key: str
    start: int
    length: int
    stacked: bool
    ev_blocks: np.ndarray  # local block ids leaving the bank
    ev_slots: np.ndarray   # the bank rows they occupied
    ad_blocks: np.ndarray  # local block ids entering the bank
    ad_slots: np.ndarray   # the (free) bank rows they receive


def plan_swap(partition: BlockPartition, slot_map, mask,
              caps: dict) -> list[GroupSwapPlan]:
    """Evict/admit plan for one boundary. ``mask``: host bool [num_blocks];
    ``caps``: per-group bank capacity (``{key: bank["slots"].shape[0]}``).
    Groups whose residency already matches the mask are omitted (an
    unchanged selection plans to an empty list — the no-op fast path).
    Raises on per-group bank overflow, same as the paper's slot contract."""
    mask = np.asarray(mask).astype(bool)
    slot_map = np.asarray(slot_map, np.int32)
    plans = []
    for g in partition.groups:
        lo = slice(g.start, g.start + g.length)
        gmask, gslots = mask[lo], slot_map[lo]
        resident = gslots >= 0
        ev_blocks = np.nonzero(resident & ~gmask)[0]
        ad_blocks = np.nonzero(gmask & ~resident)[0]
        if not len(ev_blocks) and not len(ad_blocks):
            continue
        cap = caps[g.key]
        occupied = np.zeros((cap,), bool)
        occupied[gslots[np.nonzero(resident & gmask)[0]]] = True
        free = np.nonzero(~occupied)[0]
        if len(ad_blocks) > len(free):
            raise RuntimeError(
                f"bank overflow in group {g.key!r}: {len(ad_blocks)} "
                f"admissions for {len(free)} free slots (capacity {cap}); "
                f"the selection selected more blocks than the configured "
                f"slot capacity")
        plans.append(GroupSwapPlan(
            key=g.key, start=g.start, length=g.length, stacked=g.stacked,
            ev_blocks=ev_blocks, ev_slots=gslots[ev_blocks],
            ad_blocks=ad_blocks, ad_slots=free[:len(ad_blocks)]))
    return plans


def bank_caps(banks: dict) -> dict:
    """{group key: bank slot capacity} for ``plan_swap``."""
    return {k: int(b["slots"].shape[0]) for k, b in banks.items()}


# boundary traffic is a handful of rows across ~20 bank leaves; fusing the
# whole group into one jitted call keeps it to one dispatch (and one compile
# per (group, row-count) pair) instead of one per leaf
@jax.jit
def _gather_group(leaves, slots):
    return tuple(l.at[slots].get(mode="fill", fill_value=0) for l in leaves)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_group_donated(leaves, slots, rows):
    return tuple(l.at[slots].set(r.astype(l.dtype), mode="drop")
                 for l, r in zip(leaves, rows))


def writeback_evictions(plans: list, banks: dict, store: dict) -> dict:
    """Stream evicted blocks' bank rows back into the full store
    (device -> store side). Reads the banks — so in the overlapped timeline
    this runs only after phase B's bank output is ready (device_get blocks
    on it, which is exactly the background thread's job). Host (numpy)
    store leaves are updated in place; device leaves functionally. Returns
    the (possibly new) store tree. Admitted blocks' store rows are disjoint
    from evicted ones, so this commutes with ``prefetch_admissions``."""
    from repro.core import offload
    new_store = dict(store)
    # dispatch every device-side row gather first (one fused call per
    # group), then fetch them all with one batched device_get: a single
    # host sync per boundary instead of a blocking round trip per bank leaf
    dev = []
    for plan in plans:
        if not len(plan.ev_blocks):
            continue
        bank = banks[plan.key]
        leaves = tuple(jax.tree.leaves(bank["m"]) + jax.tree.leaves(bank["v"]))
        if plan.stacked:
            dev.extend(_gather_group(leaves,
                                     jnp.asarray(plan.ev_slots, jnp.int32)))
        else:
            dev.extend(leaves)
    host = iter(jax.device_get(dev))
    for plan in plans:
        if not len(plan.ev_blocks):
            continue
        group_store = {}
        for mom in ("m", "v"):
            s_flat, s_def = jax.tree.flatten(store[plan.key][mom])
            out_s = []
            for sl in s_flat:
                rows = next(host)
                if plan.stacked:
                    sl = offload.store_write_rows(sl, plan.ev_blocks, rows)
                else:
                    sl = offload.store_write_leaf(sl, rows)
                out_s.append(sl)
            group_store[mom] = jax.tree.unflatten(s_def, out_s)
        new_store[plan.key] = group_store
    return new_store


def prefetch_admissions(plans: list, store: dict, staging=None) -> dict:
    """Stage admitted blocks' store rows as device arrays, ready to scatter
    into bank slots at commit. Reads only *non-resident* blocks' store rows,
    which cannot change while a selection is in flight — so this is safe to
    run any time after the plan exists, concurrent with phase B (the
    overlapped path's store->device prefetch). ``staging``: optional
    reusable host buffer pool (``core.swap.StagingPool``) so host-store
    reads don't allocate fresh numpy staging on every boundary. Returns
    ``{key: {"m": [rows per leaf], "v": [...]}}`` in tree-flatten order."""
    from repro.core import offload
    staged = {}
    pooled = []
    for plan in plans:
        if not len(plan.ad_blocks):
            continue
        group = {}
        for mom in ("m", "v"):
            s_flat, _ = jax.tree.flatten(store[plan.key][mom])
            rows_out = []
            for i, sl in enumerate(s_flat):
                if plan.stacked:
                    if isinstance(sl, np.ndarray):
                        buf = (staging.take(plan.key, mom, i,
                                            len(plan.ad_blocks), sl)
                               if staging is not None else None)
                        rows = offload.store_read_rows(sl, plan.ad_blocks,
                                                       out=buf)
                        dev = jax.device_put(rows)
                        if buf is not None:
                            pooled.append(dev)
                    else:
                        dev = offload.store_read_rows(sl, plan.ad_blocks)
                else:
                    dev = (jax.device_put(sl) if isinstance(sl, np.ndarray)
                           else jnp.asarray(sl))
                rows_out.append(dev)
            group[mom] = rows_out
        staged[plan.key] = group
    if pooled:
        # pool buffers are reused next boundary; one sync for all transfers
        # (not one per leaf) makes sure every transfer has read its buffer
        jax.block_until_ready(pooled)
    return staged


def commit_swap(plans: list, banks: dict, store: dict, slot_map,
                staged: dict, donate: bool = False):
    """Apply a planned boundary: scatter staged admissions into bank rows,
    mark evicted slots free, update ``slot_map``. Device work is a handful
    of async scatter dispatches — with admissions prefetched and evictions
    written back in the background, this is all that remains on the
    critical path. ``donate=True`` donates the scattered bank leaves (rows
    written in place instead of copying the whole bank) — only for callers
    that drop their last reference to the input banks, i.e. the swap
    planner's per-step boundary. Returns (banks, slot_map, store)."""
    from repro.core import offload
    slot_map = np.array(slot_map, np.int32)  # fresh copy per boundary
    new_banks = dict(banks)
    for plan in plans:
        bank = banks[plan.key]
        slots_vec = np.array(bank["slots"], np.int32)
        group_bank = {}
        if donate and plan.stacked and len(plan.ad_blocks):
            # fused path: all of the group's m+v leaves in one donated
            # scatter call — staged rows land in place, no bank copies
            m_flat, m_def = jax.tree.flatten(bank["m"])
            v_flat, v_def = jax.tree.flatten(bank["v"])
            old = m_flat + v_flat
            rows = tuple(jnp.asarray(r) for r in
                         staged[plan.key]["m"] + staged[plan.key]["v"])
            new = _scatter_group_donated(
                tuple(old), jnp.asarray(plan.ad_slots, jnp.int32), rows)
            new = [offload._keep_sharding(n, o) for n, o in zip(new, old)]
            group_bank["m"] = jax.tree.unflatten(m_def, new[:len(m_flat)])
            group_bank["v"] = jax.tree.unflatten(v_def, new[len(m_flat):])
        else:
            for mom in ("m", "v"):
                b_flat, b_def = jax.tree.flatten(bank[mom])
                rows = staged.get(plan.key, {}).get(mom)
                out_b = []
                for i, bl in enumerate(b_flat):
                    if len(plan.ad_blocks):
                        if plan.stacked:
                            new_bl = part_mod.scatter_rows(
                                bl, plan.ad_slots,
                                jnp.asarray(rows[i], dtype=bl.dtype))
                        else:
                            new_bl = jnp.asarray(rows[i], dtype=bl.dtype)
                        bl = offload._keep_sharding(new_bl, bl)
                    out_b.append(bl)
                group_bank[mom] = jax.tree.unflatten(b_def, out_b)
        slots_vec[plan.ev_slots] = plan.length
        slots_vec[plan.ad_slots] = plan.ad_blocks
        slot_map[plan.start + plan.ev_blocks] = -1
        slot_map[plan.start + plan.ad_blocks] = plan.ad_slots
        group_bank["slots"] = offload._keep_sharding(jnp.asarray(slots_vec),
                                                     bank["slots"])
        new_banks[plan.key] = group_bank
    return new_banks, slot_map, store


def swap_banked(partition: BlockPartition, banks: dict, store: dict,
                slot_map, mask, staging=None):
    """Selection-change boundary (host side, outside jit): evicted blocks'
    bank rows stream back to the full store, admitted blocks' rows stream in
    (zero rows on first selection). Retained blocks keep their slots, so
    within an interval with an unchanged mask this is a no-op. ``mask``:
    host bool [num_blocks]. Returns (banks, slot_map, store) — host (numpy)
    store leaves are updated in place, device leaves functionally.

    This is the synchronous composition of the boundary's phases —
    ``plan_swap`` -> ``prefetch_admissions`` -> ``writeback_evictions`` ->
    ``commit_swap``. The async planner (core/swap.py) runs the first three
    in the background against the *predicted* next selection while phase B
    and the next phase A compute, leaving only ``commit_swap`` on the
    critical path when the prediction hits."""
    plans = plan_swap(partition, slot_map, mask, bank_caps(banks))
    if not plans:
        return dict(banks), np.array(slot_map, np.int32), dict(store)
    staged = prefetch_admissions(plans, store, staging)
    store = writeback_evictions(plans, banks, store)
    return commit_swap(plans, banks, store, slot_map, staged)


def banked_update(cfg: OptimizerConfig, partition: BlockPartition,
                  params: dict, grads: dict, banks: dict, counts, mask, lr,
                  use_pallas: bool = False):
    """One masked AdamW step on the compact banks (jit-safe; every index is
    a runtime vector of static shape). Assumes residency == selection —
    ``swap_banked`` ran at the last selection change, so every masked
    block's moments sit in a bank row. The row arithmetic is
    ``_adamw_rows``, identical to the dense ``update``; given the same
    (grads, mask, lr) sequence the two layouts are trajectory-exact, which
    keeps the dense implementation as the oracle. Non-resident blocks'
    params (and their store moments) are untouched bit for bit.
    Returns (new_params, new_banks, new_counts)."""
    mask = jnp.asarray(mask)
    counts = jnp.asarray(counts) + mask.astype(jnp.float32)
    new_params, new_banks = {}, {}
    for g in partition.groups:
        bank = banks[g.key]
        slots = jnp.asarray(bank["slots"])
        if g.stacked:
            valid = slots < g.length
            gids = g.start + jnp.minimum(slots, g.length - 1)
            sel = jnp.where(valid, mask[gids].astype(jnp.float32), 0.0)
            cnt = counts[gids]

            def upd(p, gr, m, v):
                if use_pallas and p.ndim >= 2:
                    # fused path: the kernel fetches p/g rows through the
                    # slots vector (scalar prefetch) — no [cap, ...] gather
                    # of p or g is materialized, only the compact outputs.
                    from repro.kernels import ops as kops
                    p2, m2, v2 = kops.banked_masked_adamw(
                        p, gr, m, v, slots, sel, cnt, lr, cfg.b1, cfg.b2,
                        cfg.eps, cfg.weight_decay)
                    return part_mod.scatter_rows(p, slots, p2), m2, v2
                p_rows = part_mod.gather_rows(p, slots)
                g_rows = part_mod.gather_rows(gr, slots)
                shp = (sel.shape[0],) + (1,) * (p_rows.ndim - 1)
                p2, m2, v2 = _adamw_rows(cfg, p_rows, g_rows, m, v,
                                         sel.reshape(shp), cnt.reshape(shp),
                                         lr, False)
                # free-slot sentinels (slots == g.length) are dropped
                return part_mod.scatter_rows(p, slots, p2), m2, v2

            flat = jax.tree.map(upd, params[g.key], grads[g.key],
                                bank["m"], bank["v"])
        else:
            resident = slots[0] < g.length
            sel = jnp.where(resident, mask[g.start].astype(jnp.float32), 0.0)
            cnt = counts[g.start]

            def upd(p, gr, m, v):
                # scalar sel/cnt broadcast; no Pallas (kernel wants per-row
                # vectors — same rule as the dense path's unstacked leaves)
                return _adamw_rows(cfg, p, gr, m, v, sel, cnt, lr, False)

            flat = jax.tree.map(upd, params[g.key], grads[g.key],
                                bank["m"], bank["v"])
        p_new, m_new, v_new = _unzip3(flat)
        new_params[g.key] = p_new
        new_banks[g.key] = {"m": m_new, "v": v_new, "slots": slots}
    return new_params, new_banks, counts


def materialize_moments(partition: BlockPartition, opt: dict):
    """Full m/v pytrees reconstructed from banks + store (host sync; for
    tests, checkpoint inspection and reporting — training never needs the
    dense view). Returns (m, v) congruent with params."""
    out = {"m": {}, "v": {}}
    for g in partition.groups:
        bank = opt["banks"][g.key]
        slots = np.asarray(bank["slots"])
        for mom in ("m", "v"):
            def one(store_leaf, bank_leaf):
                full = np.array(store_leaf)
                if g.stacked:
                    valid = np.nonzero(slots < g.length)[0]
                    if len(valid):
                        full[slots[valid]] = np.asarray(bank_leaf)[valid]
                elif slots[0] == 0:
                    full[...] = np.asarray(bank_leaf)
                return full
            out[mom][g.key] = jax.tree.map(one, opt["store"][g.key][mom],
                                           bank[mom])
    return out["m"], out["v"]
