"""Optimizer-state residency policies — TPU adaptation of paper §3.3.

The paper streams AdamW moments CPU<->GPU over PCIe so only selected blocks'
states occupy accelerator memory. Two mechanisms implement that here:

1. **Banked residency** (``OptimizerConfig.moment_residency == "banked"``):
   device-resident moments are compact [k]-slot banks (masked_adamw.py)
   backed by the *full store* this module owns. The "host"/"zero1"/"none"
   policies govern where that full store lives:

     "host"  — numpy arrays in host RAM; rows stream host<->device at
               selection-change boundaries (matches the paper 1:1, works on
               every backend — no XLA memory kinds needed).
     "zero1" / "none" — store stays on device (zero1 additionally sharded by
               the caller via ``moment_shardings`` when a mesh is present).

2. **Dense residency** (the default / oracle path): full f32 m/v for every
   parameter; ``moment_shardings`` places them —

     "host"  — XLA memory kinds (NamedSharding(memory_kind="pinned_host")).
     "zero1" — shard moments across the data-parallel axis (ZeRO-1). Uses ICI
               (50 GB/s/link) instead of host DMA and divides moment memory by
               the DP degree — our beyond-paper recommendation (the paper's
               Limitations section worries precisely about PCIe bandwidth).
     "none"  — moments colocated with params (baseline / full fine-tuning).

The deterministic §3.3 memory model (Mem = 2 * P_selected * B) is
implemented in ``optimizer_memory_report``; the *measured* column next to it
(``resident_opt_bytes``, jax.eval_shape-compatible) accounts the actual
TrainState, split device vs host. Both are surfaced by the dry-run and
benchmarks regardless of backend support.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.partition import BlockPartition, params_per_block
from repro.utils.trees import tree_map_with_path


def host_memory_kind_supported() -> bool:
    """pinned_host placement inside jit is unimplemented on XLA:CPU; the
    policy degrades to 'none' there (tested)."""
    return jax.default_backend() in ("tpu", "gpu")


def moment_shardings(policy: str, param_specs: dict, mesh,
                     data_axis: str = "data", params_shapes=None) -> dict:
    """Shardings for each of m/v given the params' PartitionSpec pytree.

    For ``policy == "zero1"`` the specs are additionally sharded over the
    data axis (first unsharded, divisible dim) via
    ``distributed.sharding.apply_zero1`` — this needs ``params_shapes``, a
    shape-carrying pytree congruent with ``param_specs`` (arrays or
    ShapeDtypeStructs), to resolve divisibility against concrete dims.
    """
    if policy == "host" and not host_memory_kind_supported():
        policy = "none"
    if policy == "zero1":
        if params_shapes is None:
            raise ValueError("moment_shardings(policy='zero1') requires "
                             "params_shapes to resolve divisible dims")
        from repro.distributed.sharding import apply_zero1
        param_specs = apply_zero1(param_specs, params_shapes, mesh, data_axis)
    kind = "pinned_host" if policy == "host" else "device"

    def one(path: str, spec):
        try:
            return NamedSharding(mesh, spec, memory_kind=kind)
        except (ValueError, TypeError):
            return NamedSharding(mesh, spec)

    return tree_map_with_path(lambda p, s: one(p, s), param_specs)


# ----------------------------------------------------- banked full store


def init_full_store(partition: BlockPartition, params: dict,
                    moment_dtype=jnp.float32, policy: str = "host") -> dict:
    """Full-shape m/v store backing the compact device banks (banked
    residency). ``policy == "host"`` -> numpy arrays in host RAM (the
    paper's design — moments stream host<->device at selection changes);
    ``"device"`` -> device arrays (testing/uniformity; no memory win)."""
    np_dtype = np.dtype(moment_dtype)

    def zeros(x):
        if policy == "host":
            return np.zeros(x.shape, np_dtype)
        return jnp.zeros(x.shape, moment_dtype)

    return {g.key: {"m": jax.tree.map(zeros, params[g.key]),
                    "v": jax.tree.map(zeros, params[g.key])}
            for g in partition.groups}


def store_write_rows(leaf, blocks, rows):
    """Write evicted bank rows back into a stacked store leaf. Host (numpy)
    leaves are updated in place — the store is owned by the optimizer and
    snapshots copy (checkpoint/manager.py); device leaves functionally."""
    if isinstance(leaf, np.ndarray):
        leaf[blocks] = np.asarray(rows, dtype=leaf.dtype)
        return leaf
    return jnp.asarray(leaf).at[jnp.asarray(blocks)].set(
        jnp.asarray(rows, dtype=leaf.dtype))


def store_read_rows(leaf, blocks):
    """Rows of a stacked store leaf for admission into bank slots."""
    if isinstance(leaf, np.ndarray):
        return leaf[blocks]
    return jnp.asarray(leaf)[jnp.asarray(blocks)]


def ensure_store_residency(store: dict, policy: str) -> dict:
    """Re-place a full store on its configured side. Checkpoint restore
    materializes every leaf as numpy, which would silently demote a
    device-resident store to host (residency is dispatched on the leaf
    type); the store is never mixed, so one leaf decides."""
    leaves = jax.tree.leaves(store)
    if not leaves:
        return store
    is_np = isinstance(leaves[0], np.ndarray)
    if policy == "host":
        return store if is_np else jax.tree.map(np.asarray, store)
    return jax.tree.map(jnp.asarray, store) if is_np else store


def store_write_leaf(leaf, value):
    """Unstacked-group variant: the whole leaf is one block's moments."""
    if isinstance(leaf, np.ndarray):
        leaf[...] = np.asarray(value, dtype=leaf.dtype)
        return leaf
    return jnp.asarray(value, dtype=leaf.dtype)


def resident_opt_bytes(opt_state) -> dict:
    """Measured optimizer-state bytes of an actual TrainState subtree, split
    by residency: numpy leaves live in host RAM, everything else is
    accelerator-resident. Accepts concrete arrays or ShapeDtypeStructs
    (eval_shape output counts as device — the dry-run's measured column)."""
    dev = host = 0
    for leaf in jax.tree.leaves(opt_state):
        nbytes = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        if isinstance(leaf, np.ndarray):
            host += nbytes
        else:
            dev += nbytes
    return {"device": dev, "host": host}


@dataclass(frozen=True)
class MemoryReport:
    """Paper §3.3 deterministic optimizer-memory model, plus (when an actual
    optimizer state is supplied) the measured device/host-resident bytes."""
    p_total: int
    p_selected: int
    bytes_per_param: int
    mem_full: int
    mem_selective: int
    mem_saved: int
    pct_reduction: float
    mem_measured_device: int = -1   # -1 = not measured
    mem_measured_host: int = -1

    def __str__(self):
        gb = 1 << 30
        s = (f"opt-state memory: full={self.mem_full/gb:.2f}GiB "
             f"selective={self.mem_selective/gb:.2f}GiB "
             f"saved={self.mem_saved/gb:.2f}GiB "
             f"({self.pct_reduction:.1f}% reduction)")
        if self.mem_measured_device >= 0:
            s += (f" measured: device={self.mem_measured_device/gb:.2f}GiB "
                  f"host={self.mem_measured_host/gb:.2f}GiB")
        return s


def optimizer_memory_report(partition: BlockPartition, params: dict,
                            k_percent: float,
                            bytes_per_param: int = 4,
                            opt_state=None) -> MemoryReport:
    """Mem_selective = 2 * P_selected * B with P_selected = the k% largest
    blocks (worst case: selection favors the biggest blocks). Pass the
    actual ``state["opt"]`` pytree (arrays or eval_shape SDS) as
    ``opt_state`` to fill the measured columns next to the model."""
    counts = params_per_block(partition, params)
    p_total = int(counts.sum())
    k = max(1, int(round(partition.num_blocks * k_percent / 100.0)))
    p_sel = int(np.sort(counts)[::-1][:k].sum())
    mem_full = 2 * p_total * bytes_per_param
    mem_sel = 2 * p_sel * bytes_per_param
    measured = (resident_opt_bytes(opt_state) if opt_state is not None
                else {"device": -1, "host": -1})
    return MemoryReport(
        p_total=p_total, p_selected=p_sel, bytes_per_param=bytes_per_param,
        mem_full=mem_full, mem_selective=mem_sel, mem_saved=mem_full - mem_sel,
        pct_reduction=(1 - p_sel / p_total) * 100.0,
        mem_measured_device=measured["device"],
        mem_measured_host=measured["host"])
