"""Optimizer-state residency policies — TPU adaptation of paper §3.3.

The paper streams AdamW moments CPU<->GPU over PCIe so only selected blocks'
states occupy accelerator memory. Two mechanisms implement that here:

1. **Banked residency** (``OptimizerConfig.moment_residency == "banked"``):
   device-resident moments are compact [k]-slot banks (masked_adamw.py)
   backed by the *full store* this module owns. The "host"/"zero1"/"none"
   policies govern where that full store lives:

     "host"  — numpy arrays in host RAM; rows stream host<->device at
               selection-change boundaries (matches the paper 1:1, works on
               every backend — no XLA memory kinds needed).
     "zero1" — store stays on device, ZeRO-1-sharded over the mesh's data
               axis (``distributed.sharding.store_specs``): each device owns
               1/dp of the backing rows and the boundary swap streams only
               the shard slices holding the swapped block ids. Requires a
               mesh (rejected at init without one — a replicated device
               store would be strictly worse than dense ZeRO-1).
     "none"  — store stays on device, replicated (testing/uniformity).

2. **Dense residency** (the default / oracle path): full f32 m/v for every
   parameter; ``moment_shardings`` places them —

     "host"  — XLA memory kinds (NamedSharding(memory_kind="pinned_host")).
     "zero1" — shard moments across the data-parallel axis (ZeRO-1). Uses ICI
               (50 GB/s/link) instead of host DMA and divides moment memory by
               the DP degree — our beyond-paper recommendation (the paper's
               Limitations section worries precisely about PCIe bandwidth).
     "none"  — moments colocated with params (baseline / full fine-tuning).

The deterministic §3.3 memory model (Mem = 2 * P_selected * B) is
implemented in ``optimizer_memory_report``; the *measured* column next to it
(``resident_opt_bytes``, jax.eval_shape-compatible) accounts the actual
TrainState, split device vs host. Both are surfaced by the dry-run and
benchmarks regardless of backend support.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.partition import BlockPartition, params_per_block
from repro.utils.trees import tree_map_with_path


def host_memory_kind_supported() -> bool:
    """pinned_host placement inside jit is unimplemented on XLA:CPU; the
    policy degrades to 'none' there (tested)."""
    return jax.default_backend() in ("tpu", "gpu")


def moment_shardings(policy: str, param_specs: dict, mesh,
                     data_axis: str = "data", params_shapes=None) -> dict:
    """Shardings for each of m/v given the params' PartitionSpec pytree.

    For ``policy == "zero1"`` the specs are additionally sharded over the
    data axis (first unsharded, divisible dim) via
    ``distributed.sharding.apply_zero1`` — this needs ``params_shapes``, a
    shape-carrying pytree congruent with ``param_specs`` (arrays or
    ShapeDtypeStructs), to resolve divisibility against concrete dims.
    """
    if policy == "host" and not host_memory_kind_supported():
        policy = "none"
    if policy == "zero1":
        if params_shapes is None:
            raise ValueError("moment_shardings(policy='zero1') requires "
                             "params_shapes to resolve divisible dims")
        from repro.distributed.sharding import apply_zero1
        param_specs = apply_zero1(param_specs, params_shapes, mesh, data_axis)
    kind = "pinned_host" if policy == "host" else "device"

    def one(path: str, spec):
        try:
            return NamedSharding(mesh, spec, memory_kind=kind)
        except (ValueError, TypeError):
            return NamedSharding(mesh, spec)

    return tree_map_with_path(lambda p, s: one(p, s), param_specs)


# ----------------------------------------------------- banked full store


def init_full_store(partition: BlockPartition, params: dict,
                    moment_dtype=jnp.float32, policy: str = "host",
                    mesh=None) -> dict:
    """Full-shape m/v store backing the compact device banks (banked
    residency). ``policy == "host"`` -> numpy arrays in host RAM (the
    paper's design — moments stream host<->device at selection changes);
    ``"device"`` -> device arrays (testing/uniformity; no memory win);
    ``"zero1"`` -> device arrays ZeRO-1-sharded over the mesh's data axis
    (``distributed.sharding.store_specs``): each device owns 1/dp of the
    store rows, so banked residency composes with data parallelism instead
    of paying a replicated backing store per device."""
    np_dtype = np.dtype(moment_dtype)

    shardings = None
    if policy == "zero1":
        if mesh is None:
            raise ValueError("init_full_store(policy='zero1') needs a mesh "
                             "to shard the store over the data axis")
        from repro.distributed.sharding import store_shardings
        shapes = {g.key: {"m": params[g.key], "v": params[g.key]}
                  for g in partition.groups}
        shardings = store_shardings(partition, shapes, mesh)

    def zeros(x, sh=None):
        if policy == "host":
            return np.zeros(x.shape, np_dtype)
        z = jnp.zeros(x.shape, moment_dtype)
        return jax.device_put(z, sh) if sh is not None else z

    if shardings is not None:
        return {g.key: jax.tree.map(zeros,
                                    {"m": params[g.key], "v": params[g.key]},
                                    shardings[g.key])
                for g in partition.groups}
    return {g.key: {"m": jax.tree.map(zeros, params[g.key]),
                    "v": jax.tree.map(zeros, params[g.key])}
            for g in partition.groups}


def _keep_sharding(new, ref):
    """Device stores may carry an explicit (ZeRO-1) sharding; scatter/gather
    outputs must stay on that layout or the compiled banked phases would see
    a different input sharding next boundary and recompile."""
    ref_sh = getattr(ref, "sharding", None)
    if ref_sh is not None and getattr(new, "sharding", None) != ref_sh:
        return jax.device_put(new, ref_sh)
    return new


def store_write_rows(leaf, blocks, rows):
    """Write evicted bank rows back into a stacked store leaf. Host (numpy)
    leaves are updated in place — the store is owned by the optimizer and
    snapshots copy (checkpoint/manager.py); device leaves functionally (a
    ZeRO-1-sharded leaf only touches the shards owning ``blocks``)."""
    if isinstance(leaf, np.ndarray):
        leaf[blocks] = np.asarray(rows, dtype=leaf.dtype)
        return leaf
    new = jnp.asarray(leaf).at[jnp.asarray(blocks)].set(
        jnp.asarray(rows, dtype=leaf.dtype))
    return _keep_sharding(new, leaf)


def store_read_rows(leaf, blocks, out=None):
    """Rows of a stacked store leaf for admission into bank slots.

    ``out``: optional preallocated numpy staging buffer (first dim >=
    ``len(blocks)``) for host-store reads — the swap planner reuses pinned
    staging across boundaries instead of allocating per swap. Ignored for
    device-resident leaves (the read is a device-side gather there)."""
    if isinstance(leaf, np.ndarray):
        if out is not None:
            view = out[:len(blocks)]
            np.take(leaf, blocks, axis=0, out=view)
            return view
        return leaf[blocks]
    return jnp.asarray(leaf)[jnp.asarray(blocks)]


def ensure_store_residency(store: dict, policy: str, shardings=None) -> dict:
    """Re-place a full store on its configured side. Checkpoint restore
    materializes every leaf as numpy, which would silently demote a
    device-resident store to host (residency is dispatched on the leaf
    type); the store is never mixed, so one leaf decides. For ``"zero1"``
    pass the store's sharding tree so restored leaves land back on their
    1/dp data-axis shards instead of a single device."""
    leaves = jax.tree.leaves(store)
    if not leaves:
        return store
    is_np = isinstance(leaves[0], np.ndarray)
    if policy == "host":
        return store if is_np else jax.tree.map(np.asarray, store)
    if not is_np:
        return store
    if shardings is not None:
        return jax.tree.map(lambda x, sh: jax.device_put(x, sh),
                            store, shardings)
    return jax.tree.map(jnp.asarray, store)


def store_write_leaf(leaf, value):
    """Unstacked-group variant: the whole leaf is one block's moments."""
    if isinstance(leaf, np.ndarray):
        leaf[...] = np.asarray(value, dtype=leaf.dtype)
        return leaf
    return _keep_sharding(jnp.asarray(value, dtype=leaf.dtype), leaf)


def resident_opt_bytes(opt_state) -> dict:
    """Measured optimizer-state bytes of an actual TrainState subtree, split
    by residency: numpy leaves live in host RAM, everything else is
    accelerator-resident. Accepts concrete arrays or ShapeDtypeStructs
    (eval_shape output counts as device — the dry-run's measured column).

    ``device_per_device`` is the per-device slice of the device total: a
    leaf carrying an explicit sharding contributes only its shard bytes
    (``sharding.shard_shape``), so a ZeRO-1-sharded store measures 1/dp of
    its replicated layout while replicated/unsharded leaves count in full.
    """
    dev = host = dev_local = 0
    for leaf in jax.tree.leaves(opt_state):
        nbytes = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        if isinstance(leaf, np.ndarray):
            host += nbytes
            continue
        dev += nbytes
        local = nbytes
        sh = getattr(leaf, "sharding", None)
        if sh is not None:
            try:
                shard_shape = sh.shard_shape(tuple(leaf.shape))
                local = (int(np.prod(shard_shape))
                         * np.dtype(leaf.dtype).itemsize)
            except Exception:  # noqa: BLE001 — sharding types without it
                pass
        dev_local += local
    return {"device": dev, "host": host, "device_per_device": dev_local}


@dataclass(frozen=True)
class MemoryReport:
    """Paper §3.3 deterministic optimizer-memory model, plus (when an actual
    optimizer state is supplied) the measured device/host-resident bytes."""
    p_total: int
    p_selected: int
    bytes_per_param: int
    mem_full: int
    mem_selective: int
    mem_saved: int
    pct_reduction: float
    mem_measured_device: int = -1   # -1 = not measured
    mem_measured_host: int = -1

    def __str__(self):
        gb = 1 << 30
        s = (f"opt-state memory: full={self.mem_full/gb:.2f}GiB "
             f"selective={self.mem_selective/gb:.2f}GiB "
             f"saved={self.mem_saved/gb:.2f}GiB "
             f"({self.pct_reduction:.1f}% reduction)")
        if self.mem_measured_device >= 0:
            s += (f" measured: device={self.mem_measured_device/gb:.2f}GiB "
                  f"host={self.mem_measured_host/gb:.2f}GiB")
        return s


def optimizer_memory_report(partition: BlockPartition, params: dict,
                            k_percent: float,
                            bytes_per_param: int = 4,
                            opt_state=None) -> MemoryReport:
    """Mem_selective = 2 * P_selected * B with P_selected = the k% largest
    blocks (worst case: selection favors the biggest blocks). Pass the
    actual ``state["opt"]`` pytree (arrays or eval_shape SDS) as
    ``opt_state`` to fill the measured columns next to the model."""
    counts = params_per_block(partition, params)
    p_total = int(counts.sum())
    k = max(1, int(round(partition.num_blocks * k_percent / 100.0)))
    p_sel = int(np.sort(counts)[::-1][:k].sum())
    mem_full = 2 * p_total * bytes_per_param
    mem_sel = 2 * p_sel * bytes_per_param
    measured = (resident_opt_bytes(opt_state) if opt_state is not None
                else {"device": -1, "host": -1})
    return MemoryReport(
        p_total=p_total, p_selected=p_sel, bytes_per_param=bytes_per_param,
        mem_full=mem_full, mem_selective=mem_sel, mem_saved=mem_full - mem_sel,
        pct_reduction=(1 - p_sel / p_total) * 100.0,
        mem_measured_device=measured["device"],
        mem_measured_host=measured["host"])
