"""Optimizer-state residency policies — TPU adaptation of paper §3.3.

The paper streams AdamW moments CPU<->GPU over PCIe so only selected blocks'
states occupy accelerator memory. On TPU the idiomatic equivalents are:

  "host"  — place moments in host memory via XLA memory kinds
            (NamedSharding(..., memory_kind="pinned_host")); XLA streams them
            through the update. Matches the paper's design 1:1.
  "zero1" — shard moments across the data-parallel axis (ZeRO-1). Uses ICI
            (50 GB/s/link) instead of host DMA and divides moment memory by
            the DP degree — our beyond-paper recommendation (the paper's
            Limitations section worries precisely about PCIe bandwidth).
  "none"  — moments colocated with params (baseline / full fine-tuning).

The deterministic §3.3 memory model (Mem = 2 * P_selected * B) is
implemented in ``optimizer_memory_report`` and surfaced by the dry-run and
benchmarks regardless of backend support.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.core.partition import BlockPartition, params_per_block
from repro.utils.trees import tree_map_with_path


def host_memory_kind_supported() -> bool:
    """pinned_host placement inside jit is unimplemented on XLA:CPU; the
    policy degrades to 'none' there (tested)."""
    return jax.default_backend() in ("tpu", "gpu")


def moment_shardings(policy: str, param_specs: dict, mesh,
                     data_axis: str = "data", params_shapes=None) -> dict:
    """Shardings for each of m/v given the params' PartitionSpec pytree.

    For ``policy == "zero1"`` the specs are additionally sharded over the
    data axis (first unsharded, divisible dim) via
    ``distributed.sharding.apply_zero1`` — this needs ``params_shapes``, a
    shape-carrying pytree congruent with ``param_specs`` (arrays or
    ShapeDtypeStructs), to resolve divisibility against concrete dims.
    """
    if policy == "host" and not host_memory_kind_supported():
        policy = "none"
    if policy == "zero1":
        if params_shapes is None:
            raise ValueError("moment_shardings(policy='zero1') requires "
                             "params_shapes to resolve divisible dims")
        from repro.distributed.sharding import apply_zero1
        param_specs = apply_zero1(param_specs, params_shapes, mesh, data_axis)
    kind = "pinned_host" if policy == "host" else "device"

    def one(path: str, spec):
        try:
            return NamedSharding(mesh, spec, memory_kind=kind)
        except (ValueError, TypeError):
            return NamedSharding(mesh, spec)

    return tree_map_with_path(lambda p, s: one(p, s), param_specs)


@dataclass(frozen=True)
class MemoryReport:
    """Paper §3.3 deterministic optimizer-memory model."""
    p_total: int
    p_selected: int
    bytes_per_param: int
    mem_full: int
    mem_selective: int
    mem_saved: int
    pct_reduction: float

    def __str__(self):
        gb = 1 << 30
        return (f"opt-state memory: full={self.mem_full/gb:.2f}GiB "
                f"selective={self.mem_selective/gb:.2f}GiB "
                f"saved={self.mem_saved/gb:.2f}GiB "
                f"({self.pct_reduction:.1f}% reduction)")


def optimizer_memory_report(partition: BlockPartition, params: dict,
                            k_percent: float,
                            bytes_per_param: int = 4) -> MemoryReport:
    """Mem_selective = 2 * P_selected * B with P_selected = the k% largest
    blocks (worst case: selection favors the biggest blocks)."""
    counts = params_per_block(partition, params)
    p_total = int(counts.sum())
    k = max(1, int(round(partition.num_blocks * k_percent / 100.0)))
    p_sel = int(np.sort(counts)[::-1][:k].sum())
    mem_full = 2 * p_total * bytes_per_param
    mem_sel = 2 * p_sel * bytes_per_param
    return MemoryReport(
        p_total=p_total, p_selected=p_sel, bytes_per_param=bytes_per_param,
        mem_full=mem_full, mem_selective=mem_sel, mem_saved=mem_full - mem_sel,
        pct_reduction=(1 - p_sel / p_total) * 100.0)
