"""BlockPartition: the paper's "block" taxonomy over a parameter pytree.

A block is (paper §3.1): one transformer block, the embedding table, or the
final norm — plus, in this framework, the hybrid shared-attention block,
encoder blocks (encdec), the untied LM head, and MTP blocks, each as its own
bandit arm.

Stacked parameter groups (leading axis = #layers, produced by scan-over-
layers models) map to consecutive block ids, which is what makes per-step
dynamic selection a cheap runtime vector instead of a recompile.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class Group:
    key: str        # top-level key in the params dict
    start: int      # first block id
    length: int     # number of blocks in the group
    stacked: bool   # True -> every leaf has leading axis == length


@dataclass(frozen=True)
class BlockPartition:
    groups: tuple[Group, ...]
    num_blocks: int

    def group(self, key: str) -> Group:
        for g in self.groups:
            if g.key == key:
                return g
        raise KeyError(key)

    @property
    def block_names(self) -> list[str]:
        names = []
        for g in self.groups:
            if g.length == 1:
                names.append(g.key)
            else:
                names.extend(f"{g.key}[{i}]" for i in range(g.length))
        return names


def _group_order(cfg: ModelConfig) -> list[tuple[str, int, bool]]:
    """(key, length, stacked) in canonical block order."""
    out: list[tuple[str, int, bool]] = [("embed", 1, False)]
    if cfg.family == "encdec":
        out += [("enc_layers", cfg.num_encoder_layers, True),
                ("enc_norm", 1, False),
                ("dec_layers", cfg.num_layers, True)]
    elif cfg.family == "moe":
        if cfg.first_k_dense:
            out.append(("dense_layers", cfg.first_k_dense, True))
        out.append(("moe_layers", cfg.num_layers - cfg.first_k_dense, True))
    elif cfg.family == "hybrid":
        out += [("layers", cfg.num_layers, True), ("shared_attn", 1, False)]
    else:  # dense / vlm / ssm
        out.append(("layers", cfg.num_layers, True))
    out.append(("final_norm", 1, False))
    if not cfg.tie_embeddings:
        out.append(("lm_head", 1, False))
    if cfg.mtp_depth:
        out.append(("mtp", 1, False))
    return out


def build_partition(cfg: ModelConfig) -> BlockPartition:
    groups, start = [], 0
    for key, length, stacked in _group_order(cfg):
        groups.append(Group(key, start, length, stacked))
        start += length
    return BlockPartition(tuple(groups), start)


# ------------------------------------------------------------------ norms


def block_grad_norms(partition: BlockPartition, grads: dict,
                     use_pallas: bool = False) -> jax.Array:
    """Per-block gradient L2 norm (paper Alg. 1 lines 1-6): aggregates
    sum-of-squares over every leaf of each block, sqrt at the end.
    Returns [num_blocks] f32."""
    if use_pallas:
        from repro.kernels import ops as kops
        stacked_sq = kops.block_grad_sq_norms
    else:
        stacked_sq = None
    sq = jnp.zeros((partition.num_blocks,), jnp.float32)
    for g in partition.groups:
        sub = grads[g.key]
        leaves = jax.tree.leaves(sub)
        if g.stacked:
            acc = jnp.zeros((g.length,), jnp.float32)
            for leaf in leaves:
                if stacked_sq is not None and leaf.ndim >= 2:
                    acc = acc + stacked_sq(leaf)
                else:
                    lf = leaf.astype(jnp.float32)
                    acc = acc + jnp.sum(lf * lf, axis=tuple(range(1, lf.ndim)))
            sq = jax.lax.dynamic_update_slice(sq, acc, (g.start,))
        else:
            s = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
            sq = sq.at[g.start].add(s)
    return jnp.sqrt(sq)


# ------------------------------------------------------------------ masks


def leaf_masks(partition: BlockPartition, params: dict, mask: jax.Array) -> dict:
    """Broadcastable per-leaf selection masks matching the params structure.
    mask: [num_blocks] (bool or 0/1)."""
    m = mask.astype(jnp.float32)
    out = {}
    for g in partition.groups:
        sub = params[g.key]
        if g.stacked:
            seg = jax.lax.dynamic_slice(m, (g.start,), (g.length,))
            out[g.key] = jax.tree.map(
                lambda leaf: seg.reshape((g.length,) + (1,) * (leaf.ndim - 1)),
                sub)
        else:
            out[g.key] = jax.tree.map(lambda leaf: m[g.start], sub)
    return out


def layer_masks_dict(partition: BlockPartition, mask: jax.Array) -> dict:
    """Per-group mask vectors for the model's gate_weight_grads hook:
    {"layers": [L], "shared_attn": scalar, ...} — only body groups."""
    out = {}
    for g in partition.groups:
        if g.key in ("embed", "final_norm", "enc_norm", "lm_head"):
            continue
        if g.stacked:
            out[g.key] = jax.lax.dynamic_slice(
                mask.astype(jnp.float32), (g.start,), (g.length,))
        else:
            out[g.key] = mask[g.start].astype(jnp.float32)
    return out


# ------------------------------------------------------------------ slots
#
# Helpers for the compact banked optimizer state (masked_adamw.py): a
# stacked group's moments live in a [cap, ...] bank whose row ``s`` holds
# the moments of local block ``slots[s]`` (``slots[s] == group.length`` =
# free slot). Both helpers keep every index a runtime vector of static
# shape, so per-step selection changes never trigger recompilation.


def gather_rows(leaf, slots, fill=0):
    """Rows of a stacked leaf [L, ...] at ``slots`` [n] -> [n, ...].
    Out-of-range entries (the ``L`` free-slot sentinel) read as ``fill``
    rows instead of clamping onto a real block."""
    return jnp.asarray(leaf).at[jnp.asarray(slots, dtype=jnp.int32)].get(
        mode="fill", fill_value=fill)


def scatter_rows(leaf, slots, rows):
    """Write ``rows`` [n, ...] into stacked leaf [L, ...] at ``slots`` [n].
    Out-of-range entries are dropped, so free-slot sentinels never land."""
    return jnp.asarray(leaf).at[jnp.asarray(slots, dtype=jnp.int32)].set(
        rows, mode="drop")


def params_per_block(partition: BlockPartition, params: dict) -> np.ndarray:
    """Static count of parameters per block (for the §3.3 memory model)."""
    counts = np.zeros((partition.num_blocks,), np.int64)
    for g in partition.groups:
        for leaf in jax.tree.leaves(params[g.key]):
            shape = leaf.shape
            if g.stacked:
                per = int(np.prod(shape[1:]))
                counts[g.start:g.start + g.length] += per
            else:
                counts[g.start] += int(np.prod(shape))
    return counts
