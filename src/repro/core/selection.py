"""Selection primitives: top-k masking, Dirichlet sampling, Gumbel-top-k.

All functions are jit-safe (static k, dynamic scores) — the entire
AdaGradSelect controller runs inside the compiled train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_mask(scores: jax.Array, k: int) -> jax.Array:
    """Boolean mask of the k largest entries of ``scores`` [N] -> [N]."""
    n = scores.shape[0]
    _, idx = jax.lax.top_k(scores, k)
    return jnp.zeros((n,), jnp.bool_).at[idx].set(True)


def dirichlet_probs(key: jax.Array, freq: jax.Array, delta: float) -> jax.Array:
    """p ~ Dirichlet(freq + delta) (paper §3.2)."""
    alpha = freq.astype(jnp.float32) + delta
    return jax.random.dirichlet(key, alpha)


def sample_without_replacement(key: jax.Array, probs: jax.Array, k: int) -> jax.Array:
    """Draw k items without replacement with probability proportional to
    ``probs`` — the Gumbel-top-k trick (exact for Plackett-Luce sampling).
    Returns a boolean mask [N]."""
    g = jax.random.gumbel(key, probs.shape)
    keys = jnp.log(probs + 1e-20) + g
    return topk_mask(keys, k)


def random_mask(key: jax.Array, n: int, k: int) -> jax.Array:
    return topk_mask(jax.random.uniform(key, (n,)), k)


def apply_always_include(mask: jax.Array, always_include: tuple) -> jax.Array:
    for i in always_include:
        mask = mask.at[i].set(True)
    return mask
