"""Async double-buffered moment streaming for banked residency.

The synchronous banked step pays a host boundary between its two compiled
phases: ``swap_banked`` plans the evict/admit sets, stages admissions out of
the full store, and waits for evicted bank rows — all while the device
idles. GRASS (PAPERS.md) hides the analogous projected-gradient traffic by
overlapping it with compute; BlockLLM leans on selections drifting slowly
between steps. ``SwapPlanner`` combines both ideas:

* after step t's apply (phase B) has been *dispatched*, the planner asks the
  selection policy where step t+1 will land (``adagradselect.predict_next``
  — exact for schedule/PRNG-driven policies, the cumulative-signal
  approximation for norm-driven ones) and hands the boundary work to a
  single background thread: plan against the predicted mask, prefetch the
  predicted admit rows store->device into staging, and write predicted
  evictions back device->store (the ``np.asarray`` on bank rows blocks on
  phase B's output *inside the thread*, which is exactly the overlap).
  On a multi-device mesh the job runs *inline* on the dispatching thread
  instead — sharded store reads carry collectives, which deadlock if two
  threads enqueue them concurrently — still after phase B's async dispatch;
* at step t+1's boundary ``resolve`` joins the thread. If the prediction
  matched the real selection (all-or-nothing on the [k] indices vector),
  only ``commit_swap`` remains on the critical path — and it donates the
  scattered bank leaves, so XLA writes the staged rows in place instead of
  copying each bank. A miss falls back to the synchronous ``swap_banked``
  and is counted (``SwapStats.predicted_hit_rate``).

Why the overlap cannot corrupt state:

* admitted blocks are non-resident, so their store rows are frozen while
  the prediction is in flight — prefetch reads stable data;
* predicted evictions write the post-phase-B bank values of *resident*
  blocks; on a mispredict the store rows written are for blocks whose
  authoritative copy is still the bank, so the write is inert (the sync
  fallback re-writes the real evictions);
* evict and admit sets of one boundary are disjoint, so writeback and
  prefetch commute;
* ``resolve``/``quiesce`` join the thread before the next apply donates the
  bank buffers the writeback reads, and before checkpointing snapshots the
  store.

``StagingPool`` keeps the host-side staging buffers (admission reads out of
a host store) alive across boundaries instead of allocating per swap — the
same pool serves the background path and the synchronous fallback.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor

import jax
import numpy as np

from repro import obs
from repro.core import adagradselect
from repro.core import masked_adamw as ma


class StagingPool:
    """Reusable numpy staging buffers for host-store admission reads, keyed
    by (group, moment, leaf index) and grown to the high-water row count.
    ``prefetch_admissions`` blocks on the device transfer before a buffer
    can be handed out again, so a single-slot pool per leaf is enough."""

    def __init__(self):
        self._bufs: dict = {}

    def take(self, key: str, mom: str, leaf_idx: int, n: int,
             leaf: np.ndarray) -> np.ndarray:
        k = (key, mom, leaf_idx)
        buf = self._bufs.get(k)
        shape = (n,) + leaf.shape[1:]
        if buf is None or buf.shape[0] < n or buf.shape[1:] != leaf.shape[1:] \
                or buf.dtype != leaf.dtype:
            buf = np.empty(shape, leaf.dtype)
            self._bufs[k] = buf
        return buf

    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._bufs.values())


@dataclasses.dataclass
class SwapStats:
    """Boundary accounting + step-phase timing for the banked driver.
    ``boundaries`` counts selection changes that required bank traffic;
    ``predicted_hits`` those fully absorbed by the background dispatch;
    ``sync_swaps`` the fallback (mispredict, overflow-on-predicted-plan, or
    async disabled). Phase timing lives in obs histograms — the one timing
    source of truth (the banked driver times each phase once via
    ``obs.timed`` and trace spans ride the same measurement): ``phase_a``
    includes the forward/select device wait (the indices sync), ``swap``
    the boundary resolve+commit (or the full synchronous swap), ``phase_b``
    the apply + dispatch issue. The historical accumulated-µs fields
    (``phase_a_us`` etc., the bench JSON schema) are read-only views over
    those histograms' totals."""
    steps: int = 0
    boundaries: int = 0
    predicted_hits: int = 0
    sync_swaps: int = 0
    dispatches: int = 0
    phase_a: obs.Histogram = dataclasses.field(
        default_factory=obs.Histogram, repr=False)
    swap: obs.Histogram = dataclasses.field(
        default_factory=obs.Histogram, repr=False)
    phase_b: obs.Histogram = dataclasses.field(
        default_factory=obs.Histogram, repr=False)

    @property
    def phase_a_us(self) -> float:
        return self.phase_a.total

    @property
    def swap_us(self) -> float:
        return self.swap.total

    @property
    def phase_b_us(self) -> float:
        return self.phase_b.total

    @property
    def predicted_hit_rate(self) -> float:
        return self.predicted_hits / self.boundaries if self.boundaries else 1.0

    def as_dict(self) -> dict:
        return {"steps": self.steps, "boundaries": self.boundaries,
                "predicted_hits": self.predicted_hits,
                "sync_swaps": self.sync_swaps,
                "dispatches": self.dispatches,
                "phase_a_us": self.phase_a_us, "swap_us": self.swap_us,
                "phase_b_us": self.phase_b_us,
                "predicted_hit_rate": self.predicted_hit_rate}


class SwapPlanner:
    """Owns the background boundary work for one banked trainer. At most one
    job is ever in flight; ``resolve`` (or ``quiesce``) joins it before any
    state the job reads can be donated, checkpointed, or mutated."""

    def __init__(self, partition, select_cfg, num_blocks: int,
                 enabled: bool = True, inline: bool = False):
        self.partition = partition
        self.num_blocks = num_blocks
        self.enabled = enabled
        # On a multi-device mesh the job's store/bank reads are sharded, so
        # they lower to collective-bearing XLA computations. Collectives
        # rendezvous by enqueue order; a second thread issuing them while
        # phase B's collectives are in flight can interleave participants
        # from different executions and deadlock. ``inline`` runs the job on
        # the dispatching thread instead — one enqueue order, and the device
        # still overlaps because phase B was already dispatched async.
        self.inline = inline
        self.staging = StagingPool()
        self.stats = SwapStats()
        # last-planner-wins registry bindings: the active trainer's phase
        # histograms and boundary counters show up in obs.snapshot()
        for name, hist in (("phase_a_us", self.stats.phase_a),
                           ("swap_us", self.stats.swap),
                           ("phase_b_us", self.stats.phase_b)):
            obs.metrics.register(name, hist, subsystem="swap")
        obs.metrics.register("banked", self.stats.as_dict, subsystem="swap")
        self._c_mispredicts = obs.metrics.counter("mispredicts",
                                                  subsystem="swap")
        self._pool: ThreadPoolExecutor | None = None
        self._pending = None  # Future | dict -> dict | None
        self._predict = jax.jit(
            lambda st: adagradselect.predict_next(select_cfg, st, num_blocks))

    # ------------------------------------------------------------ dispatch

    def dispatch(self, sel_state: dict, banks: dict, store: dict,
                 slot_map) -> None:
        """Kick off the predicted boundary for the *next* step. Call after
        this step's apply has been dispatched: the job's device reads block
        on apply's outputs in the background thread, not on the main one.
        No-op (beyond the prediction) when async streaming is disabled."""
        if not self.enabled or self._pending is not None:
            return
        pred_idx = self._predict(sel_state)  # async device dispatch
        caps = ma.bank_caps(banks)
        slot_map = np.array(slot_map, np.int32)  # snapshot: host-global map

        def job():
            # the span puts the boundary work on its own timeline track
            # when the job runs on the "swap-planner" background thread
            with obs.span("swap_dispatch_job"):
                idx = np.asarray(pred_idx)
                mask = np.zeros((self.num_blocks,), bool)
                mask[idx[idx < self.num_blocks]] = True
                try:
                    plans = ma.plan_swap(self.partition, slot_map, mask, caps)
                except RuntimeError:
                    # predicted selection overflows the banks — the real one
                    # may not (or will raise on the sync path with context)
                    return {"idx": idx, "failed": True}
                staged = ma.prefetch_admissions(plans, store, self.staging)
                new_store = ma.writeback_evictions(plans, banks, store)
                return {"idx": idx, "failed": False, "plans": plans,
                        "staged": staged, "store": new_store}

        if self.inline:
            self._pending = job()
        else:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix="swap-planner")
            self._pending = self._pool.submit(job)
        self.stats.dispatches += 1

    # ------------------------------------------------------------- resolve

    def resolve(self, indices, banks: dict, store: dict, slot_map):
        """The selection-change boundary for the *actual* indices of this
        step. Joins any in-flight dispatch; on an exact prediction hit only
        ``commit_swap`` runs here — with the bank leaves *donated*, so the
        staged rows are written in place rather than copying each bank.
        Donation is safe exactly here: the caller hands over its banks and
        uses only the returned ones, and the joined job was the last other
        reader. A miss falls back to the synchronous ``swap_banked`` path
        (same donation, pooled staging). Returns (banks, slot_map, store)."""
        idx = np.asarray(indices)
        job = self._take_pending()
        if job is not None and not job["failed"] \
                and np.array_equal(job["idx"], idx):
            if job["plans"]:  # unchanged selections are not boundaries
                self.stats.boundaries += 1
                self.stats.predicted_hits += 1
            return ma.commit_swap(job["plans"], banks, job["store"],
                                  slot_map, job["staged"], donate=True)
        if job is not None:
            # keep the job's store: predicted-eviction writebacks are inert
            # for still-resident blocks and identical for real evictions
            store = job["store"] if not job["failed"] else store
        mask = np.zeros((self.num_blocks,), bool)
        mask[idx[idx < self.num_blocks]] = True
        plans = ma.plan_swap(self.partition, slot_map, mask,
                             ma.bank_caps(banks))
        if not plans:
            return dict(banks), np.array(slot_map, np.int32), dict(store)
        self.stats.boundaries += 1
        self.stats.sync_swaps += 1
        if job is not None:
            # a dispatch was in flight but missed (or overflowed): count it
            # where latency diagnosis looks first
            self._c_mispredicts.inc()
            obs.instant("swap_mispredict",
                        {"predicted": job["idx"].tolist(),
                         "actual": idx.tolist()} if not job["failed"]
                        else {"failed_plan": True})
        staged = ma.prefetch_admissions(plans, store, self.staging)
        store = ma.writeback_evictions(plans, banks, store)
        return ma.commit_swap(plans, banks, store, slot_map, staged,
                              donate=True)

    # ------------------------------------------------------------- barrier

    def quiesce(self) -> None:
        """Join and discard any in-flight dispatch. Must run before
        checkpointing (the job holds references into banks/store) and at
        the end of training. Discarding loses nothing: staged admissions
        are re-derivable and predicted-eviction writebacks are inert."""
        self._take_pending()

    def close(self) -> None:
        self.quiesce()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    def _take_pending(self):
        job, self._pending = self._pending, None
        if job is None or isinstance(job, dict):  # inline jobs store results
            return job
        return job.result()
