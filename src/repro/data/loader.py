"""Shard-aware, resumable data loading.

Two sources:
  * synthetic math (default; offline MetaMathQA proxy)   -- pure f(step)
  * jsonl documents, byte-tokenized and packed           -- pure f(step) over
    a pre-tokenized ring buffer

Both expose ``batch_at(step) -> {"tokens", "loss_mask"}`` as GLOBAL arrays;
the launcher device_puts them with the batch sharding (single-controller).
On multi-host deployments each process feeds its addressable slice via
``host_local_slice`` — the global batch layout (and hence training) is
identical either way, and resume-after-restart needs only the step counter.

Streaming SFT corpora (variable-length prompt/completion records, packed
with segment ids, prefetched) live in ``repro.data.pipeline``;
``make_source("jsonl_sft" | "packed_math", ...)`` builds one behind the same
trainer seam.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from repro.data import synthetic
from repro.data import tokenizer as tok


@dataclass
class SyntheticMathSource:
    cfg: synthetic.MathTaskConfig
    global_batch: int

    def batch_at(self, step: int) -> dict:
        return synthetic.batch_at(self.cfg, step, self.global_batch)

    def eval_batch(self, step: int) -> dict:
        return synthetic.batch_at(self.cfg, step, self.global_batch,
                                  eval_split=True)


@dataclass
class JsonlSource:
    """Packs byte-tokenized documents into fixed-length rows (drop-remainder).
    The whole (small) corpus is materialized once; batches index a ring."""
    path: str
    seq_len: int
    global_batch: int
    rows: np.ndarray = field(init=False)

    def __post_init__(self):
        stream: list[int] = []
        with open(self.path) as f:
            for line in f:
                if not line.strip():
                    continue
                text = json.loads(line).get("text", "")
                stream.extend(tok.encode(text).tolist())
        if not stream:
            raise ValueError(
                f"{self.path}: no tokens — the corpus is empty (need at "
                f"least 1 token; {self.seq_len} fill one row)")
        if len(stream) < self.seq_len:
            # shorter than one row: pad the tail instead of crashing in the
            # reshape below (PAD rows are loss-masked out in batch_at)
            stream = stream + [tok.PAD] * (self.seq_len - len(stream))
        n = len(stream) // self.seq_len
        arr = np.asarray(stream[: n * self.seq_len], np.int32)
        self.rows = arr.reshape(n, self.seq_len)

    def batch_at(self, step: int) -> dict:
        n = self.rows.shape[0]
        idx = (np.arange(self.global_batch) + step * self.global_batch) % n
        toks = self.rows[idx]
        return {"tokens": toks,
                "loss_mask": (toks != tok.PAD).astype(np.float32)}


def host_local_slice(batch: dict, process_index: int, process_count: int) -> dict:
    """Slice a global batch to this host's rows (multi-host data feeding).
    The batch dimension must divide evenly — silently dropping trailing
    rows would make the global batch layout depend on process_count."""
    sizes = {k: v.shape[0] for k, v in batch.items()}
    bad = {k: b for k, b in sizes.items() if b % process_count}
    if bad:
        raise ValueError(
            f"host_local_slice: batch dim must be divisible by "
            f"process_count={process_count}, got {bad} — pad or resize the "
            f"global batch so every host feeds the same number of rows")

    def sl(x):
        per = x.shape[0] // process_count
        return x[process_index * per:(process_index + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


def make_source(kind: str, *, seq_len: int, global_batch: int, seed: int = 1234,
                path: str = "", digits: int = 3, pack: bool = True,
                num_records: int = 4096):
    """``synthetic_math`` / ``jsonl`` are the legacy pure-f(step) sources;
    ``jsonl_sft`` (prompt/completion lines) and ``packed_math`` (the
    synthetic corpus as variable-length records) return a streaming
    ``data.pipeline.SFTPipeline`` (packed unless ``pack=False``) whose
    cursor rides along in checkpoints."""
    if kind == "synthetic_math":
        return SyntheticMathSource(
            synthetic.MathTaskConfig(digits=digits, seq_len=seq_len, seed=seed),
            global_batch)
    if kind == "jsonl":
        return JsonlSource(path, seq_len, global_batch)
    if kind in ("jsonl_sft", "packed_math"):
        from repro.data import pipeline as pipe
        if kind == "jsonl_sft":
            source = pipe.JsonlSftRecords(path)
        else:
            source = pipe.SyntheticMathRecords(
                synthetic.MathTaskConfig(digits=digits, seq_len=seq_len,
                                         seed=seed),
                num_records=num_records)
        return pipe.SFTPipeline(source, seq_len=seq_len,
                                global_batch=global_batch, pack=pack)
    raise ValueError(kind)
