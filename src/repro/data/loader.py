"""Shard-aware, resumable data loading.

Two sources:
  * synthetic math (default; offline MetaMathQA proxy)   -- pure f(step)
  * jsonl documents, byte-tokenized and packed           -- pure f(step) over
    a pre-tokenized ring buffer

Both expose ``batch_at(step) -> {"tokens", "loss_mask"}`` as GLOBAL arrays;
the launcher device_puts them with the batch sharding (single-controller).
On multi-host deployments each process feeds its addressable slice via
``host_local_slice`` — the global batch layout (and hence training) is
identical either way, and resume-after-restart needs only the step counter.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from repro.data import synthetic
from repro.data import tokenizer as tok


@dataclass
class SyntheticMathSource:
    cfg: synthetic.MathTaskConfig
    global_batch: int

    def batch_at(self, step: int) -> dict:
        return synthetic.batch_at(self.cfg, step, self.global_batch)

    def eval_batch(self, step: int) -> dict:
        return synthetic.batch_at(self.cfg, step, self.global_batch,
                                  eval_split=True)


@dataclass
class JsonlSource:
    """Packs byte-tokenized documents into fixed-length rows (drop-remainder).
    The whole (small) corpus is materialized once; batches index a ring."""
    path: str
    seq_len: int
    global_batch: int
    rows: np.ndarray = field(init=False)

    def __post_init__(self):
        stream: list[int] = []
        with open(self.path) as f:
            for line in f:
                if not line.strip():
                    continue
                text = json.loads(line).get("text", "")
                stream.extend(tok.encode(text).tolist())
        n = max(1, len(stream) // self.seq_len)
        arr = np.asarray(stream[: n * self.seq_len], np.int32)
        self.rows = arr.reshape(n, self.seq_len)

    def batch_at(self, step: int) -> dict:
        n = self.rows.shape[0]
        idx = (np.arange(self.global_batch) + step * self.global_batch) % n
        toks = self.rows[idx]
        return {"tokens": toks,
                "loss_mask": (toks != tok.PAD).astype(np.float32)}


def host_local_slice(batch: dict, process_index: int, process_count: int) -> dict:
    """Slice a global batch to this host's rows (multi-host data feeding)."""
    def sl(x):
        per = x.shape[0] // process_count
        return x[process_index * per:(process_index + 1) * per]
    return {k: sl(v) for k, v in batch.items()}


def make_source(kind: str, *, seq_len: int, global_batch: int, seed: int = 1234,
                path: str = "", digits: int = 3):
    if kind == "synthetic_math":
        return SyntheticMathSource(
            synthetic.MathTaskConfig(digits=digits, seq_len=seq_len, seed=seed),
            global_batch)
    if kind == "jsonl":
        return JsonlSource(path, seq_len, global_batch)
    raise ValueError(kind)
