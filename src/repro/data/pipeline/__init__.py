"""Streaming SFT input pipeline: records -> packed [B, L] batches -> device.

The subsystem has three stages, each separately testable:

  records.py   RecordSource — variable-length prompt/completion records with
               deterministic random access (cursor = one integer)
  packing.py   greedy segment-aware packer (tokens / loss_mask /
               segment_ids / positions), pure in the cursor
  prefetch.py  background-thread batch build + device_put, ``depth`` ahead

``SFTPipeline`` ties them together behind the iterator seam the Trainer
consumes: ``batches()`` yields ``(host_batch, cursor_after)`` pairs computed
from a LOCAL copy of the cursor — generators (and the prefetcher running
them ahead) never mutate pipeline state, so read-ahead can overshoot freely.
The trainer commits consumption back via ``restore_cursor`` with the cursor
of the last batch it actually used; the same dict rides along checkpoints
(CheckpointManager meta) so a restored run resumes the record stream with no
skipped or repeated records.

Legacy ``batch_at(step)`` sources keep working: the trainer wraps them in
``StepIndexedAdapter`` (cursor IS the step counter, as before).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.data.pipeline import packing, records
from repro.data.pipeline.prefetch import Prefetcher
from repro.data.pipeline.records import (JsonlSftRecords, Record,
                                         RecordSource, SyntheticMathRecords)

__all__ = [
    "JsonlSftRecords", "Prefetcher", "Record", "RecordSource",
    "SFTPipeline", "StepIndexedAdapter", "SyntheticMathRecords",
    "packing", "records",
]


@dataclass
class SFTPipeline:
    """Streaming packed-batch producer over a RecordSource.

    ``pack=True``: greedy multi-segment packing (block-diagonal attention —
    the model consumes segment_ids/positions). ``pack=False``: one record
    per row, padded — the unpacked oracle layout with the same batch keys.
    """

    source: RecordSource
    seq_len: int
    global_batch: int
    pack: bool = True
    _cursor: int = field(default=0, init=False)

    # ------------------------------------------------------------ stream
    def build(self, cursor: int) -> tuple[dict, int]:
        """One batch from ``cursor`` — pure, the resume/prefetch primitive."""
        fn = packing.pack_batch if self.pack else packing.unpacked_batch
        return fn(self.source, cursor, self.global_batch, self.seq_len)

    def batches(self, steps: int | None = None):
        """Yield ``(host_batch, cursor_after)`` from the current committed
        cursor. Iterates a LOCAL cursor — pipeline state is only advanced by
        ``restore_cursor`` (the trainer commits what it consumed), so a
        prefetcher running this generator ``depth`` ahead is harmless."""
        local = self._cursor
        produced = 0
        while steps is None or produced < steps:
            batch, local = self.build(local)
            yield batch, {"record": local}
            produced += 1

    # ------------------------------------------------------------ cursor
    def cursor(self) -> dict:
        """Serializable stream position (checkpoint meta)."""
        return {"record": self._cursor}

    def restore_cursor(self, cursor: dict):
        self._cursor = int(cursor["record"])


@dataclass
class StepIndexedAdapter:
    """Iterator seam over a legacy pure-``f(step)`` source (SyntheticMath /
    Jsonl ring sources): the cursor is the step counter, exactly the
    pre-pipeline resume contract."""

    source: object  # anything with batch_at(step) -> dict
    start_step: int = 0

    def batches(self, steps: int | None = None):
        step = self.start_step
        while steps is None or step < self.start_step + steps:
            yield self.source.batch_at(step), {"step": step + 1}
            step += 1

    def cursor(self) -> dict:
        return {"step": self.start_step}

    def restore_cursor(self, cursor: dict):
        self.start_step = int(cursor.get("step", self.start_step))
