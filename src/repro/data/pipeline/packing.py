"""Greedy segment-aware sequence packing (MaxText-style decoder_segment_ids).

``pack_batch(source, cursor, B, L)`` consumes records from ``cursor`` and
fills ``[B, L]`` rows first-fit: a record that still fits the current row is
appended as the next *segment*; one that doesn't closes the row. No record is
split across rows (a record longer than L is truncated to its first L
tokens — the only token loss packing introduces). The function is PURE in
``cursor``: rebuilding a batch from the same cursor yields bit-identical
arrays and the same ``next_cursor``, which is what makes checkpoint resume
and async prefetch exact.

Batch layout (all [B, L]):
  tokens       i32, PAD-filled tails
  loss_mask    f32, 1.0 on completion tokens only
  segment_ids  i32, 1..n per row, 0 = padding
  positions    i32, restart at 0 at every segment start (RoPE sees each
               example at its unpacked positions)

Parity contract: with block-diagonal attention (attend only within equal
nonzero segment_ids, causal within a segment) and the reset positions, the
loss/gradients of a packed batch equal the per-example unpacked oracle
(``unpacked_batch`` with one record per row) — every cross-segment
next-token target lands on a segment's first token, which is loss-masked
(records.Record guarantees a non-empty prompt).
"""
from __future__ import annotations

import numpy as np

from repro.data import tokenizer as tok
from repro.data.pipeline.records import Record, RecordSource


def _record_arrays(rec: Record) -> tuple[np.ndarray, np.ndarray]:
    toks = np.concatenate([rec.prompt, rec.completion]).astype(np.int32)
    mask = np.concatenate([np.zeros(len(rec.prompt), np.float32),
                           np.ones(len(rec.completion), np.float32)])
    return toks, mask


def _empty_batch(batch_size: int, seq_len: int) -> dict:
    return {
        "tokens": np.full((batch_size, seq_len), tok.PAD, np.int32),
        "loss_mask": np.zeros((batch_size, seq_len), np.float32),
        "segment_ids": np.zeros((batch_size, seq_len), np.int32),
        "positions": np.zeros((batch_size, seq_len), np.int32),
    }


def _place(batch: dict, row: int, start: int, toks, mask, seg: int):
    ln = len(toks)
    batch["tokens"][row, start:start + ln] = toks
    batch["loss_mask"][row, start:start + ln] = mask
    batch["segment_ids"][row, start:start + ln] = seg
    batch["positions"][row, start:start + ln] = np.arange(ln)


def pack_batch(source: RecordSource, cursor: int, batch_size: int,
               seq_len: int) -> tuple[dict, int]:
    """Greedy first-fit packing. -> (batch, next_cursor). Pure in cursor."""
    n = source.num_records
    batch = _empty_batch(batch_size, seq_len)
    i = cursor
    for row in range(batch_size):
        used, seg = 0, 0
        while True:
            toks, mask = _record_arrays(source.record_at(i % n))
            ln = len(toks)
            if ln > seq_len:
                toks, mask, ln = toks[:seq_len], mask[:seq_len], seq_len
            if used + ln > seq_len:
                break  # doesn't fit — record opens the next row
            seg += 1
            _place(batch, row, used, toks, mask, seg)
            used += ln
            i += 1
            if used == seq_len:
                break
    return batch, i


def unpacked_batch(source: RecordSource, cursor: int, batch_size: int,
                   seq_len: int) -> tuple[dict, int]:
    """One record per row, padded to seq_len (the per-example oracle layout
    and the pack=False pipeline mode). Emits only ``tokens``/``loss_mask``
    — single-segment rows ARE the plain causal path (pads sit at the tail,
    behind every supervised token), so no segment keys are needed and the
    batch stays consumable by every architecture family (ssm/hybrid/vlm/
    MLA included), which packed batches are not."""
    n = source.num_records
    batch = _empty_batch(batch_size, seq_len)
    i = cursor
    for row in range(batch_size):
        toks, mask = _record_arrays(source.record_at(i % n))
        ln = min(len(toks), seq_len)
        _place(batch, row, 0, toks[:ln], mask[:ln], 1)
        i += 1
    return {"tokens": batch["tokens"], "loss_mask": batch["loss_mask"]}, i


# ------------------------------------------------------------- accounting


def packing_stats(source: RecordSource, seq_len: int,
                  batch_size: int) -> dict:
    """One-epoch packing-efficiency accounting (benchmarks/bench_data.py).

    ``*_kept``: fraction of the corpus' supervised (completion) tokens that
    train with their full example context intact —
      * packed: everything except truncation of records longer than L;
      * drop_remainder: the legacy concat-and-reshape layout
        (data/loader.JsonlSource) loses the reshape remainder AND corrupts
        every example straddling a row boundary (its context mixes the
        previous document);
      * unpacked: one example per row — tail truncation only.
    ``*_slot_util``: non-pad fraction of the [B, L] token slots actually
    emitted over the epoch (device-FLOP utilization of the layout).
    """
    n = source.num_records
    lens = np.array([len(source.record_at(i)) for i in range(n)])
    comp = np.array([len(source.record_at(i).completion) for i in range(n)])
    total_completion = int(comp.sum())
    total_tokens = int(lens.sum())

    # packed: walk one epoch through pack_batch
    packed_kept = 0
    packed_slots = packed_used = 0
    cur = 0
    while cur < n:
        batch, nxt = pack_batch(source, cur, batch_size, seq_len)
        for i in range(cur, min(nxt, n)):
            rec = source.record_at(i)
            if len(rec) <= seq_len:
                packed_kept += len(rec.completion)
            else:  # truncated: completion tokens within the first L survive
                packed_kept += max(0, seq_len - len(rec.prompt))
        packed_slots += batch["tokens"].size
        packed_used += int((batch["segment_ids"] != 0).sum())
        cur = nxt

    # drop-remainder: concatenate, reshape [*, L], drop the tail; an example
    # is intact iff it lies fully inside one row
    bounds = np.concatenate([[0], np.cumsum(lens)])
    kept_len = (total_tokens // seq_len) * seq_len
    drop_kept = 0
    for i in range(n):
        s, e = int(bounds[i]), int(bounds[i + 1])
        if e <= kept_len and s // seq_len == (e - 1) // seq_len:
            drop_kept += int(comp[i])

    # unpacked per-example rows: completion tokens that fit after the prompt
    unp_kept = int(sum(max(0, min(int(c), seq_len - int(ln - c)))
                       for ln, c in zip(lens, comp)))
    unp_rows = -(-n // batch_size) * batch_size
    unp_used = int(np.minimum(lens, seq_len).sum())

    denom = max(1, total_completion)
    return {
        "num_records": n,
        "corpus_tokens": total_tokens,
        "completion_tokens": total_completion,
        "packed_kept": packed_kept / denom,
        "drop_remainder_kept": drop_kept / denom,
        "unpacked_kept": unp_kept / denom,
        "packed_slot_util": packed_used / max(1, packed_slots),
        "unpacked_slot_util": unp_used / max(1, unp_rows * seq_len),
    }
