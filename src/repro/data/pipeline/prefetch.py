"""Async device prefetch: overlap host batch construction with device compute.

``Prefetcher`` drains a ``(host_batch, cursor_after)`` iterator on a
background thread, runs ``place`` (the trainer's device_put with the mesh
batch sharding) on each batch, and keeps up to ``depth`` placed batches in a
bounded queue. The train loop's ``next()`` then returns an already-resident
batch while the thread builds the next ones — host batch construction leaves
the critical path.

Determinism contract: the prefetcher only *reorders work in time*, never the
stream — batches come off the queue in exactly the order the iterator
produced them, and the iterator itself is a pure function of its starting
cursor (packing.pack_batch). Trajectories with prefetch on and off are
therefore bit-identical (pinned in tests/test_pipeline.py, single-device and
dp=8).

Error/shutdown semantics: exceptions in the worker are re-raised at the
consumer's next ``next()``; ``close()`` (or context-manager exit) unblocks
and joins the thread, so a crashed train loop never leaks a producer.
"""
from __future__ import annotations

import queue
import threading

from repro import obs

_DONE = object()


class Prefetcher:
    """Iterator over ``stream`` with ``depth`` batches built+placed ahead.

    ``depth == 0`` degrades to fully synchronous iteration (no thread) — the
    on/off switch is this one constructor argument, nothing else changes.
    """

    def __init__(self, stream, place=None, depth: int = 2):
        self._stream = iter(stream)
        self._place = place or (lambda x: x)
        self.depth = depth
        self._err: BaseException | None = None
        self._thread = None
        # prefetch-depth occupancy: how many placed batches were waiting
        # when the consumer arrived (depth sustained = producer keeps up)
        self._g_occupancy = obs.metrics.gauge("prefetch_occupancy",
                                              subsystem="data")
        self._c_batches = obs.metrics.counter("prefetch_batches",
                                              subsystem="data")
        if depth > 0:
            self._q: queue.Queue = queue.Queue(maxsize=depth)
            self._stop = threading.Event()
            self._thread = threading.Thread(target=self._work, daemon=True,
                                            name="data-prefetch")
            self._thread.start()

    # ------------------------------------------------------------ worker
    def _work(self):
        try:
            for batch, cursor in self._stream:
                placed = self._place(batch)
                while not self._stop.is_set():
                    try:
                        self._q.put((placed, cursor), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
        except BaseException as e:  # noqa: BLE001 — surfaced to consumer
            self._err = e
        finally:
            while not self._stop.is_set():
                try:
                    self._q.put(_DONE, timeout=0.1)
                    break
                except queue.Full:
                    continue

    # ---------------------------------------------------------- consumer
    def __iter__(self):
        return self

    def __next__(self):
        if self._thread is None:  # synchronous mode
            batch, cursor = next(self._stream)
            self._c_batches.inc()
            return self._place(batch), cursor
        self._g_occupancy.set(self._q.qsize())
        item = self._q.get()
        if item is _DONE:
            if self._err is not None:
                err, self._err = self._err, None
                raise err
            raise StopIteration
        self._c_batches.inc()
        return item

    def close(self):
        if self._thread is not None:
            self._stop.set()
            # drain so a blocked put() observes the stop event promptly
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join()
            self._thread = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
