"""Variable-length SFT record sources (the pipeline's input end).

A ``Record`` is one prompt/completion pair as token id arrays — *no padding,
no fixed length*. Sources expose deterministic random access
(``record_at(index)``) over a finite corpus; the stream position is therefore
a single integer **cursor** (record index, monotonically increasing across
epochs — ``record_at(cursor % num_records)``), which serializes into a
checkpoint and resumes the stream exactly (see pipeline.SFTPipeline).

Two concrete sources:

* ``SyntheticMathRecords`` — the offline MetaMathQA proxy as variable-length
  records (same problems as data/synthetic.py, but without seq_len padding,
  so the packer sees true lengths).
* ``JsonlSftRecords`` — real SFT corpora: one ``{"prompt": str,
  "completion": str}`` JSON object per line, byte-tokenized. The prompt is
  encoded with BOS (and no EOS), the completion with EOS (and no BOS), so a
  packed segment is ``BOS prompt... completion... EOS`` and always *starts*
  with a loss-masked token — the invariant that makes the packed loss equal
  the per-example oracle (a cross-segment next-token target is always
  masked).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable

import numpy as np

from repro.data import synthetic
from repro.data import tokenizer as tok


@dataclass(frozen=True)
class Record:
    """One SFT example. ``prompt`` tokens are context (loss-masked 0);
    ``completion`` tokens are supervised (loss-masked 1)."""
    prompt: np.ndarray       # [P] i32, P >= 1 (starts with BOS)
    completion: np.ndarray   # [C] i32

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(
                "Record.prompt must be non-empty (segments must start with a "
                "loss-masked token so packed cross-segment targets are "
                "masked; prepend BOS)")

    def __len__(self) -> int:
        return len(self.prompt) + len(self.completion)


@runtime_checkable
class RecordSource(Protocol):
    """Deterministic random access over a finite corpus of records.

    ``record_at(i)`` must be a pure function of ``i`` for 0 <= i <
    ``num_records`` — the pipeline wraps indices modulo ``num_records`` (an
    epoch) and resumes from a plain integer cursor."""

    num_records: int

    def record_at(self, index: int) -> Record: ...


@dataclass
class SyntheticMathRecords:
    """data/synthetic.py problems as variable-length records.

    ``num_records`` bounds the corpus (one epoch); problems themselves are a
    pure function of (seed, index) so any size is valid."""
    cfg: synthetic.MathTaskConfig
    num_records: int = 4096
    eval_split: bool = False

    def record_at(self, index: int) -> Record:
        if not 0 <= index < self.num_records:
            raise IndexError(index)
        base = self.cfg.eval_offset if self.eval_split else 0
        toks, mask = synthetic.sample_problem(self.cfg, base + index)
        # strip the fixed-length padding: true length = last supervised
        # token (the mask covers CoT + answer + EOS)
        end = int(np.max(np.nonzero(mask))) + 1
        p_len = synthetic.prompt_len(self.cfg)
        return Record(prompt=np.asarray(toks[:p_len], np.int32),
                      completion=np.asarray(toks[p_len:end], np.int32))


@dataclass
class JsonlSftRecords:
    """``{"prompt", "completion"}`` JSONL corpus, byte-tokenized and
    materialized once (SFT corpora are small; streaming decode stays an
    option behind the same protocol)."""
    path: str
    _records: list[Record] = field(init=False, repr=False)

    def __post_init__(self):
        self._records = []
        with open(self.path) as f:
            for ln, line in enumerate(f, 1):
                if not line.strip():
                    continue
                obj = json.loads(line)
                if "prompt" not in obj or "completion" not in obj:
                    raise ValueError(
                        f"{self.path}:{ln}: jsonl_sft records need "
                        f"'prompt' and 'completion' keys, got "
                        f"{sorted(obj)} (use --data jsonl for plain "
                        f"{{'text': ...}} document corpora)")
                self._records.append(Record(
                    prompt=tok.encode(obj["prompt"], add_bos=True,
                                      add_eos=False),
                    completion=tok.encode(obj["completion"], add_bos=False,
                                          add_eos=True)))
        if not self._records:
            raise ValueError(f"{self.path}: empty jsonl_sft corpus")

    @property
    def num_records(self) -> int:
        return len(self._records)

    def record_at(self, index: int) -> Record:
        return self._records[index]
