"""Synthetic math-reasoning dataset — the offline proxy for MetaMathQA-40K.

Problems are multi-digit additions with a column-by-column chain-of-thought
and a final answer, emitted as token sequences with a loss mask covering only
the completion (CoT + answer), mirroring instruction-tuning on MetaMathQA.
Everything is a pure function of (seed, index): the loader is resumable and
shard-deterministic by construction, and "GSM8K-style" eval is exact-match
on the answer digits under greedy decoding (paper §4.2 protocol).

Token space (fits any vocab >= 32):
  0 PAD  1 BOS  2 EOS  3 '+'  4 '='  5 STEP  6 CARRY  7 ANS  8.. digits 0-9
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

PAD, BOS, EOS, PLUS, EQ, STEP, CARRY, ANS = range(8)
D0 = 8  # token id of digit 0


@dataclass(frozen=True)
class MathTaskConfig:
    digits: int = 3          # fixed-width operands (leading zeros)
    seq_len: int = 64
    seed: int = 1234
    eval_offset: int = 1 << 30  # index offset separating train/eval streams


def _digits_of(x: int, width: int) -> list[int]:
    return [D0 + int(c) for c in str(x).zfill(width)]


def prompt_len(cfg: MathTaskConfig) -> int:
    # BOS a_digits + b_digits =
    return 1 + cfg.digits + 1 + cfg.digits + 1


def sample_problem(cfg: MathTaskConfig, index: int) -> tuple[np.ndarray, np.ndarray]:
    """-> (tokens [seq_len], loss_mask [seq_len]). Deterministic in index."""
    rng = np.random.default_rng((cfg.seed, index))
    hi = 10 ** cfg.digits
    a, b = int(rng.integers(0, hi)), int(rng.integers(0, hi))
    toks = [BOS] + _digits_of(a, cfg.digits) + [PLUS] + _digits_of(b, cfg.digits) + [EQ]
    p_len = len(toks)
    # chain of thought: per-column sums with an ALWAYS-PRESENT carry digit,
    # least significant first — every sequence has the same length, which
    # keeps per-microbatch loss-mask counts equal (exact grad accumulation)
    carry = 0
    da, db = str(a).zfill(cfg.digits)[::-1], str(b).zfill(cfg.digits)[::-1]
    for i in range(cfg.digits):
        s = int(da[i]) + int(db[i]) + carry
        toks += [D0 + int(da[i]), PLUS, D0 + int(db[i]), CARRY, D0 + carry,
                 EQ, D0 + s // 10, D0 + s % 10, STEP]
        carry = s // 10
    toks += [ANS] + _digits_of(a + b, cfg.digits + 1) + [EOS]
    if len(toks) > cfg.seq_len:
        raise ValueError(f"seq_len {cfg.seq_len} too short for digits={cfg.digits} "
                         f"(need {len(toks)})")
    mask = np.zeros(cfg.seq_len, np.float32)
    mask[p_len:len(toks)] = 1.0
    out = np.full(cfg.seq_len, PAD, np.int32)
    out[:len(toks)] = toks
    return out, mask


def batch_at(cfg: MathTaskConfig, step: int, batch_size: int,
             eval_split: bool = False) -> dict:
    """Global batch for a step — a pure function, so data resume after
    restart/rescale is exact (checkpoint stores only the step)."""
    base = step * batch_size + (cfg.eval_offset if eval_split else 0)
    toks, masks = zip(*(sample_problem(cfg, base + i) for i in range(batch_size)))
    return {"tokens": np.stack(toks), "loss_mask": np.stack(masks)}


def answer_of(cfg: MathTaskConfig, index: int, eval_split: bool = True) -> int:
    rng = np.random.default_rng((cfg.seed, (cfg.eval_offset if eval_split else 0) + index))
    hi = 10 ** cfg.digits
    a, b = int(rng.integers(0, hi)), int(rng.integers(0, hi))
    return a + b


def decode_answer(tokens: np.ndarray) -> int | None:
    """Parse the digits following the ANS token of a generated sequence."""
    toks = list(np.asarray(tokens))
    if ANS not in toks:
        return None
    i = toks.index(ANS) + 1
    digits = []
    while i < len(toks) and D0 <= toks[i] <= D0 + 9:
        digits.append(toks[i] - D0)
        i += 1
    if not digits:
        return None
    return int("".join(map(str, digits)))
