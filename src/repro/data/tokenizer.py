"""Byte-level tokenizer for the real-data (jsonl) path — no external deps.

ids: 0 PAD, 1 BOS, 2 EOS, 3..258 = bytes 0..255.
"""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 0, 1, 2
OFFSET = 3
VOCAB_SIZE = 256 + OFFSET


def encode(text: str, add_bos: bool = True, add_eos: bool = True) -> np.ndarray:
    b = list(text.encode("utf-8"))
    ids = ([BOS] if add_bos else []) + [x + OFFSET for x in b] + ([EOS] if add_eos else [])
    return np.asarray(ids, np.int32)


def decode(ids) -> str:
    bs = bytes(int(i) - OFFSET for i in ids if int(i) >= OFFSET)
    return bs.decode("utf-8", errors="replace")
