"""Gradient compression for the data-parallel all-reduce (beyond-paper
distributed-optimization feature; config: optimizer.grad_compression).

``compressed_psum``: shard_map helper that casts to bf16 before the psum and
keeps an f32 error-feedback buffer so the quantization error is re-injected
the next step (1-bit-Adam-style EF). Halves the DP collective bytes — the
effect is directly visible in the dry-run's collective-bytes term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_with_feedback(g, err):
    """-> (bf16 payload, new error). g, err: f32."""
    target = g + err
    q = target.astype(jnp.bfloat16)
    return q, target - q.astype(jnp.float32)


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_grad_sync(grads, err_state, mesh, axes=("data",)):
    """All-reduce (mean) gradients over the DP axes in bf16 with error
    feedback. grads: pytree of *per-shard* (unreduced) f32/bf16 grads laid
    out so the DP axes are unsharded dims; used inside shard_map train steps.
    Returns (synced f32 grads, new error state)."""
    def one(g, e):
        q, e2 = quantize_with_feedback(g.astype(jnp.float32), e)
        for ax in axes:
            q = jax.lax.pmean(q, ax)
        return q.astype(jnp.float32), e2

    flat = jax.tree.map(one, grads, err_state)
    g2 = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    e2 = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return g2, e2
