"""Elastic rescaling: move a TrainState between meshes of different sizes.

Restart-based elasticity (the production TPU pattern): on a membership
change the job restores the latest checkpoint onto the new mesh.
``reshard_state`` additionally supports live resharding when both meshes
are addressable from this process (used by tests and single-host runs).

The data pipeline is a pure function of the step, and selection state is
replicated, so rescaling only requires resharding arrays and (optionally)
re-chunking the global batch — training is bitwise-continuable as long as
the global batch stays fixed.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding


def reshard_state(state, new_shardings):
    """Pull to host, re-place onto the new mesh's shardings."""
    host = jax.tree.map(np.asarray, jax.device_get(state))
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if isinstance(s, NamedSharding)
        else jax.device_put(x), host, new_shardings)


def validate_rescale(old_mesh_shape: tuple, new_mesh_shape: tuple,
                     global_batch: int) -> None:
    """Invariants for a safe rescale: the global batch must stay divisible
    by the new DP degree (model math is unaffected by the mesh change)."""
    new_dp = int(np.prod(new_mesh_shape[:-1]))
    if global_batch % new_dp:
        raise ValueError(
            f"global_batch {global_batch} not divisible by new DP degree "
            f"{new_dp} (mesh {new_mesh_shape}); adjust batch or mesh")
