"""Sharding rules: parameter/optimizer/batch PartitionSpecs per architecture.

Strategy (DESIGN.md 3.4):
  * DP   — batch over ("pod","data")
  * TP   — q-heads over "model" (uneven dims allowed — GSPMD pads), kv
           replicated unless KVH divides the model axis; FFN hidden over
           "model"; vocab/embedding over "model"
  * EP   — MoE expert dim over "model" (shard_map all_to_all inside the layer)
  * SSM  — d_inner/head channels over "model"
  * ZeRO-1 — optimizer moments additionally sharded over "data" on the first
           divisible dim (offload="zero1")

Rules key off canonical leaf paths (utils.trees.path_str) so the same table
covers every family.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils.trees import tree_map_with_path


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """``jax.shard_map`` across jax lines: the >=0.6 line takes
    ``check_vma``, older lines spell it ``check_rep`` (and pre-promotion
    only ship ``jax.experimental.shard_map``). Every in-repo shard_map goes
    through here so the repo runs on both."""
    import inspect
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:  # pragma: no cover - older jax line
        from jax.experimental.shard_map import shard_map as sm
    kw = ("check_vma" if "check_vma" in inspect.signature(sm).parameters
          else "check_rep")
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check})


def _model_dim(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def param_spec(cfg: ModelConfig, path: str, shape: tuple, m: int) -> P:
    """PartitionSpec for one parameter leaf. ``m`` = model-axis size."""
    base = path.split("/")[-1]
    stacked = path.split("/")[0].endswith("layers")
    nd = len(shape)

    def spec(*parts):
        # prepend None for the stacked layer axis
        parts = ((None,) + parts) if stacked else parts
        parts = parts + (None,) * (nd - len(parts))
        return P(*parts[:nd])

    # ---- embeddings / heads
    if path.startswith("embed/"):
        return P("model", None)
    if path.startswith("lm_head/"):
        return P(None, "model")

    # ---- norms, scalars, biases on heads
    if base in ("scale", "A_log", "D", "dt_bias", "conv_b"):
        return spec()
    # ---- attention projections
    if base == "wq":
        return spec(None, "model")          # [.., D, H, Dh]
    if base in ("wk", "wv"):
        kvh = shape[-2]
        return spec(None, "model") if kvh % m == 0 else spec()
    if base in ("bq",):
        return spec("model")
    if base in ("bk", "bv"):
        kvh = shape[-2] if nd >= (2 + (1 if stacked else 0)) else shape[0]
        return spec("model") if kvh % m == 0 else spec()
    if base == "wo":
        return spec("model")                # [.., H, Dh, D] row-parallel
    # ---- MLA
    if base == "wq_a":
        return spec()                       # [D, qr] small, replicate
    if base == "wq_b":
        return spec(None, "model")          # [qr, H, nd+rd]
    if base == "wkv_a":
        return spec()
    if base in ("wk_b", "wv_b"):
        return spec(None, "model")          # [kvr, H, d]
    # ---- MoE
    if "moe" in path.split("/"):
        if base == "router":
            return spec()
        if base in ("wg", "wu", "wd") and "shared" not in path:
            return spec(tuple(cfg.ep_axes))  # experts over the EP plane
        # shared expert: like dense mlp
        if base in ("wg", "wu"):
            return spec(None, "model")
        if base == "wd":
            return spec("model", None)
    # ---- dense MLP
    if base in ("wg", "wu"):
        return spec(None, "model")          # [D, F]
    if base == "wd":
        return spec("model", None)          # [F, D]
    # ---- SSM (split projections; channel dims shard-aligned with heads)
    if base in ("proj_z", "proj_x", "proj_b", "proj_c", "proj_dt"):
        return spec(None, "model")          # [D, channels]
    if base in ("conv_x", "conv_b_mat", "conv_c_mat"):
        return spec(None, "model")          # [K, channels]
    if base in ("cbias_x", "cbias_b", "cbias_c"):
        return spec("model")
    if base == "out_proj":
        return spec("model", None)          # [d_inner, D]
    # ---- MTP projection and anything else
    return spec()


def param_specs(cfg: ModelConfig, params_shapes, mesh):
    m = _model_dim(mesh)
    return tree_map_with_path(
        lambda path, leaf: param_spec(cfg, path, leaf.shape, m), params_shapes)


def param_shardings(cfg: ModelConfig, params_shapes, mesh):
    specs = param_specs(cfg, params_shapes, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh) -> P:
    return P(batch_axes_of(mesh))


def canonical_spec(spec: P) -> P:
    """Strip trailing ``None`` entries: ``P(None,)`` and ``P()`` describe
    the same placement but compare unequal, and a jitted step whose output
    constraint normalizes differently from the initial ``device_put`` would
    recompile on its second call. Canonicalize wherever specs feed a
    sharding that round-trips through a compiled step."""
    parts = list(spec)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def mesh_canonical_spec(spec: P, mesh) -> P:
    """``canonical_spec`` plus dropping axes of mesh size 1: on a pure-DP
    mesh ``P(None, "model")`` places identically to ``P()`` and jax's
    sharding normalization inside jit reflects that — placements built from
    the verbatim rule table would mismatch the step's constrained outputs
    and break compile-once. Single-element tuples collapse to the axis."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    keep = lambda ax: sizes.get(ax, 1) > 1  # noqa: E731
    parts = []
    for pt in spec:
        if isinstance(pt, tuple):
            kept = tuple(a for a in pt if keep(a))
            parts.append(kept[0] if len(kept) == 1 else (kept or None))
        else:
            parts.append(pt if (pt is None or keep(pt)) else None)
    return canonical_spec(P(*parts))


def apply_zero1(specs, params_shapes, mesh, data_axis: str = "data"):
    """Moment specs: additionally shard the first dim that is (a) unsharded
    and (b) divisible by the data-axis size. Falls back to the param spec."""
    d = dict(zip(mesh.axis_names, mesh.devices.shape))[data_axis]

    def one(path, leaf, spec):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for pt in parts:
            for ax in (pt if isinstance(pt, tuple) else (pt,)):
                if ax:
                    used.add(ax)
        if data_axis in used:   # e.g. 2D-EP expert weights already use data
            return spec
        for i, (dim, pt) in enumerate(zip(leaf.shape, parts)):
            if pt is None and dim % d == 0 and dim >= d:
                parts[i] = data_axis
                return canonical_spec(P(*parts))
        return spec

    return tree_map_with_path(one, params_shapes, specs)


def sds_with_sharding(shapes, shardings):
    """Attach shardings to a ShapeDtypeStruct pytree (dry-run inputs)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


# ------------------------------------------------- banked-store ZeRO-1 layout

# Marker used in sharding trees for leaves that intentionally live in host
# RAM as numpy (the banked slot_map and a "host"-policy full store): tree-
# congruent with the TrainState, never device_put. String (not None) so
# pytree mapping over (state, shardings) stays structurally exact.
HOST_RESIDENT = "host"


def data_axis_size(mesh, data_axis: str = "data") -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[data_axis]


def store_specs(partition, store_shapes: dict, mesh,
                data_axis: str = "data") -> dict:
    """ZeRO-1 PartitionSpecs for the banked optimizer's full backing store
    (``core.offload.init_full_store`` under ``offload == "zero1"``).

    Stacked groups shard the leading block axis over ``data`` — each device
    owns ``1/dp`` of the store rows, and the selection-boundary swap
    (``masked_adamw.swap_banked``) only touches the shard(s) holding the
    evicted/admitted block ids. When the block axis does not divide the dp
    degree (or for unstacked groups, where the whole leaf is one block), the
    first divisible dim is sharded instead; fully indivisible leaves stay
    replicated. ``slot_map`` stays host-global: every process plans the same
    swap from the same [num_blocks] vector.
    """
    d = data_axis_size(mesh, data_axis)

    def leaf_spec(stacked: bool, leaf) -> P:
        shape = tuple(leaf.shape)
        start = 0
        if stacked and shape and shape[0] % d == 0:
            return P(data_axis)
        if stacked:
            start = 1  # never split the block axis unevenly
        for i in range(start, len(shape)):
            if shape[i] % d == 0 and shape[i] >= d:
                return P(*((None,) * i + (data_axis,)))
        return P()

    return {g.key: jax.tree.map(lambda leaf, s=g.stacked: leaf_spec(s, leaf),
                                store_shapes[g.key])
            for g in partition.groups}


def store_shardings(partition, store_shapes: dict, mesh,
                    data_axis: str = "data") -> dict:
    specs = store_specs(partition, store_shapes, mesh, data_axis)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
