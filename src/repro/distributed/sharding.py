"""Sharding rules: parameter/optimizer/batch PartitionSpecs per architecture.

Strategy (DESIGN.md 3.4):
  * DP   — batch over ("pod","data")
  * TP   — q-heads over "model" (uneven dims allowed — GSPMD pads), kv
           replicated unless KVH divides the model axis; FFN hidden over
           "model"; vocab/embedding over "model"
  * EP   — MoE expert dim over "model" (shard_map all_to_all inside the layer)
  * SSM  — d_inner/head channels over "model"
  * ZeRO-1 — optimizer moments additionally sharded over "data" on the first
           divisible dim (offload="zero1")

Rules key off canonical leaf paths (utils.trees.path_str) so the same table
covers every family.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils.trees import tree_map_with_path


def _model_dim(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def param_spec(cfg: ModelConfig, path: str, shape: tuple, m: int) -> P:
    """PartitionSpec for one parameter leaf. ``m`` = model-axis size."""
    base = path.split("/")[-1]
    stacked = path.split("/")[0].endswith("layers")
    nd = len(shape)

    def spec(*parts):
        # prepend None for the stacked layer axis
        parts = ((None,) + parts) if stacked else parts
        parts = parts + (None,) * (nd - len(parts))
        return P(*parts[:nd])

    # ---- embeddings / heads
    if path.startswith("embed/"):
        return P("model", None)
    if path.startswith("lm_head/"):
        return P(None, "model")

    # ---- norms, scalars, biases on heads
    if base in ("scale", "A_log", "D", "dt_bias", "conv_b"):
        return spec()
    # ---- attention projections
    if base == "wq":
        return spec(None, "model")          # [.., D, H, Dh]
    if base in ("wk", "wv"):
        kvh = shape[-2]
        return spec(None, "model") if kvh % m == 0 else spec()
    if base in ("bq",):
        return spec("model")
    if base in ("bk", "bv"):
        kvh = shape[-2] if nd >= (2 + (1 if stacked else 0)) else shape[0]
        return spec("model") if kvh % m == 0 else spec()
    if base == "wo":
        return spec("model")                # [.., H, Dh, D] row-parallel
    # ---- MLA
    if base == "wq_a":
        return spec()                       # [D, qr] small, replicate
    if base == "wq_b":
        return spec(None, "model")          # [qr, H, nd+rd]
    if base == "wkv_a":
        return spec()
    if base in ("wk_b", "wv_b"):
        return spec(None, "model")          # [kvr, H, d]
    # ---- MoE
    if "moe" in path.split("/"):
        if base == "router":
            return spec()
        if base in ("wg", "wu", "wd") and "shared" not in path:
            return spec(tuple(cfg.ep_axes))  # experts over the EP plane
        # shared expert: like dense mlp
        if base in ("wg", "wu"):
            return spec(None, "model")
        if base == "wd":
            return spec("model", None)
    # ---- dense MLP
    if base in ("wg", "wu"):
        return spec(None, "model")          # [D, F]
    if base == "wd":
        return spec("model", None)          # [F, D]
    # ---- SSM (split projections; channel dims shard-aligned with heads)
    if base in ("proj_z", "proj_x", "proj_b", "proj_c", "proj_dt"):
        return spec(None, "model")          # [D, channels]
    if base in ("conv_x", "conv_b_mat", "conv_c_mat"):
        return spec(None, "model")          # [K, channels]
    if base in ("cbias_x", "cbias_b", "cbias_c"):
        return spec("model")
    if base == "out_proj":
        return spec("model", None)          # [d_inner, D]
    # ---- MTP projection and anything else
    return spec()


def param_specs(cfg: ModelConfig, params_shapes, mesh):
    m = _model_dim(mesh)
    return tree_map_with_path(
        lambda path, leaf: param_spec(cfg, path, leaf.shape, m), params_shapes)


def param_shardings(cfg: ModelConfig, params_shapes, mesh):
    specs = param_specs(cfg, params_shapes, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh) -> P:
    return P(batch_axes_of(mesh))


def apply_zero1(specs, params_shapes, mesh, data_axis: str = "data"):
    """Moment specs: additionally shard the first dim that is (a) unsharded
    and (b) divisible by the data-axis size. Falls back to the param spec."""
    d = dict(zip(mesh.axis_names, mesh.devices.shape))[data_axis]

    def one(path, leaf, spec):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for pt in parts:
            for ax in (pt if isinstance(pt, tuple) else (pt,)):
                if ax:
                    used.add(ax)
        if data_axis in used:   # e.g. 2D-EP expert weights already use data
            return spec
        for i, (dim, pt) in enumerate(zip(leaf.shape, parts)):
            if pt is None and dim % d == 0 and dim >= d:
                parts[i] = data_axis
                return P(*parts)
        return spec

    return tree_map_with_path(one, params_shapes, specs)


def sds_with_sharding(shapes, shardings):
    """Attach shardings to a ShapeDtypeStruct pytree (dry-run inputs)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
