"""Fused per-block gradient sum-of-squares (paper Alg. 1 lines 1-6).

The selection hot-spot: without fusion, computing per-block norms costs one
extra HBM pass over every gradient leaf. The kernel streams a stacked
[L, R] gradient once through VMEM in 128-lane-aligned tiles, keeping one
f32 partial per layer in VMEM scratch and writing it out on the last chunk.

Grid: (L, R / CHUNK) — the chunk axis is innermost (sequential on TPU), so
the accumulator legally carries across the chunks of one layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 2048  # 16 sublanes x 128 lanes of f32 per tile


def _kernel(g_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[0, 0] = 0.0

    g = g_ref[...].astype(jnp.float32)
    acc_ref[0, 0] += jnp.sum(g * g)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _done():
        o_ref[0] = acc_ref[0, 0]


def block_grad_sq_norms(g2d: jax.Array, *, interpret: bool = True) -> jax.Array:
    """g2d: [L, R] (R padded to CHUNK by ops.py) -> [L] f32 sum of squares."""
    l, r = g2d.shape
    assert r % CHUNK == 0, (r, CHUNK)
    return pl.pallas_call(
        _kernel,
        grid=(l, r // CHUNK),
        in_specs=[pl.BlockSpec((1, CHUNK), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(g2d)
