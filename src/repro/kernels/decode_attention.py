"""Flash-decoding: one query token against a long KV cache.

Layout: q [BH, D], k/v [BH, S, D] (GQA expanded outside, like
flash_attention.py). Grid (BH, S/BK) with the KV-block axis innermost
(sequential), carrying online-softmax stats (m, l, acc) in VMEM scratch —
a single pass over the cache at HBM bandwidth, which is the roofline for
decode. ``valid_len`` masks unwritten cache slots; it may be a per-row
vector so continuous-batching slots at mixed progress each attend over
their own cache length.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bk, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[0, 0] = NEG_INF
        l_ref[0, 0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [D]
    k = k_ref[0].astype(jnp.float32)                  # [BK, D]
    v = v_ref[0].astype(jnp.float32)
    s = k @ q                                         # [BK]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)
    m_prev, l_prev = m_ref[0, 0], l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                            # [BK]
    alpha = jnp.exp(m_prev - m_new)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_prev * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + (p @ v)[None, :]

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        o_ref[0] = (acc_ref[0] / jnp.maximum(l_ref[0, 0], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention(q, k, v, valid_len, *, bk=DEFAULT_BK, interpret=True):
    """q: [BH, D]; k, v: [BH, S, D]; valid_len: scalar i32 or [BH] i32
    vector (per-row valid cache length) -> o [BH, D]."""
    bh, s, d = k.shape
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    scale = d ** -0.5
    vlen = jnp.asarray(valid_len, jnp.int32)
    if vlen.ndim == 0:
        vlen = jnp.full((bh,), vlen, jnp.int32)
    assert vlen.shape == (bh,), (vlen.shape, bh)
    return pl.pallas_call(
        partial(_kernel, bk=bk, scale=scale),
        grid=(bh, s // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(vlen, q, k, v)


# ------------------------------------------------------------ paged variant
#
# Same online-softmax pass, but the KV cache lives in a shared page pool
# ([num_pages, page_size, KVH, D] per layer) and each batch row reads its
# pages through a scalar-prefetched page table: the KV block for grid step
# (b, h, j) is pool page ``table[b, j]`` at kv head ``hmap[h]`` — the page
# gather happens in the BlockSpec index map, so the dense [B, S, ...] view
# is never materialized. GQA needs no head expansion of the pool either
# (the dense kernel requires pre-expanded [BH, S, D] k/v); one pool page
# serves every query head of its kv group.


def _paged_kernel(tbl_ref, hm_ref, vlen_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, ps, scale):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[0, 0] = NEG_INF
        l_ref[0, 0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [D]
    k = k_ref[0, :, 0].astype(jnp.float32)            # [ps, D]
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = k @ q                                         # [ps]
    # virtual position of page-slot i within this row's cache; positions at
    # or past valid_len are masked, which also neutralizes sentinel table
    # entries (allocated pages always cover [0, valid_len))
    kpos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (ps,), 0)
    s = jnp.where(kpos < vlen_ref[0], s, NEG_INF)
    m_prev, l_prev = m_ref[0, 0], l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_prev * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + (p @ v)[None, :]

    @pl.when(j == pl.num_programs(2) - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[0] / jnp.maximum(l_ref[0, 0], 1e-30)
                       ).astype(o_ref.dtype)


def paged_decode_attention(q, k_pool, v_pool, page_tables, valid_len, hmap,
                           *, interpret=True):
    """q: [B, H, D]; k_pool/v_pool: [num_pages, page_size, KVH, D];
    page_tables: [B, max_pages] i32 pool page ids (entries >= num_pages mark
    unallocated slots — clamped for the fetch, masked by ``valid_len``);
    valid_len: [B] i32 per-row cache length; hmap: [H] i32 q-head -> kv-head
    map -> o [B, H, D].

    Grid (B, H, max_pages) with the page axis innermost (sequential online
    softmax, like the dense kernel); page/table indirection happens in the
    BlockSpec index maps via scalar prefetch."""
    b, h, d = q.shape
    num_pages, ps, kvh, dk = k_pool.shape
    assert dk == d, (dk, d)
    maxp = page_tables.shape[1]
    assert page_tables.shape == (b, maxp), (page_tables.shape, b)
    scale = d ** -0.5
    vlen = jnp.asarray(valid_len, jnp.int32)
    if vlen.ndim == 0:
        vlen = jnp.full((b,), vlen, jnp.int32)
    assert vlen.shape == (b,), (vlen.shape, b)
    tbl = jnp.asarray(page_tables, jnp.int32)
    hm = jnp.asarray(hmap, jnp.int32)
    assert hm.shape == (h,), (hm.shape, h)

    def page_of(bi, hi, j, tbl_ref, hm_ref):
        return (jnp.minimum(tbl_ref[bi, j], num_pages - 1), 0,
                hm_ref[hi], 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, maxp),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, j, t, m: (bi,)),
            pl.BlockSpec((1, 1, d), lambda bi, hi, j, t, m: (bi, hi, 0)),
            pl.BlockSpec((1, ps, 1, d), page_of),
            pl.BlockSpec((1, ps, 1, d), page_of),
        ],
        out_specs=pl.BlockSpec((1, 1, d),
                               lambda bi, hi, j, t, m: (bi, hi, 0)),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)])
    return pl.pallas_call(
        partial(_paged_kernel, ps=ps, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(tbl, hm, vlen, q, k_pool, v_pool)
