"""Flash-decoding: one query token against a long KV cache.

Layout: q [BH, D], k/v [BH, S, D] (GQA expanded outside, like
flash_attention.py). Grid (BH, S/BK) with the KV-block axis innermost
(sequential), carrying online-softmax stats (m, l, acc) in VMEM scratch —
a single pass over the cache at HBM bandwidth, which is the roofline for
decode. ``valid_len`` masks unwritten cache slots; it may be a per-row
vector so continuous-batching slots at mixed progress each attend over
their own cache length.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bk, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[0, 0] = NEG_INF
        l_ref[0, 0] = 0.0
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [D]
    k = k_ref[0].astype(jnp.float32)                  # [BK, D]
    v = v_ref[0].astype(jnp.float32)
    s = k @ q                                         # [BK]
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)
    m_prev, l_prev = m_ref[0, 0], l_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    p = jnp.exp(s - m_new)                            # [BK]
    alpha = jnp.exp(m_prev - m_new)
    m_ref[0, 0] = m_new
    l_ref[0, 0] = l_prev * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + (p @ v)[None, :]

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        o_ref[0] = (acc_ref[0] / jnp.maximum(l_ref[0, 0], 1e-30)
                    ).astype(o_ref.dtype)


def decode_attention(q, k, v, valid_len, *, bk=DEFAULT_BK, interpret=True):
    """q: [BH, D]; k, v: [BH, S, D]; valid_len: scalar i32 or [BH] i32
    vector (per-row valid cache length) -> o [BH, D]."""
    bh, s, d = k.shape
    bk = min(bk, s)
    assert s % bk == 0, (s, bk)
    scale = d ** -0.5
    vlen = jnp.asarray(valid_len, jnp.int32)
    if vlen.ndim == 0:
        vlen = jnp.full((bh,), vlen, jnp.int32)
    assert vlen.shape == (bh,), (vlen.shape, bh)
    return pl.pallas_call(
        partial(_kernel, bk=bk, scale=scale),
        grid=(bh, s // bk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, d), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(vlen, q, k, v)
