"""Causal flash attention (fwd + bwd) — FlashAttention-2 schedule on TPU.

Layout: [B*H, S, D] (GQA is gather-expanded to MHA outside the kernel, on
the model-sharded head axis — see models/layers/attention_core.py).

Forward   grid (BH, S/BQ):  online-softmax over K blocks held in VMEM one
          BK-tile at a time; saves LSE for the backward.
Backward  two kernels (the standard split to keep accumulation orders
          grid-sequential):
            dq:   grid (BH, S/BQ), inner loop over K blocks
            dkv:  grid (BH, S/BK), inner loop over Q blocks
          probs are rematerialized from q, k and the saved LSE.

Tiles default to (BQ, BK) = (128, 128) with D padded to a lane multiple —
MXU-aligned and ~(3*128*D + 128*128)*4 bytes of VMEM working set.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


# ----------------------------------------------------------------- forward


def _fwd_kernel(q_ref, k_ref, v_ref, *rest, bq, bk, scale,
                causal, segmented=False):
    if segmented:
        qseg_ref, kseg_ref, o_ref, lse_ref = rest
    else:
        o_ref, lse_ref = rest
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # [BQ, D]
    d = q.shape[-1]
    nk = pl.num_programs(1) * 0 + (k_ref.shape[1] // bk)

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)   # [BK, D]
        v = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        s = q @ k.T                                               # [BQ, BK]
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if segmented:
            qs = qseg_ref[0]                                      # [BQ] f32
            ks = kseg_ref[0, pl.ds(j * bk, bk)]                   # [BK] f32
            s = jnp.where(qs[:, None] == ks[None, :], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        if segmented:
            # a k block can be FULLY masked for a row (cross-segment), so
            # m_new may still be NEG_INF and exp(s - m_new) would be 1 —
            # zero masked entries explicitly (a no-op when m_new is real:
            # exp(NEG_INF - m_new) already underflows to 0)
            p = jnp.where(s > 0.5 * NEG_INF, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    # causal: only K blocks with j*bk <= (qi+1)*bq - 1 contribute
    upper = jnp.minimum(nk, (qi + 1) * bq // bk) if causal else nk
    m, lsum, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, acc0))
    lsum = jnp.maximum(lsum, 1e-30)
    o_ref[0] = (acc / lsum[:, None]).astype(o_ref.dtype)
    lse_ref[0] = (m + jnp.log(lsum)).astype(jnp.float32)


def flash_attention_fwd(q, k, v, q_seg=None, k_seg=None, *, bq=DEFAULT_BQ,
                        bk=DEFAULT_BK, causal=True, interpret=True):
    """q,k,v: [BH, S, D] -> (o [BH, S, D], lse [BH, S]).
    q_seg/k_seg: optional [BH, S] f32 packed segment ids (block-diagonal
    attention; both or neither)."""
    bh, s, d = q.shape
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    segmented = q_seg is not None
    scale = d ** -0.5
    kern = partial(_fwd_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
                   segmented=segmented)
    in_specs = [
        pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
        pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
    ]
    args = [q, k, v]
    if segmented:
        in_specs += [pl.BlockSpec((1, bq), lambda b, i: (b, i)),
                     pl.BlockSpec((1, s), lambda b, i: (b, 0))]
        args += [q_seg.astype(jnp.float32), k_seg.astype(jnp.float32)]
    return pl.pallas_call(
        kern,
        grid=(bh, s // bq),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
                   pl.BlockSpec((1, bq), lambda b, i: (b, i))),
        out_shape=(jax.ShapeDtypeStruct((bh, s, d), q.dtype),
                   jax.ShapeDtypeStruct((bh, s), jnp.float32)),
        interpret=interpret,
    )(*args)


# ----------------------------------------------------------------- backward


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, *rest,
                   bq, bk, scale, causal, segmented=False):
    if segmented:
        qseg_ref, kseg_ref, dq_ref = rest
    else:
        (dq_ref,) = rest
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                   # [BQ, D]
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                                   # [BQ]
    delta = delta_ref[0]                               # [BQ] = rowsum(do*o)
    d = q.shape[-1]
    nk = k_ref.shape[1] // bk

    def body(j, dq):
        k = k_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * bk, bk), :].astype(jnp.float32)
        s = (q @ k.T) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if segmented:
            qs = qseg_ref[0]
            ks = kseg_ref[0, pl.ds(j * bk, bk)]
            s = jnp.where(qs[:, None] == ks[None, :], s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                  # [BQ, BK]
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        return dq + ds @ k

    upper = jnp.minimum(nk, (qi + 1) * bq // bk) if causal else nk
    dq = jax.lax.fori_loop(0, upper, body,
                           jnp.zeros((bq, d), jnp.float32))
    dq_ref[0] = dq.astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    *rest, bq, bk, scale, causal, segmented=False):
    if segmented:
        qseg_ref, kseg_ref, dk_ref, dv_ref = rest
    else:
        dk_ref, dv_ref = rest
    ki = pl.program_id(1)
    k = k_ref[0].astype(jnp.float32)                   # [BK, D]
    v = v_ref[0].astype(jnp.float32)
    d = k.shape[-1]
    nq = q_ref.shape[1] // bq

    def body(i, carry):
        dk, dv = carry
        q = q_ref[0, pl.ds(i * bq, bq), :].astype(jnp.float32)
        do = do_ref[0, pl.ds(i * bq, bq), :].astype(jnp.float32)
        lse = lse_ref[0, pl.ds(i * bq, bq)]
        delta = delta_ref[0, pl.ds(i * bq, bq)]
        s = (q @ k.T) * scale                          # [BQ, BK]
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if segmented:
            qs = qseg_ref[0, pl.ds(i * bq, bq)]
            ks = kseg_ref[0]
            s = jnp.where(qs[:, None] == ks[None, :], s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dv = dv + p.T @ do
        dp = do @ v.T
        ds = p * (dp - delta[:, None]) * scale
        return dk + ds.T @ q, dv

    lower = (ki * bk) // bq if causal else 0
    dk0 = jnp.zeros((bk, d), jnp.float32)
    dv0 = jnp.zeros((bk, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(lower, nq, body, (dk0, dv0))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, q_seg=None, k_seg=None, *,
                        bq=DEFAULT_BQ, bk=DEFAULT_BK, causal=True,
                        interpret=True):
    bh, s, d = q.shape
    segmented = q_seg is not None
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    scale = d ** -0.5
    seg_args = ()
    dq_seg_specs, dkv_seg_specs = [], []
    if segmented:
        seg_args = (q_seg.astype(jnp.float32), k_seg.astype(jnp.float32))
        dq_seg_specs = [pl.BlockSpec((1, bq), lambda b, i: (b, i)),
                        pl.BlockSpec((1, s), lambda b, i: (b, 0))]
        dkv_seg_specs = [pl.BlockSpec((1, s), lambda b, j: (b, 0)),
                         pl.BlockSpec((1, bk), lambda b, j: (b, j))]
    dq = pl.pallas_call(
        partial(_bwd_dq_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
                segmented=segmented),
        grid=(bh, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i: (b, i)),
        ] + dq_seg_specs,
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta, *seg_args)
    dk, dv = pl.pallas_call(
        partial(_bwd_dkv_kernel, bq=bq, bk=bk, scale=scale, causal=causal,
                segmented=segmented),
        grid=(bh, s // bk),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, s, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, s), lambda b, j: (b, 0)),
            pl.BlockSpec((1, s), lambda b, j: (b, 0)),
        ] + dkv_seg_specs,
        out_specs=(pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0)),
                   pl.BlockSpec((1, bk, d), lambda b, j: (b, j, 0))),
        out_shape=(jax.ShapeDtypeStruct((bh, s, d), k.dtype),
                   jax.ShapeDtypeStruct((bh, s, d), v.dtype)),
        interpret=interpret,
    )(q, k, v, do, lse, delta, *seg_args)
    return dq, dk, dv


# ------------------------------------------------------------- public op


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal=True, bq=DEFAULT_BQ, bk=DEFAULT_BK,
                    interpret=True):
    o, _ = flash_attention_fwd(q, k, v, bq=bq, bk=bk, causal=causal,
                               interpret=interpret)
    return o


def _vjp_fwd(q, k, v, causal, bq, bk, interpret):
    o, lse = flash_attention_fwd(q, k, v, bq=bq, bk=bk, causal=causal,
                                 interpret=interpret)
    return o, (q, k, v, o, lse)


def _vjp_bwd(causal, bq, bk, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, bq=bq, bk=bk,
                                     causal=causal, interpret=interpret)
    return dq, dk, dv


flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def flash_attention_segmented(q, k, v, q_seg, k_seg, causal=True,
                              bq=DEFAULT_BQ, bk=DEFAULT_BK, interpret=True):
    """Segment-masked flash attention for packed batches. q_seg/k_seg:
    [BH, S] segment ids as f32 (integers cast to float — exact for any
    realistic segment count); attention is restricted to equal-segment
    pairs. The ids ride through the custom_vjp as ordinary (zero-gradient)
    operands so callers can differentiate wrt q/k/v as usual."""
    o, _ = flash_attention_fwd(q, k, v, q_seg, k_seg, bq=bq, bk=bk,
                               causal=causal, interpret=interpret)
    return o


def _vjp_seg_fwd(q, k, v, q_seg, k_seg, causal, bq, bk, interpret):
    o, lse = flash_attention_fwd(q, k, v, q_seg, k_seg, bq=bq, bk=bk,
                                 causal=causal, interpret=interpret)
    return o, (q, k, v, o, lse, q_seg, k_seg)


def _vjp_seg_bwd(causal, bq, bk, interpret, res, do):
    q, k, v, o, lse, q_seg, k_seg = res
    dq, dk, dv = flash_attention_bwd(q, k, v, o, lse, do, q_seg, k_seg,
                                     bq=bq, bk=bk, causal=causal,
                                     interpret=interpret)
    return dq, dk, dv, jnp.zeros_like(q_seg), jnp.zeros_like(k_seg)


flash_attention_segmented.defvjp(_vjp_seg_fwd, _vjp_seg_bwd)
