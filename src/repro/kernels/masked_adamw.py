"""Fused block-masked AdamW update (paper Alg. 1 lines 9-13 + moments).

The optimizer step is purely memory-bound (reads p, g, m, v; writes p, m, v
= ~36 bytes/param at bf16 params + f32 moments). The unfused XLA form
materializes m-hat/v-hat intermediates; this kernel does the whole masked
update in one VMEM pass. The per-block mask and bias-correction count enter
as per-layer (1, 1) blocks.

Grid: (L, R / CHUNK) over stacked [L, R] leaves.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 2048


def _kernel(lr_ref, b1_ref, b2_ref, eps_ref, wd_ref,
            p_ref, g_ref, m_ref, v_ref, sel_ref, cnt_ref,
            po_ref, mo_ref, vo_ref):
    lr, b1, b2 = lr_ref[0], b1_ref[0], b2_ref[0]
    eps, wd = eps_ref[0], wd_ref[0]
    sel = sel_ref[0, 0] > 0
    c = jnp.maximum(cnt_ref[0, 0], 1.0)
    g = g_ref[...].astype(jnp.float32)
    m, v = m_ref[...], v_ref[...]
    p = p_ref[...].astype(jnp.float32)
    m2 = jnp.where(sel, b1 * m + (1 - b1) * g, m)
    v2 = jnp.where(sel, b2 * v + (1 - b2) * g * g, v)
    mhat = m2 / (1 - b1 ** c)
    vhat = v2 / (1 - b2 ** c)
    step = lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    po_ref[...] = jnp.where(sel, p - step, p).astype(po_ref.dtype)
    mo_ref[...] = m2
    vo_ref[...] = v2


def masked_adamw(p, g, m, v, sel, counts, lr, b1, b2, eps, wd, *,
                 interpret: bool = True):
    """p,g: [L, R] (param dtype); m,v: [L, R] f32; sel, counts: [L] f32;
    lr: scalar (traced). Returns (p', m', v')."""
    l, r = p.shape
    assert r % CHUNK == 0, (r, CHUNK)
    scalars = [jnp.asarray(x, jnp.float32).reshape(1)
               for x in (lr, b1, b2, eps, wd)]
    sel2 = sel.astype(jnp.float32).reshape(l, 1)
    cnt2 = counts.astype(jnp.float32).reshape(l, 1)
    grid = (l, r // CHUNK)
    data_spec = pl.BlockSpec((1, CHUNK), lambda i, j: (i, j))
    lspec = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    sspec = pl.BlockSpec((1,), lambda i, j: (0,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[sspec] * 5 + [data_spec] * 4 + [lspec, lspec],
        out_specs=(data_spec, data_spec, data_spec),
        out_shape=(jax.ShapeDtypeStruct((l, r), p.dtype),
                   jax.ShapeDtypeStruct((l, r), jnp.float32),
                   jax.ShapeDtypeStruct((l, r), jnp.float32)),
        interpret=interpret,
    )(*scalars, p, g, m, v, sel2, cnt2)
