"""Fused block-masked AdamW update (paper Alg. 1 lines 9-13 + moments).

The optimizer step is purely memory-bound (reads p, g, m, v; writes p, m, v
= ~36 bytes/param at bf16 params + f32 moments). The unfused XLA form
materializes m-hat/v-hat intermediates; this kernel does the whole masked
update in one VMEM pass. The per-block mask and bias-correction count enter
as per-layer (1, 1) blocks.

Grid: (L, R / CHUNK) over stacked [L, R] leaves.

``banked_masked_adamw`` is the banked-residency variant (paper §3.3): the
moments are compact [cap]-row banks and the parameter/gradient rows are
addressed *through the [cap] slots vector* with scalar prefetch
(``PrefetchScalarGridSpec``) — the grid walks bank rows and the p/g index
maps dereference ``slots[i]`` to pick the full-leaf row, so the former
``gather_rows -> masked_adamw -> scatter_rows`` chain collapses into one
kernel and the two materialized [cap, R] copies of p and g disappear.
Sentinel slots (``slots[i] >= L``, unfilled bank rows) are clamped to a
real row for the fetch and neutralized by ``sel == 0`` (the masked update
is the identity there); the compact p output for those rows is dropped by
the caller's ``scatter_rows(..., mode="drop")``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 2048


def _kernel(lr_ref, b1_ref, b2_ref, eps_ref, wd_ref,
            p_ref, g_ref, m_ref, v_ref, sel_ref, cnt_ref,
            po_ref, mo_ref, vo_ref):
    lr, b1, b2 = lr_ref[0], b1_ref[0], b2_ref[0]
    eps, wd = eps_ref[0], wd_ref[0]
    sel = sel_ref[0, 0] > 0
    c = jnp.maximum(cnt_ref[0, 0], 1.0)
    g = g_ref[...].astype(jnp.float32)
    m, v = m_ref[...], v_ref[...]
    p = p_ref[...].astype(jnp.float32)
    m2 = jnp.where(sel, b1 * m + (1 - b1) * g, m)
    v2 = jnp.where(sel, b2 * v + (1 - b2) * g * g, v)
    mhat = m2 / (1 - b1 ** c)
    vhat = v2 / (1 - b2 ** c)
    step = lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    po_ref[...] = jnp.where(sel, p - step, p).astype(po_ref.dtype)
    mo_ref[...] = m2
    vo_ref[...] = v2


def masked_adamw(p, g, m, v, sel, counts, lr, b1, b2, eps, wd, *,
                 interpret: bool = True):
    """p,g: [L, R] (param dtype); m,v: [L, R] f32; sel, counts: [L] f32;
    lr: scalar (traced). Returns (p', m', v')."""
    l, r = p.shape
    assert r % CHUNK == 0, (r, CHUNK)
    scalars = [jnp.asarray(x, jnp.float32).reshape(1)
               for x in (lr, b1, b2, eps, wd)]
    sel2 = sel.astype(jnp.float32).reshape(l, 1)
    cnt2 = counts.astype(jnp.float32).reshape(l, 1)
    grid = (l, r // CHUNK)
    data_spec = pl.BlockSpec((1, CHUNK), lambda i, j: (i, j))
    lspec = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    sspec = pl.BlockSpec((1,), lambda i, j: (0,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[sspec] * 5 + [data_spec] * 4 + [lspec, lspec],
        out_specs=(data_spec, data_spec, data_spec),
        out_shape=(jax.ShapeDtypeStruct((l, r), p.dtype),
                   jax.ShapeDtypeStruct((l, r), jnp.float32),
                   jax.ShapeDtypeStruct((l, r), jnp.float32)),
        interpret=interpret,
    )(*scalars, p, g, m, v, sel2, cnt2)


def _banked_kernel(slots_ref, lr_ref, b1_ref, b2_ref, eps_ref, wd_ref,
                   p_ref, g_ref, m_ref, v_ref, sel_ref, cnt_ref,
                   po_ref, mo_ref, vo_ref):
    # identical arithmetic to _kernel; the slots vector only steers the p/g
    # BlockSpec index maps (scalar prefetch), it is never read in the body.
    del slots_ref
    lr, b1, b2 = lr_ref[0], b1_ref[0], b2_ref[0]
    eps, wd = eps_ref[0], wd_ref[0]
    sel = sel_ref[0, 0] > 0
    c = jnp.maximum(cnt_ref[0, 0], 1.0)
    g = g_ref[...].astype(jnp.float32)
    m, v = m_ref[...], v_ref[...]
    p = p_ref[...].astype(jnp.float32)
    m2 = jnp.where(sel, b1 * m + (1 - b1) * g, m)
    v2 = jnp.where(sel, b2 * v + (1 - b2) * g * g, v)
    mhat = m2 / (1 - b1 ** c)
    vhat = v2 / (1 - b2 ** c)
    step = lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    po_ref[...] = jnp.where(sel, p - step, p).astype(po_ref.dtype)
    mo_ref[...] = m2
    vo_ref[...] = v2


def banked_masked_adamw(p, g, m, v, slots, sel, counts,
                        lr, b1, b2, eps, wd, *, interpret: bool = True):
    """Fused gather -> masked AdamW -> (compact) update over bank rows.

    p, g: [L, R] full stacked leaves; m, v: [cap, R] f32 moment banks;
    slots: [cap] i32 bank->leaf row map (``>= L`` marks an unfilled slot);
    sel, counts: [cap] per-slot (sel must already be 0 for sentinel slots).
    Returns (p_rows' [cap, R], m' [cap, R], v' [cap, R]) — the caller
    scatters p_rows' back with drop-mode OOB semantics. Grid walks
    (cap, R/CHUNK); p/g blocks are addressed via ``slots[i]`` through
    scalar prefetch, sentinels clamped to row L-1 (fetch-only: sel == 0
    makes the update an identity and the scatter drops the row)."""
    l, r = p.shape
    cap = m.shape[0]
    assert r % CHUNK == 0, (r, CHUNK)
    scalars = [jnp.asarray(x, jnp.float32).reshape(1)
               for x in (lr, b1, b2, eps, wd)]
    sel2 = sel.astype(jnp.float32).reshape(cap, 1)
    cnt2 = counts.astype(jnp.float32).reshape(cap, 1)
    grid = (cap, r // CHUNK)
    row_spec = pl.BlockSpec((1, CHUNK),
                            lambda i, j, s: (jnp.minimum(s[i], l - 1), j))
    bank_spec = pl.BlockSpec((1, CHUNK), lambda i, j, s: (i, j))
    lspec = pl.BlockSpec((1, 1), lambda i, j, s: (i, 0))
    sspec = pl.BlockSpec((1,), lambda i, j, s: (0,))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[sspec] * 5 + [row_spec] * 2 + [bank_spec] * 2
                 + [lspec, lspec],
        out_specs=(bank_spec, bank_spec, bank_spec))
    return pl.pallas_call(
        _banked_kernel,
        grid_spec=grid_spec,
        out_shape=(jax.ShapeDtypeStruct((cap, r), p.dtype),
                   jax.ShapeDtypeStruct((cap, r), jnp.float32),
                   jax.ShapeDtypeStruct((cap, r), jnp.float32)),
        interpret=interpret,
    )(jnp.asarray(slots, jnp.int32), *scalars, p, g, m, v, sel2, cnt2)
