"""Jit-ready wrappers around the Pallas kernels.

Handle shape normalization (flatten/pad to kernel layouts) and backend
dispatch: ``interpret=True`` on CPU (validation), compiled Mosaic on TPU.
The model layers call these when cfg.use_pallas resolves truthy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import block_grad_norm as _bgn
from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import masked_adamw as _ma
from repro.kernels import rmsnorm as _rn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_flat(x: jax.Array, chunk: int) -> jax.Array:
    """[L, ...] -> [L, R] with R padded up to a multiple of ``chunk``."""
    nl = x.shape[0]
    flat = x.reshape(nl, -1)
    r = flat.shape[1]
    pad = (-r) % chunk
    if pad:
        flat = jnp.pad(flat, [(0, 0), (0, pad)])
    return flat


def block_grad_sq_norms(g: jax.Array) -> jax.Array:
    """g: [L, ...] stacked gradient leaf -> [L] f32 sum of squares."""
    flat = _pad_flat(g, _bgn.CHUNK)
    return _bgn.block_grad_sq_norms(flat, interpret=_interpret())


def masked_adamw(p, g, m, v, sel, counts, lr, b1, b2, eps, wd):
    """Leaf-shaped masked AdamW. p,g,m,v: [L, ...]; sel/counts broadcastable
    [L,1,..] or [L]. Returns (p', m', v') in original shapes."""
    shape = p.shape
    nl = shape[0]
    sel1 = sel.reshape(nl)
    cnt1 = counts.reshape(nl)
    pf, gf = _pad_flat(p, _ma.CHUNK), _pad_flat(g, _ma.CHUNK)
    mf, vf = _pad_flat(m, _ma.CHUNK), _pad_flat(v, _ma.CHUNK)
    r_orig = 1
    for d in shape[1:]:
        r_orig *= d
    p2, m2, v2 = _ma.masked_adamw(pf, gf, mf, vf, sel1, cnt1, lr, b1, b2,
                                  eps, wd, interpret=_interpret())
    unpad = lambda t: t[:, :r_orig].reshape(shape)  # noqa: E731
    return unpad(p2), m2[:, :r_orig].reshape(shape), v2[:, :r_orig].reshape(shape)


def banked_masked_adamw(p, g, m, v, slots, sel, counts, lr, b1, b2, eps, wd):
    """Banked (slot-indexed) masked AdamW. p, g: [L, ...] full stacked
    leaves; m, v: [cap, ...] moment banks; slots/sel/counts: [cap] (sel == 0
    on sentinel slots). Returns (p_rows', m', v') in bank shape [cap, ...] —
    scatter p_rows' back into the leaf with drop-mode semantics. The kernel
    reads p/g rows through the slots vector (scalar prefetch), so no
    [cap, ...] gather of p or g is ever materialized."""
    shape = p.shape
    cap = m.shape[0]
    sel1 = sel.reshape(cap)
    cnt1 = counts.reshape(cap)
    pf, gf = _pad_flat(p, _ma.CHUNK), _pad_flat(g, _ma.CHUNK)
    mf, vf = _pad_flat(m, _ma.CHUNK), _pad_flat(v, _ma.CHUNK)
    r_orig = 1
    for d in shape[1:]:
        r_orig *= d
    p2, m2, v2 = _ma.banked_masked_adamw(pf, gf, mf, vf, slots, sel1, cnt1,
                                         lr, b1, b2, eps, wd,
                                         interpret=_interpret())
    bank_shape = (cap,) + shape[1:]
    unpad = lambda t: t[:, :r_orig].reshape(bank_shape)  # noqa: E731
    return unpad(p2), unpad(m2), unpad(v2)


def flash_attention(q, k, v, *, causal=True, segment_ids=None):
    """q,k,v: [B, S, H, D] (layer layout; kv already head-expanded) ->
    [B, S, H, D]. ``segment_ids``: optional [B, S] packed segment ids
    (0 = pad) — attention is block-diagonal over equal segments (the
    segment-masked kernel; ids are repeated over the folded head axis)."""
    b, s, h, d = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)  # noqa: E731
    bq = min(_fa.DEFAULT_BQ, s)
    bk = min(_fa.DEFAULT_BK, s)
    if segment_ids is None:
        o = _fa.flash_attention(fold(q), fold(k), fold(v), causal, bq, bk,
                                _interpret())
    else:
        seg = jnp.repeat(jnp.asarray(segment_ids, jnp.float32), h, axis=0)
        o = _fa.flash_attention_segmented(fold(q), fold(k), fold(v), seg,
                                          seg, causal, bq, bk, _interpret())
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def decode_attention(q, k, v, valid_len):
    """q: [B, 1, H, D]; k,v: [B, S, H, D] (head-expanded cache);
    valid_len: scalar i32 or per-row [B] vector -> [B, 1, H, D]."""
    b, s, h, d = k.shape
    qf = q.reshape(b, h, d).reshape(b * h, d)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, d)  # noqa: E731
    vl = jnp.asarray(valid_len, jnp.int32)
    if vl.ndim:  # [B] -> [B*H], b-major to match the head fold
        vl = jnp.repeat(vl, h)
    o = _dec.decode_attention(qf, fold(k), fold(v), vl,
                              interpret=_interpret())
    return o.reshape(b, 1, h, d)


def paged_decode_attention(q, k_pool, v_pool, page_tables, valid_len, hmap):
    """q: [B, 1, H, D]; k_pool/v_pool: [num_pages, page_size, KVH, D] shared
    pools; page_tables: [B, max_pages] i32; valid_len: [B] i32; hmap: [H]
    q-head -> kv-head map -> [B, 1, H, D]. Unlike the dense wrapper no
    head-expanded [B, S, H, D] view is ever built — the kernel reads pool
    pages through the table and kv heads through hmap."""
    b, _, h, d = q.shape
    o = _dec.paged_decode_attention(q.reshape(b, h, d), k_pool, v_pool,
                                    page_tables, valid_len,
                                    jnp.asarray(hmap, jnp.int32),
                                    interpret=_interpret())
    return o.reshape(b, 1, h, d)


def rmsnorm(x, scale, eps=1e-5):
    """x: [..., D] -> fused RMSNorm over the trailing dim."""
    shape = x.shape
    n = 1
    for d in shape[:-1]:
        n *= d
    flat = x.reshape(n, shape[-1])
    rows = _rn.DEFAULT_ROWS
    while n % rows:
        rows //= 2
    out = _rn.rmsnorm(flat, scale, eps, rows=max(rows, 1),
                      interpret=_interpret())
    return out.reshape(shape)
