"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_grad_sq_norms(g: jax.Array) -> jax.Array:
    """g: [L, ...] -> [L] sum of squares over non-leading axes (f32)."""
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf, axis=tuple(range(1, gf.ndim)))


def masked_adamw(p, g, m, v, sel, counts, lr, b1, b2, eps, wd):
    """p,g,m,v: [L, R]; sel, counts: [L] (counts = post-increment per-block
    step). Returns (p', m', v') with the masked-AdamW semantics of
    core/masked_adamw.py."""
    gf = g.astype(jnp.float32)
    selb = (sel > 0)[:, None]
    m2 = jnp.where(selb, b1 * m + (1 - b1) * gf, m)
    v2 = jnp.where(selb, b2 * v + (1 - b2) * gf * gf, v)
    c = jnp.maximum(counts, 1.0)[:, None]
    mhat = m2 / (1 - b1 ** c)
    vhat = v2 / (1 - b2 ** c)
    pf = p.astype(jnp.float32)
    step = lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * pf)
    p2 = jnp.where(selb, pf - step, pf)
    return p2.astype(p.dtype), m2, v2


def flash_attention(q, k, v, *, causal=True, segment_ids=None):
    """q,k,v: [B, H, S, D] (MHA layout) -> [B, H, S, D]. f32 softmax.
    ``segment_ids``: optional [B, S] packed segment ids — block-diagonal
    masking (attend only within equal segments)."""
    s = q.shape[2]
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
    if segment_ids is not None:
        seg_ok = (segment_ids[:, None, :, None]
                  == segment_ids[:, None, None, :])
        scores = jnp.where(seg_ok, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q, k, v, valid_len):
    """q: [B, H, D]; k,v: [B, H, S, D]; valid_len: scalar or per-row [B]
    vector — masked single-query attention."""
    s = k.shape[2]
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    vl = jnp.asarray(valid_len)
    if vl.ndim:
        mask = jnp.arange(s)[None, None, :] < vl[:, None, None]
    else:
        mask = (jnp.arange(s) < vl)[None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhk,bhkd->bhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_decode_attention(q, k_pool, v_pool, page_tables, valid_len, hmap):
    """q: [B, H, D]; k_pool/v_pool: [num_pages, page_size, KVH, D];
    page_tables: [B, max_pages] i32 (entries >= num_pages are unallocated
    sentinels: clamped for the gather, masked by valid_len); hmap: [H] i32
    q-head -> kv-head map. Gathers the pool into the dense per-row view and
    defers to the dense oracle."""
    b = q.shape[0]
    num_pages, ps, kvh, d = k_pool.shape
    tbl = jnp.minimum(jnp.asarray(page_tables, jnp.int32), num_pages - 1)
    maxp = tbl.shape[1]
    dense = lambda pool: pool[tbl].reshape(b, maxp * ps, kvh, d)  # noqa: E731
    hm = jnp.asarray(hmap)
    kd = dense(k_pool)[:, :, hm, :].transpose(0, 2, 1, 3)  # [B, H, S, D]
    vd = dense(v_pool)[:, :, hm, :].transpose(0, 2, 1, 3)
    return decode_attention(q, kd, vd, jnp.asarray(valid_len))


def rmsnorm(x, scale, eps=1e-5):
    """x: [N, D]; scale: [D]."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
