"""Fused RMSNorm forward: one VMEM pass per row tile (f32 statistics)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROWS = 8


def _kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                # [R, D]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, scale, eps=1e-5, *, rows=DEFAULT_ROWS, interpret=True):
    """x: [N, D]; scale: [D] -> [N, D]. N must be divisible by ``rows``."""
    n, d = x.shape
    rows = min(rows, n)
    assert n % rows == 0, (n, rows)
    return pl.pallas_call(
        partial(_kernel, eps=eps),
        grid=(n // rows,),
        in_specs=[pl.BlockSpec((rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
