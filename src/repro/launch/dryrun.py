import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
derive roofline terms — no device allocation (ShapeDtypeStruct inputs only).

The XLA_FLAGS assignment above MUST run before any other import (jax locks
the device count on first init); smoke tests and benches import repro
normally and see 1 device.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k \
      --mesh single --out results/
  python -m repro.launch.dryrun --all --mesh single --out results/
Mesh names: single = (16,16) ("data","model");  multi = (2,16,16)
("pod","data","model");  tiny = (2,4) (tests; set REPRO_DRYRUN_DEVICES=8).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ALL_ARCHS, get_config, get_shape, shapes_for  # noqa: E402
from repro.configs.base import OptimizerConfig, SelectConfig  # noqa: E402
from repro.distributed.sharding import batch_axes_of  # noqa: E402
from repro.launch import roofline as roofline_mod  # noqa: E402
from repro.launch import specs as specs_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh, make_mesh, mesh_config  # noqa: E402
from repro.models import registry  # noqa: E402


def lower_cell(cfg, shape, mesh, *, opt_offload="zero1", microbatch=0,
               moment_dtype="float32", train_method="adagradselect",
               extra_desc=""):
    """-> (lowered, compiled, meta) for one (arch, shape, mesh) cell.

    Production train defaults: ZeRO-1 moment sharding over the data axis
    (the TPU-native equivalent of the paper's 3.3 host offload — see
    core/offload.py) and microbatch gradient accumulation sized so the
    per-layer activation residency fits HBM. ``train_method`` picks the
    fine-tuning method from the repro.methods registry (selection family
    only — the SDS layout follows the masked-AdamW TrainState).
    """
    model = registry.get(cfg)
    baxes = batch_axes_of(mesh)
    batch_sds = specs_mod.data_batch_specs(cfg, shape, mesh)
    if microbatch == 0 and shape.kind == "train":
        microbatch = 8 if cfg.num_experts >= 64 else 4

    if shape.kind == "train":
        from repro import methods
        from repro.configs.base import TrainConfig
        sel_cfg = SelectConfig(k_percent=20.0)
        opt_cfg = OptimizerConfig(offload=opt_offload, microbatch=microbatch,
                                  moment_dtype=moment_dtype)
        method = methods.build(train_method, TrainConfig(
            model=cfg, select=sel_cfg, optimizer=opt_cfg))
        method_sel = getattr(method, "sel_cfg", None)
        if method_sel is None:
            raise ValueError(
                f"--train-method {train_method!r} is not a selection-family "
                f"method; the dry-run's TrainState SDS layout only covers "
                f"masked-AdamW methods (full/adagradselect/topk_grad/random/"
                f"lisa/grass)")
        state_sds = specs_mod.train_state_sds(
            cfg, mesh, opt_offload, moment_dtype, policy=method_sel.policy)
        fn = method.make_step(cfg, opt_cfg, mesh=mesh, batch_axes=baxes,
                              donate=True)
        with mesh:
            lowered = fn.lower(state_sds, batch_sds)
        meta_mem = _opt_memory(cfg, method, method_sel, state_sds)
    elif shape.kind == "prefill":
        p_sds, _ = specs_mod.params_sds(cfg, mesh)
        max_len = shape.seq_len

        def prefill(params, batch):
            return model.prefill(params, cfg, batch, max_len, mesh=mesh,
                                 batch_axes=baxes)

        with mesh:
            lowered = jax.jit(prefill).lower(p_sds, batch_sds)
    else:  # decode
        p_sds, _ = specs_mod.params_sds(cfg, mesh)
        gb, _ = specs_mod.batch_dims(cfg, shape)
        cache_sds = specs_mod.cache_specs(cfg, mesh, shape.global_batch,
                                          shape.seq_len)

        def serve_step(params, tokens, cache):
            return model.decode_step(params, cfg, tokens, cache, mesh=mesh,
                                     batch_axes=baxes)

        with mesh:
            lowered = jax.jit(serve_step, donate_argnums=(2,)).lower(
                p_sds, batch_sds["tokens"], cache_sds)

    t0 = time.time()
    compiled = lowered.compile()
    meta = {"compile_s": time.time() - t0}
    if shape.kind == "train":
        meta["opt_memory"] = meta_mem
    return lowered, compiled, meta


def _opt_memory(cfg, method, sel_cfg, state_sds) -> dict:
    """Optimizer-state memory for one train cell: the deterministic §3.3
    model (2 * P_sel * B) next to the *measured* column — jax.eval_shape
    accounting of the actual TrainState — plus the banked-residency
    projection (compact [k]-slot device banks, core/masked_adamw)."""
    from repro.core import masked_adamw, offload
    from repro.core.partition import build_partition
    from repro.utils.trees import tree_bytes

    partition = build_partition(cfg)
    rep = offload.optimizer_memory_report(
        partition, state_sds["params"], sel_cfg.k_percent,
        opt_state=state_sds["opt"])
    cap = method.slot_capacity(cfg)
    banked = jax.eval_shape(
        lambda p: masked_adamw.init_banked_opt_state(partition, p, cap,
                                                     store_policy=None),
        state_sds["params"])
    return {
        "model_full_bytes": rep.mem_full,
        "model_selective_bytes": rep.mem_selective,
        "model_pct_reduction": rep.pct_reduction,
        "measured_bytes": rep.mem_measured_device + rep.mem_measured_host,
        "banked_resident_bytes": tree_bytes(banked),
        # store<->bank traffic of one worst-case selection-change boundary
        # (full slot turnover: k admissions streamed in + k evictions
        # written back = 2 directions x m+v of the k largest blocks). This
        # is the per-interval transfer the async swap planner hides behind
        # compute; amortize over the policy's reselection interval for
        # bytes/step.
        "swap_bytes_per_interval": 2 * rep.mem_selective,
    }


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             opt_offload="zero1", microbatch=0, moment_dtype="float32",
             train_method="adagradselect", verbose=True, cfg_override=None,
             hlo_dir=None):
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    if mesh_name == "multi":
        mesh = make_production_mesh(multi_pod=True)
    elif mesh_name == "single":
        mesh = make_production_mesh(multi_pod=False)
    else:
        mesh = make_mesh(mesh_config(mesh_name))
    n_dev = mesh.devices.size

    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "num_devices": int(n_dev), "kind": shape.kind,
              "opt_offload": opt_offload, "status": "ok"}
    try:
        lowered, compiled, meta = lower_cell(cfg, shape, mesh,
                                             opt_offload=opt_offload,
                                             microbatch=microbatch,
                                             moment_dtype=moment_dtype,
                                             train_method=train_method)
        ma = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # jax<0.6 returns [dict]
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        if hlo_dir:
            import gzip
            os.makedirs(hlo_dir, exist_ok=True)
            tag = f"{arch}_{shape_name}_{mesh_name}".replace("/", "_")
            with gzip.open(os.path.join(hlo_dir, f"{tag}.hlo.gz"), "wt") as f:
                f.write(hlo)
        mf = roofline_mod.model_flops(cfg, shape)
        rf = roofline_mod.analyze(cost, hlo, n_dev, model_flops_total=mf)
        result.update(meta)
        result["xla_cost_analysis"] = {  # undercounts scans; for reference
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        from repro.launch.hlo_cost import analyze_text as _at
        result["top_ops"] = _at(hlo, n_dev).summarize(8)
        result["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "host_argument_bytes": ma.host_argument_size_in_bytes,
            # live-buffer peak per device: args + temps (aliased outputs
            # reuse argument space)
            "peak_per_device": ma.argument_size_in_bytes + ma.temp_size_in_bytes,
        }
        result["roofline"] = rf.as_dict()
        if verbose:
            peak_gb = result["memory"]["peak_per_device"] / (1 << 30)
            print(f"[{arch} | {shape_name} | {mesh_name}] ok "
                  f"compile={meta['compile_s']:.1f}s peak={peak_gb:.2f}GiB/dev "
                  f"compute={rf.compute_s*1e3:.2f}ms memory={rf.memory_s*1e3:.2f}ms "
                  f"collective={rf.collective_s*1e3:.2f}ms -> {rf.bottleneck}")
            om = result.get("opt_memory")
            if om:
                gb = 1 << 30
                print(f"    opt-state: model 2PB={om['model_selective_bytes']/gb:.2f}GiB "
                      f"(full {om['model_full_bytes']/gb:.2f}GiB, "
                      f"-{om['model_pct_reduction']:.0f}%) "
                      f"measured={om['measured_bytes']/gb:.2f}GiB "
                      f"banked-resident={om['banked_resident_bytes']/gb:.2f}GiB "
                      f"swap/interval="
                      f"{om['swap_bytes_per_interval']/gb:.2f}GiB")
    except Exception as e:  # noqa: BLE001 — report failures per-cell
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} | {shape_name} | {mesh_name}] FAILED: "
                  f"{result['error']}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "tiny"])
    ap.add_argument("--offload", default="zero1",
                    choices=["none", "host", "zero1"])
    ap.add_argument("--microbatch", type=int, default=0,
                    help="0 = per-arch default (4; MoE 8)")
    ap.add_argument("--train-method", default="adagradselect",
                    help="fine-tuning method for train cells "
                         "(repro.methods registry, selection family)")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch x shape) cell")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ALL_ARCHS[:10]:
            cfg = get_config(arch)
            for shape in shapes_for(cfg):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape_name in cells:
        res = run_cell(arch, shape_name, args.mesh, opt_offload=args.offload,
                       microbatch=args.microbatch,
                       train_method=args.train_method,
                       hlo_dir=os.path.join(args.out, "hlo"))
        results.append(res)
        tag = f"{arch}_{shape_name}_{args.mesh}" + \
              (f"_{args.offload}" if args.offload != "zero1" else "")
        with open(os.path.join(args.out, f"dryrun_{tag}.json"), "w") as f:
            json.dump(res, f, indent=2)
    ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{ok}/{len(results)} cells OK")
    return 0 if ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
