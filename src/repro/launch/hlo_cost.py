"""Trip-count-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` visits every computation ONCE — a lax.scan over
61 layers reports 1/61st of the real FLOPs (verified; see EXPERIMENTS.md
§Dry-run). Since the whole framework scans over layers/chunks/microbatches,
we derive roofline terms from the partitioned HLO text instead, walking the
call graph with while-loop trip counts:

  flops       — 2 * |out| * contraction for every dot, x enclosing trips
  bytes       — per memory-op (fusions: params + outputs; the XLA definition
                of bytes-accessed for a fused kernel), x enclosing trips
  collectives — ring-model link bytes per op kind, x enclosing trips

Trip counts come from the scan's canonical while condition
(`compare(iter, constant(N)), direction=LT`). ``conditional`` takes the max
across branches (runtime executes one). This is an estimate — layout copies
and overlap are not modeled — but unlike cost_analysis it is *structurally*
correct for scanned programs; both numbers are recorded in the dry-run JSON.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}
_SHAPE_RE = re.compile(r"([a-z]\w*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition|true_computation|"
                    r"false_computation)=%([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "call", "conditional", "after-all",
               "reshape", "iota", "partition-id", "replica-id"}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    calls: list[str]
    branches: list[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # value name -> type str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        # computation headers start at column 0 and end with "{"
        m = (_COMP_HDR.match(line)
             if line and not line[0].isspace() and line.rstrip().endswith("{")
             else None)
        if m and ("->" in line):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, type_str, op = mi.group(1), mi.group(2), mi.group(3)
        rest = line[mi.end():]
        head = rest.split(")", 1)[0]
        operands = _OPERANDS.findall(head)
        calls = _CALLS.findall(line)
        br = _BRANCHES.search(line)
        branches = _OPERANDS.findall(br.group(1)) if br else []
        cur.instrs.append(Instr(name, type_str, op, operands, calls + branches,
                                branches, line))
        cur.shapes[name] = type_str
    return comps


def _trip_count(cond: Computation) -> int:
    """Canonical scan condition: compare(iter, constant(N)), LT."""
    consts = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    # find compare with a constant operand (possibly via a fusion param)
    best = None
    for ins in cond.instrs:
        if ins.op == "compare" or "compare" in ins.line:
            for o in ins.operands:
                if o in consts:
                    best = consts[o]
    if best is None and consts:
        best = max(consts.values())
    return best if best else 1


@dataclass
class CostTotals:
    flops: float = 0.0
    bytes: float = 0.0
    link_bytes: float = 0.0
    coll_bytes_by_op: dict = field(default_factory=dict)
    coll_count_by_op: dict = field(default_factory=dict)
    unknown_trip_whiles: int = 0
    # diagnostics: "op_name shape" -> (total scaled bytes, count)
    top_mem: dict = field(default_factory=dict)
    top_coll: dict = field(default_factory=dict)
    top_flop: dict = field(default_factory=dict)

    def summarize(self, k: int = 12) -> dict:
        def top(d):
            items = sorted(d.items(), key=lambda kv: -kv[1][0])[:k]
            return [{"what": w, "total": v, "count": c} for w, (v, c) in items]
        return {"mem": top(self.top_mem), "coll": top(self.top_coll),
                "flop": top(self.top_flop)}


class HloCost:
    def __init__(self, text: str, num_devices: int):
        self.comps = parse_module(text)
        self.num_devices = num_devices
        self._cache: dict[str, CostTotals] = {}
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
                if m:
                    entry = m.group(1)
        self.entry = entry

    def total(self) -> CostTotals:
        if self.entry and self.entry in self.comps:
            return self._comp_cost(self.entry)
        # fallback: largest computation
        big = max(self.comps, key=lambda c: len(self.comps[c].instrs))
        return self._comp_cost(big)

    # ------------------------------------------------------------- internals
    def _comp_cost(self, name: str) -> CostTotals:
        if name in self._cache:
            return self._cache[name]
        comp = self.comps.get(name)
        out = CostTotals()
        self._cache[name] = out  # cycle guard
        if comp is None:
            return out
        for ins in comp.instrs:
            self._add_instr(out, comp, ins)
        return out

    def _add_scaled(self, out: CostTotals, sub: CostTotals, k: float):
        out.flops += sub.flops * k
        out.bytes += sub.bytes * k
        out.link_bytes += sub.link_bytes * k
        for op, v in sub.coll_bytes_by_op.items():
            out.coll_bytes_by_op[op] = out.coll_bytes_by_op.get(op, 0.0) + v * k
        for op, v in sub.coll_count_by_op.items():
            out.coll_count_by_op[op] = out.coll_count_by_op.get(op, 0) + v * k
        out.unknown_trip_whiles += sub.unknown_trip_whiles
        for dst, src in ((out.top_mem, sub.top_mem),
                         (out.top_coll, sub.top_coll),
                         (out.top_flop, sub.top_flop)):
            for w, (v, c) in src.items():
                v0, c0 = dst.get(w, (0.0, 0))
                dst[w] = (v0 + v * k, c0 + int(c * k))

    @staticmethod
    def _note(d: dict, what: str, val: float):
        v0, c0 = d.get(what, (0.0, 0))
        d[what] = (v0 + val, c0 + 1)

    def _add_instr(self, out: CostTotals, comp: Computation, ins: Instr):
        op = ins.op
        if op == "while":
            body = cond = None
            mb = re.search(r"body=%([\w\.\-]+)", ins.line)
            mc = re.search(r"condition=%([\w\.\-]+)", ins.line)
            body = mb.group(1) if mb else None
            cond = mc.group(1) if mc else None
            trip = _trip_count(self.comps[cond]) if cond in self.comps else 1
            if trip == 1:
                out.unknown_trip_whiles += 1
            if body:
                self._add_scaled(out, self._comp_cost(body), trip)
            return
        if op == "conditional":
            subs = [self._comp_cost(c) for c in ins.calls if c in self.comps]
            if subs:
                best = max(subs, key=lambda s: s.flops + s.bytes)
                self._add_scaled(out, best, 1.0)
            return
        if op in ("call", "async-start"):
            for c in ins.calls:
                if c in self.comps:
                    self._add_scaled(out, self._comp_cost(c), 1.0)
            return
        if op == "fusion":
            # flops: recurse (dots can live inside fusions);
            # bytes: output + operands, EXCEPT operands the fused computation
            # only slices (all_to_all/gather decompositions pass the whole
            # buffer but read one row)
            for c in ins.calls:
                if c in self.comps:
                    sub = self._comp_cost(c)
                    out.flops += sub.flops
                    self._add_coll_only(out, sub)
            b = self._fusion_bytes(comp, ins)
            out.bytes += b
            self._note(out.top_mem, f"fusion {ins.type_str[:60]}", b)
            return
        base = op.replace("-start", "")
        if base in COLLECTIVE_OPS:
            if op.endswith("-done"):
                return
            _, size = _shape_elems_bytes(ins.type_str)
            n = self._group_size(ins.line)
            if n > 1:
                mult = {"all-reduce": 2.0 * (n - 1) / n,
                        "all-gather": (n - 1) / n,
                        "reduce-scatter": float(n - 1),
                        "all-to-all": (n - 1) / n,
                        "collective-permute": 1.0}[base]
                out.coll_bytes_by_op[base] = (
                    out.coll_bytes_by_op.get(base, 0.0) + size * mult)
                out.coll_count_by_op[base] = (
                    out.coll_count_by_op.get(base, 0) + 1)
                out.link_bytes += size * mult
                self._note(out.top_coll, f"{base} {ins.type_str[:60]} n={n}",
                           size * mult)
            out.bytes += self._io_bytes(comp, ins)
            return
        if op in ("dot", "dot_general"):
            elems, _ = _shape_elems_bytes(ins.type_str)
            contract = 1
            mc = _CONTRACT.search(ins.line)
            if mc and ins.operands:
                lhs = comp.shapes.get(ins.operands[0], "")
                dims_str = [d for d in mc.group(1).split(",") if d]
                shapes = _SHAPE_RE.findall(lhs)
                if shapes:
                    lhs_dims = [int(d) for d in shapes[0][1].split(",") if d]
                    for di in dims_str:
                        i = int(di)
                        if i < len(lhs_dims):
                            contract *= lhs_dims[i]
            out.flops += 2.0 * elems * contract
            out.bytes += self._io_bytes(comp, ins)
            self._note(out.top_flop, f"dot {ins.type_str[:60]} k={contract}",
                       2.0 * elems * contract)
            return
        if op == "convolution":
            # rare here; approximate as dot over input feature window
            elems, _ = _shape_elems_bytes(ins.type_str)
            out.flops += 2.0 * elems  # lower bound
            out.bytes += self._io_bytes(comp, ins)
            return
        if op in _SKIP_BYTES:
            return
        if op in ("slice", "dynamic-slice", "gather"):
            # reads only the selected region ~= output size (counting the
            # full input would overcount XLA:CPU's all_to_all/gather
            # decompositions by the slice count)
            _, ob = _shape_elems_bytes(ins.type_str)
            b = float(2 * ob)
        elif op in ("dynamic-update-slice", "scatter"):
            # in-place update: read+write of the update region (operand 1)
            ts = comp.shapes.get(ins.operands[1]) if len(ins.operands) > 1 else None
            _, ub = _shape_elems_bytes(ts or ins.type_str)
            b = float(2 * ub)
        else:
            b = self._io_bytes(comp, ins)
        out.bytes += b
        self._note(out.top_mem, f"{op} {ins.type_str[:60]}", b)

    def _add_coll_only(self, out: CostTotals, sub: CostTotals):
        out.link_bytes += sub.link_bytes
        for op, v in sub.coll_bytes_by_op.items():
            out.coll_bytes_by_op[op] = out.coll_bytes_by_op.get(op, 0.0) + v
        for op, v in sub.coll_count_by_op.items():
            out.coll_count_by_op[op] = out.coll_count_by_op.get(op, 0) + v

    def _fusion_bytes(self, comp: Computation, ins: Instr) -> float:
        """Output + operand bytes, with slice-only-consumed params counted at
        their sliced size."""
        _, ob = _shape_elems_bytes(ins.type_str)
        total = float(ob)
        fused = self.comps.get(ins.calls[0]) if ins.calls else None
        sliced_reads: dict[int, float] = {}
        if fused is not None:
            pidx = {}
            for fi in fused.instrs:
                if fi.op == "parameter":
                    m = re.search(r"parameter\((\d+)\)", fi.line)
                    if m:
                        pidx[fi.name] = int(m.group(1))
            consumers: dict[str, list[Instr]] = {}
            for fi in fused.instrs:
                for o in fi.operands:
                    if o in pidx:
                        consumers.setdefault(o, []).append(fi)
            for pname, idx in pidx.items():
                cons = consumers.get(pname, [])
                if cons and all(c.op in ("slice", "dynamic-slice", "gather")
                                for c in cons):
                    sliced_reads[idx] = sum(
                        _shape_elems_bytes(c.type_str)[1] for c in cons)
        for i, o in enumerate(ins.operands):
            ts = comp.shapes.get(o)
            if ts is None:
                continue
            _, bfull = _shape_elems_bytes(ts)
            total += min(sliced_reads.get(i, bfull), bfull)
        return total

    def _io_bytes(self, comp: Computation, ins: Instr) -> float:
        _, ob = _shape_elems_bytes(ins.type_str)
        total = float(ob)
        for o in ins.operands:
            ts = comp.shapes.get(o)
            if ts:
                _, b = _shape_elems_bytes(ts)
                total += b
        return total

    def _group_size(self, line: str) -> int:
        m = _GROUPS_IOTA.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST.search(line)
        if m:
            return len(m.group(1).split(","))
        return self.num_devices


def analyze_text(text: str, num_devices: int) -> CostTotals:
    return HloCost(text, num_devices).total()
