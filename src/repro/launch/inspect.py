"""Inspect an obs metrics snapshot: selection heatmap + metric tables.

  PYTHONPATH=src python -m repro.launch.inspect metrics.json

Reads the JSON written by ``--metrics-json`` on the train/serve launchers
(the ``obs.snapshot()`` document: ``{subsystem: {metric: value}}`` plus an
optional ``selection`` key) and renders:

  * the per-block selection-frequency heatmap over training — columns are
    step windows, shade is the in-window selection rate, the bottom row is
    normalized selection entropy. A falling entropy profile is the
    exploration->exploitation transition the paper's epsilon-decay predicts;
    flat entropy means a schedule/uniform policy (lisa, random).
  * a flat table of every counter/gauge/histogram summary in the snapshot.

``--bins`` controls heatmap resolution; ``--no-metrics`` / ``--no-heatmap``
restrict output to one view.
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("snapshot", help="metrics JSON written by --metrics-json")
    ap.add_argument("--bins", type=int, default=12,
                    help="heatmap step-window count")
    ap.add_argument("--no-metrics", action="store_true",
                    help="skip the metric tables")
    ap.add_argument("--no-heatmap", action="store_true",
                    help="skip the selection heatmap")
    args = ap.parse_args()

    from repro.obs import report
    from repro.obs.selection import SelectionTrace

    with open(args.snapshot) as f:
        doc = json.load(f)

    sel_doc = doc.pop("selection", None)
    if not args.no_heatmap:
        if sel_doc:
            trace = SelectionTrace.from_snapshot(sel_doc)
            print(report.render_selection_trace(trace, bins=args.bins))
        else:
            print("no selection telemetry in snapshot (train with "
                  "--metrics-json and an obs-enabled run to record it)")
    if not args.no_metrics:
        if not args.no_heatmap:
            print()
        print(report.render_metrics(doc))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
