"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

``make_mesh`` / ``make_production_mesh`` validate the device count up front
and raise an actionable error (requested shape, found devices, and the
XLA_FLAGS incantation for CPU testing) instead of jax's opaque
"len(devices) != prod(shape)" failure deep inside mesh_utils.

Compat: ``jax.sharding.AxisType`` only exists on the jax>=0.6 line; on older
jax the mesh is built without explicit axis types (everything is Auto there
anyway).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.6
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - older jax line
    AxisType = None

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD, TINY_MESH


def _check_device_count(shape: tuple, axes: tuple) -> None:
    need = 1
    for s in shape:
        need *= s
    have = len(jax.devices())
    if have < need:  # a surplus is fine — jax uses the first `need` devices
        raise ValueError(
            f"requested mesh shape {tuple(shape)} over axes {tuple(axes)} "
            f"needs {need} devices, found {have} "
            f"(hint: XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"to emulate {need} devices on CPU)")


def _make(shape: tuple, axes: tuple):
    _check_device_count(shape, axes)
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(cfg: MeshConfig):
    return _make(cfg.shape, cfg.axes)


def make_data_mesh(dp: int | None = None):
    """Pure data-parallel mesh ("data", "model") with model axis 1 — the
    multi-device CI topology (dp defaults to every visible device)."""
    dp = dp if dp is not None else len(jax.devices())
    return _make((dp, 1), ("data", "model"))


def mesh_config(name: str) -> MeshConfig:
    return {"single": SINGLE_POD, "multi": MULTI_POD, "tiny": TINY_MESH}[name]
