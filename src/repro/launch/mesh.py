"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD, TINY_MESH


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axes,
                         axis_types=(AxisType.Auto,) * len(cfg.axes))


def mesh_config(name: str) -> MeshConfig:
    return {"single": SINGLE_POD, "multi": MULTI_POD, "tiny": TINY_MESH}[name]
