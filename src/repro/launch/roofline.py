"""Roofline-term derivation from a compiled dry-run artifact.

Hardware model: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM, ~50 GB/s/link
ICI. The compiled module from ``lowered.compile()`` is the per-device SPMD
program, so ``cost_analysis()`` FLOPs/bytes are per-chip quantities:

    compute term    = flops_per_chip / peak_flops
    memory term     = hbm_bytes_per_chip / hbm_bw
    collective term = link_bytes_per_chip / link_bw

Collective bytes are NOT in cost_analysis; we parse the partitioned HLO and
apply ring-algorithm multipliers per op (n = collective group size):
    all-reduce        2 * (n-1)/n * result_bytes
    all-gather            (n-1)/n * result_bytes
    reduce-scatter        (n-1)   * result_bytes   (result is the shard)
    all-to-all            (n-1)/n * result_bytes
    collective-permute              result_bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)
    link_bytes: float = 0.0     # per chip, ring-multiplier applied
    raw_bytes: float = 0.0      # per chip, result sizes only


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        # avoid double counting async start/done pairs: only count -start or
        # the sync form; skip "-done" lines (their shape repeats the result)
        if "-done(" in line:
            continue
        op = m.group(3)
        shape_str = m.group(1) or m.group(2) or ""
        size = _shape_bytes(shape_str)
        n = _group_size(line, num_devices)
        if n <= 1:
            continue
        mult = {
            "all-reduce": 2.0 * (n - 1) / n,
            "all-gather": (n - 1) / n,
            "reduce-scatter": float(n - 1),
            "all-to-all": (n - 1) / n,
            "collective-permute": 1.0,
        }[op]
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + size * mult
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
        stats.link_bytes += size * mult
        stats.raw_bytes += size
    return stats


@dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    link_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_per_chip: float = 0.0
    useful_flops_frac: float = 0.0
    collectives: dict = field(default_factory=dict)

    def as_dict(self):
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "link_bytes_per_chip": self.link_bytes_per_chip,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops_per_chip": self.model_flops_per_chip,
            "useful_flops_frac": self.useful_flops_frac,
            "collectives": self.collectives,
        }


def analyze(cost: dict, hlo_text: str, num_devices: int,
            model_flops_total: float = 0.0) -> Roofline:
    """Terms come from the trip-count-aware HLO walk (launch/hlo_cost.py);
    XLA's own cost_analysis numbers ride along in the dry-run JSON for
    comparison (they undercount scanned programs)."""
    from repro.launch.hlo_cost import analyze_text
    ct = analyze_text(hlo_text, num_devices)
    flops, hbm = ct.flops, ct.bytes
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": ct.link_bytes / LINK_BW,
    }
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_total / num_devices
    return Roofline(
        flops_per_chip=flops,
        hbm_bytes_per_chip=hbm,
        link_bytes_per_chip=ct.link_bytes,
        compute_s=terms["compute"],
        memory_s=terms["memory"],
        collective_s=terms["collective"],
        bottleneck=bottleneck,
        model_flops_per_chip=mf,
        useful_flops_frac=(mf / flops) if flops else 0.0,
        collectives={k: {"bytes": v, "count": ct.coll_count_by_op[k]}
                     for k, v in ct.coll_bytes_by_op.items()},
    )


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs for the cell: 6·N_active·T for training,
    2·N_active·T for inference, + exact attention-score/V FLOPs."""
    from repro.core.partition import build_partition  # noqa: F401 (doc link)
    n_active = active_params(cfg)
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, mult = gb * s, 6.0
    elif shape.kind == "prefill":
        tokens, mult = gb * s, 2.0
    else:
        tokens, mult = gb * 1, 2.0
    base = mult * n_active * tokens
    attn = attention_flops(cfg, shape)
    return base + attn


def active_params(cfg) -> float:
    """Parameter count actually touched per token (MoE: top-k + shared)."""
    import jax

    from repro.models import registry
    model = registry.get(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg), jax.random.PRNGKey(0))
    total = 0.0
    for pth, leaf in _leaves(shapes):
        n = float(_size(leaf.shape))
        if "/moe/" in f"/{pth}/" and "shared" not in pth and \
                pth.split("/")[-1] in ("wg", "wu", "wd"):
            n *= cfg.num_experts_per_tok / cfg.num_experts
        total += n
    return total


def attention_flops(cfg, shape) -> float:
    """Scores + AV FLOPs (2·B·H·Sq·Sk·(Dk+Dv) with causal 1/2 for train)."""
    gb, s = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        return 0.0
    h = cfg.num_heads
    if cfg.use_mla:
        dk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        dv = cfg.v_head_dim
    else:
        dk = dv = cfg.head_dim
    if cfg.family == "hybrid":
        layers = cfg.num_layers // max(1, cfg.shared_attn_period)
    elif cfg.family == "encdec":
        layers = cfg.num_layers + cfg.num_encoder_layers
    else:
        layers = cfg.num_layers
    if shape.kind == "train":
        per = 2 * gb * h * s * s * (dk + dv) * 0.5 * 3  # fwd+bwd(2x), causal
    elif shape.kind == "prefill":
        per = 2 * gb * h * s * s * (dk + dv) * 0.5
    else:
        per = 2 * gb * h * 1 * s * (dk + dv)
    return per * layers


def _leaves(tree):
    from repro.utils.trees import tree_leaves_with_path
    return tree_leaves_with_path(tree)


def _size(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n
