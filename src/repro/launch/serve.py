"""Serving launcher: continuous-batching engine over a (smoke or full) model.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32 --kv-layout paged

Reports compile time (warmup call) and steady-state tok/s separately — the
pre-warmup number was dominated by XLA compile and meaningless as a
throughput figure. After the timed pass the launcher prints the engine's
consolidated ``stats_snapshot()`` — engine counters, per-request latency
histograms (queue-wait / TTFT / TPOT / e2e), page-pool + scheduler +
prefix-cache + fn-cache state in one nested dict (keys documented in
serve/engine.py). ``--prefix-cache`` turns on the radix prefix cache (and
makes the demo batch share a prompt prefix so hits are observable);
``--preempt`` allows the engine to preempt-and-requeue residents when the
pool is exhausted. ``--trace out.json`` exports a Perfetto-loadable trace
with per-request ttft/e2e lanes; ``--metrics-json`` dumps the obs registry
snapshot.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--kv-layout", choices=["dense", "paged"],
                    default="dense")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size (0 = full dense capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill width (0 = single-shot)")
    ap.add_argument("--prefill-rows", type=int, default=1,
                    help="rows per bucketed prefill batch")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache over the page pool "
                         "(requires --kv-layout paged)")
    ap.add_argument("--preempt", action="store_true",
                    help="preempt-and-requeue when the page pool is "
                         "exhausted (requires --kv-layout paged)")
    ap.add_argument("--admission", choices=["fcfs", "prefix_aware"],
                    default="fcfs",
                    help="admission order: prefix_aware admits queued "
                         "requests early when their cached prefix pages "
                         "sit at the LRU eviction frontier")
    ap.add_argument("--persist-prefix", action="store_true",
                    help="keep the radix tree in a PrefixStore across "
                         "engine instances (the second launcher pass then "
                         "prefills suffix-only)")
    ap.add_argument("--fn-cache-limit", type=int, default=0,
                    help="bound the compiled-fn LRU (0 = keep default)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="export a Chrome trace-event JSON (ui.perfetto.dev)"
                         " with admission/prefill/decode spans and "
                         "per-request ttft/e2e lanes")
    ap.add_argument("--metrics-json", default="",
                    help="write the obs registry snapshot to this path")
    args = ap.parse_args()

    from repro import obs

    obs_on = bool(args.trace or args.metrics_json)
    if obs_on:
        obs.enable(selection=False)

    from repro.configs import get_config, get_smoke_config
    from repro.models import registry
    from repro.serve.config import ServeConfig
    from repro.serve.engine import (ServeEngine, fn_cache_info,
                                    set_fn_cache_limit)
    from repro.serve.prefix_store import PrefixStore

    if args.fn_cache_limit:
        set_fn_cache_limit(args.fn_cache_limit)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), cfg)
    rng = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": np.asarray(
        jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                           cfg.vocab_size), np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = np.asarray(jax.random.normal(
            rng, (args.batch, cfg.num_frontend_tokens, cfg.d_model)) * 0.02)
    if cfg.family == "encdec":
        batch["src_embeds"] = np.asarray(jax.random.normal(
            rng, (args.batch, args.prompt_len, cfg.d_model)) * 0.02)
    if args.prefix_cache:
        # shared-prefix traffic so radix hits are observable: every row
        # reuses row 0's first half (page-aligned for typical page sizes)
        half = args.prompt_len // 2
        toks = batch["tokens"].copy()
        toks[:, :half] = toks[0, :half]
        batch["tokens"] = toks

    prefix = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
    max_len = args.prompt_len + prefix + args.new_tokens
    store = PrefixStore() if args.persist_prefix else None
    serve_cfg = ServeConfig(
        max_len=max_len, num_slots=args.batch,
        temperature=args.temperature, rng=rng,
        decode_chunk=args.decode_chunk,
        kv_layout=args.kv_layout, page_size=args.page_size,
        num_pages=args.num_pages or None,
        prefill_chunk=args.prefill_chunk,
        prefill_rows=args.prefill_rows,
        prefix_cache=args.prefix_cache, preempt=args.preempt,
        admission=args.admission, prefix_store=store)

    def one_pass(close=False):
        engine = ServeEngine(cfg, params, serve_cfg)
        out = engine.generate(batch, max_new_tokens=args.new_tokens)
        if close:
            # with --persist-prefix this hands the radix tree to the store,
            # so the next pass's engine adopts it warm
            engine.close()
        return out, engine

    # warmup: same shapes/max_len as the timed call, so every compile
    # (prefill, decode chunk, insert) lands here
    t0 = time.perf_counter()
    one_pass(close=True)
    t_compile = time.perf_counter() - t0
    warm = fn_cache_info()

    t0 = time.perf_counter()
    out, engine = one_pass()
    dt = time.perf_counter() - t0
    steady = fn_cache_info()
    tps = args.batch * args.new_tokens / dt
    print(f"compile+first-call: {t_compile:.2f}s")
    print(f"steady state: generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    if steady["misses"] > warm["misses"]:
        print(f"  WARNING: steady-state call added "
              f"{steady['misses'] - warm['misses']} fn-cache misses "
              f"(a closure was rebuilt instead of cached)")
    if args.prefix_cache:
        # second wave on the SAME engine: the first wave populated the
        # radix tree, so every re-sent prompt aliases its cached pages and
        # prefills only the copy-on-write tail token. The first warm-tree
        # wave compiles the cached-suffix closure; the timed one is steady.
        engine.generate(batch, max_new_tokens=args.new_tokens)
        t0 = time.perf_counter()
        engine.generate(batch, max_new_tokens=args.new_tokens)
        dt2 = time.perf_counter() - t0
        print(f"  2nd wave (warm radix tree): "
              f"{args.batch * args.new_tokens / dt2:.1f} tok/s "
              f"({dt / max(dt2, 1e-9):.2f}x 1st wave)")
    if store is not None:
        print(f"  prefix store: {store.stats['adoptions']} adoptions")
    # one consolidated dump replaces the old fn-cache / page-pool / prefix
    # printouts — key structure documented in serve/engine.py
    print("engine stats_snapshot:")
    print(json.dumps(engine.stats_snapshot(), indent=2))
    if args.trace:
        obs.export_trace(args.trace)
        print(f"trace written to {args.trace} (open in ui.perfetto.dev)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(obs.snapshot(), f, indent=2)
        print(f"metrics snapshot written to {args.metrics_json}")
    if obs_on:
        obs.disable()
    print("first row:", out[0][:24])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
