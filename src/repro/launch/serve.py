"""Serving launcher: continuous-batching engine over a (smoke or full) model.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 16 --new-tokens 32

Reports compile time (warmup call) and steady-state tok/s separately — the
pre-warmup number was dominated by XLA compile and meaningless as a
throughput figure.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.models import registry
    from repro.serve.engine import generate

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(args.seed), cfg)
    rng = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": np.asarray(
        jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                           cfg.vocab_size), np.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = np.asarray(jax.random.normal(
            rng, (args.batch, cfg.num_frontend_tokens, cfg.d_model)) * 0.02)
    if cfg.family == "encdec":
        batch["src_embeds"] = np.asarray(jax.random.normal(
            rng, (args.batch, args.prompt_len, cfg.d_model)) * 0.02)

    prefix = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
    max_len = args.prompt_len + prefix + args.new_tokens
    kw = dict(max_new_tokens=args.new_tokens, max_len=max_len,
              temperature=args.temperature, rng=rng,
              decode_chunk=args.decode_chunk)

    # warmup: same shapes/max_len as the timed call, so every compile
    # (prefill, decode chunk, insert) lands here
    t0 = time.perf_counter()
    generate(params, cfg, batch, **kw)
    t_compile = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = generate(params, cfg, batch, **kw)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"compile+first-call: {t_compile:.2f}s")
    print(f"steady state: generated {out.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print("first row:", out[0][:24])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
