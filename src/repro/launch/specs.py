"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape, mesh)`` returns (args, kind) where args are SDS
pytrees with NamedShardings attached — weak-type-correct, shardable, zero
allocation. For decode cells the KV/SSM cache specs implement the SP rules:
batch over ("pod","data") when divisible, kv-heads over "model" when
divisible, otherwise cache *sequence* over the spare axes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shard_rules
from repro.models import registry
from repro.utils.trees import tree_map_with_path


def _axes_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_dims(cfg: ModelConfig, shape: ShapeConfig):
    """Token-batch geometry for a cell. For vlm, seq_len counts the image
    prefix; for encdec, src length = seq_len // frontend_len_ratio."""
    gb, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        return gb, s - cfg.num_frontend_tokens
    return gb, s


def data_batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict:
    sizes = _axes_sizes(mesh)
    baxes = shard_rules.batch_axes_of(mesh)
    bdim = baxes if shape.global_batch % _prod(sizes, baxes) == 0 else None
    gb, s = batch_dims(cfg, shape)
    dt = jnp.dtype(cfg.dtype)
    out = {}
    if shape.kind == "train":
        out["tokens"] = _sds((gb, s), jnp.int32, mesh, P(bdim, None))
        out["loss_mask"] = _sds((gb, s), jnp.float32, mesh, P(bdim, None))
    elif shape.kind == "prefill":
        out["tokens"] = _sds((gb, s), jnp.int32, mesh, P(bdim, None))
    else:  # decode: one new token
        out["tokens"] = _sds((gb, 1), jnp.int32, mesh, P(bdim, None))
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patch_embeds"] = _sds((gb, cfg.num_frontend_tokens, cfg.d_model),
                                   dt, mesh, P(bdim, None, None))
    if cfg.family == "encdec" and shape.kind != "decode":
        out["src_embeds"] = _sds((gb, shape.seq_len // cfg.frontend_len_ratio,
                                  cfg.d_model), dt, mesh, P(bdim, None, None))
    return out


def _prod(sizes: dict, axes) -> int:
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def cache_spec_for(cfg: ModelConfig, mesh, batch: int, path: str,
                   shape: tuple) -> P:
    """Sharding rule for one cache leaf (see module docstring)."""
    sizes = _axes_sizes(mesh)
    baxes = shard_rules.batch_axes_of(mesh)
    m = sizes["model"]
    b_ok = batch % _prod(sizes, baxes) == 0
    bdim = baxes if b_ok else None
    leaf = path.split("/")[-1]
    if leaf == "pos":
        return P()
    if leaf in ("k", "v", "ak", "av", "ck", "cv"):
        # [L, B, S, KVH, Dh]
        kvh = shape[3]
        if kvh % m == 0:
            return P(None, bdim, None, "model", None)
        seq_axes = ("model",) if b_ok else tuple([*baxes, "model"])
        return P(None, bdim, seq_axes, None, None)
    if leaf in ("ckv", "kpe"):
        # MLA latent [L, B, S, r] — shard S over model (+ batch axes if B=1)
        seq_axes = ("model",) if b_ok else tuple([*baxes, "model"])
        return P(None, bdim, seq_axes, None)
    if leaf in ("x", "b", "c"):      # conv windows [L, B, K-1, C]
        return P(None, bdim, None, "model")
    if leaf == "state":              # SSM state [L, B, H, P, N]
        h = shape[2]
        return P(None, bdim, "model" if h % m == 0 else None, None, None)
    return P()


def cache_specs(cfg: ModelConfig, mesh, batch: int, max_len: int):
    model = registry.get(cfg)
    shapes = jax.eval_shape(partial(model.init_cache, cfg, batch, max_len))
    return tree_map_with_path(
        lambda path, leaf: jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype,
            sharding=NamedSharding(mesh, cache_spec_for(cfg, mesh, batch,
                                                        path, leaf.shape))),
        shapes)


def params_sds(cfg: ModelConfig, mesh, seed: int = 0):
    model = registry.get(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(seed))
    specs = shard_rules.param_specs(cfg, shapes, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return shard_rules.sds_with_sharding(shapes, shardings), specs


def train_state_sds(cfg: ModelConfig, mesh, opt_offload: str = "none",
                    moment_dtype=None, policy: str = "adagradselect"):
    """SDS + shardings for the full TrainState. Moments follow the params'
    specs, optionally ZeRO-1 resharded or host-offloaded (DESIGN 3.2).
    ``policy`` fixes the selection-state pytree layout (per-policy state)."""
    from repro.train import step as step_mod
    moment_dtype = jnp.dtype(moment_dtype or jnp.float32)
    shapes = step_mod.train_state_shapes(cfg, policy=policy)
    p_sds, p_specs = params_sds(cfg, mesh)

    def rep(leaf):  # replicated small state
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, P()))

    m_specs = p_specs
    if opt_offload == "zero1":
        m_specs = shard_rules.apply_zero1(p_specs, shapes["params"], mesh)
    kind = ("pinned_host"
            if opt_offload == "host" and jax.default_backend() in ("tpu", "gpu")
            else None)

    def moment_sds(leaf, spec):
        if kind:
            sh = NamedSharding(mesh, spec, memory_kind=kind)
        else:
            sh = NamedSharding(mesh, spec)
        return jax.ShapeDtypeStruct(leaf.shape, moment_dtype, sharding=sh)

    state = {
        "params": p_sds,
        "opt": {
            "m": jax.tree.map(moment_sds, shapes["opt"]["m"], m_specs),
            "v": jax.tree.map(moment_sds, shapes["opt"]["v"], m_specs),
            "counts": rep(shapes["opt"]["counts"]),
        },
        "sel": jax.tree.map(rep, shapes["sel"]),
        "step": rep(shapes["step"]),
    }
    return state
