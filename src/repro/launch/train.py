"""Training launcher.

CPU-scale (runs here):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-0.5b --smoke \
      --method adagradselect --k 20 --steps 200

Production (TPU pod; same code, mesh from --mesh):
  python -m repro.launch.train --arch qwen2.5-32b --mesh single \
      --steps 10000 --checkpoint-dir gs://.../ckpts

--method accepts any entry in the repro.methods registry (full,
adagradselect, topk_grad, random, lora, lisa, grass, ...).

Observability: ``--trace run.json`` exports a Perfetto-loadable Chrome
trace of the run, ``--metrics-json m.json`` dumps the metrics-registry
snapshot (inspect with ``python -m repro.launch.inspect m.json``), and
``--report`` prints the selection-frequency heatmap after training.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main():
    from repro import methods

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--method", default="adagradselect",
                    choices=sorted(methods.available()))
    ap.add_argument("--k", type=float, default=20.0, help="k%% blocks per step")
    ap.add_argument("--lora-rank", type=int, default=128)
    ap.add_argument("--lisa-interval", type=int, default=20,
                    help="lisa: steps between mask resamples")
    ap.add_argument("--grass-temperature", type=float, default=1.0,
                    help="grass: sampling ∝ cum_norms^T")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--data", default="synthetic_math",
                    choices=["synthetic_math", "jsonl", "jsonl_sft"],
                    help="synthetic_math/jsonl: legacy pure-f(step) "
                         "sources; jsonl_sft: streaming pipeline over "
                         "{'prompt','completion'} lines (cursor "
                         "checkpointed, packed under --pack)")
    ap.add_argument("--data-path", default="",
                    help="corpus path for --data jsonl / jsonl_sft")
    ap.add_argument("--pack", action="store_true",
                    help="segment-aware sequence packing (jsonl_sft, or "
                         "synthetic_math via its record form): multiple "
                         "examples per row with block-diagonal attention "
                         "+ per-segment positions")
    ap.add_argument("--prefetch-depth", type=int, default=0,
                    help=">0: async prefetcher builds and device_puts this "
                         "many batches ahead of the train loop "
                         "(bit-identical trajectory, prefetch on or off)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--offload", default="none", choices=["none", "host", "zero1"])
    ap.add_argument("--moment-residency", default="device",
                    choices=["device", "banked"],
                    help="banked: compact [k]-slot device moment banks over "
                         "a full store placed per --offload (paper 3.3); "
                         "banked + --offload zero1 shards the store 1/dp "
                         "over the mesh's data axis and requires --mesh")
    ap.add_argument("--async-swap", default="on", choices=["on", "off"],
                    help="banked only: 'on' overlaps the selection-change "
                         "boundary with compute (a background thread "
                         "prefetches the policy's predicted next admit set "
                         "and writes predicted evictions back while phase B "
                         "runs; mispredictions fall back to the synchronous "
                         "swap — the trajectory is bit-identical either "
                         "way); 'off' forces every boundary synchronous")
    ap.add_argument("--mesh", default=None,
                    choices=[None, "single", "multi", "tiny", "data"],
                    help="run data-parallel (or DP x TP) on a device mesh: "
                         "batch shards over the data axes, params/moments "
                         "follow distributed/sharding.py (TP where the "
                         "model axis is >1, ZeRO-1 moments under --offload "
                         "zero1). 'single'=(16,16) 'multi'=(2,16,16) "
                         "'tiny'=(2,4); 'data'=(N,1) over every visible "
                         "device — the CPU-testable topology "
                         "(XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--trace", default="",
                    help="export a Chrome trace-event JSON (load in "
                         "ui.perfetto.dev) of the run: train_step spans, "
                         "banked phase_a/swap/phase_b, the background "
                         "swap-dispatch thread on its own track. Enables "
                         "the obs layer (adds host syncs; trajectories "
                         "stay bit-identical)")
    ap.add_argument("--metrics-json", default="",
                    help="write the full obs registry snapshot (counters/"
                         "gauges/histogram summaries + selection "
                         "telemetry) to this path; feed it to "
                         "repro.launch.inspect")
    ap.add_argument("--report", action="store_true",
                    help="print the selection-frequency heatmap "
                         "(exploration->exploitation view) after training")
    args = ap.parse_args()

    from repro import obs

    obs_on = bool(args.trace or args.metrics_json or args.report)
    if obs_on:
        obs.enable()

    from repro.configs import get_config, get_smoke_config
    from repro.configs.base import OptimizerConfig, SelectConfig, TrainConfig

    mcfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        model=mcfg,
        method=args.method,
        select=SelectConfig(k_percent=args.k,
                            steps_per_epoch=max(1, args.steps // 4),
                            lisa_interval=args.lisa_interval,
                            grass_temperature=args.grass_temperature),
        optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps,
                                  offload=args.offload,
                                  moment_residency=args.moment_residency,
                                  async_swap=args.async_swap == "on",
                                  lora_rank=args.lora_rank),
        seq_len=args.seq_len, global_batch=args.global_batch,
        steps=args.steps, seed=args.seed,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)

    mesh = None
    batch_axes = ("data",)
    if args.mesh:
        from repro.launch.mesh import (make_data_mesh, make_mesh,
                                       make_production_mesh, mesh_config)
        if args.mesh == "data":
            mesh = make_data_mesh()
        elif args.mesh == "tiny":
            mesh = make_mesh(mesh_config("tiny"))
        else:
            mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        batch_axes = tuple(a for a in mesh.axis_names if a != "model")

    data_source = None
    if args.pack and args.data == "jsonl":
        raise SystemExit("--pack needs example boundaries; use --data "
                         "jsonl_sft ({'prompt','completion'} lines) — "
                         "plain jsonl documents are ring-packed already")
    if args.data != "synthetic_math" or args.pack:
        from repro.data import loader
        kind = args.data
        if args.data == "synthetic_math" and args.pack:
            kind = "packed_math"  # synthetic corpus as packable records
        data_source = loader.make_source(
            kind, seq_len=args.seq_len, global_batch=args.global_batch,
            seed=args.seed, path=args.data_path, pack=args.pack)

    from repro.train.trainer import Trainer
    trainer = Trainer(tcfg, mesh=mesh, batch_axes=batch_axes,
                      data_source=data_source,
                      prefetch_depth=args.prefetch_depth)
    report = trainer.method.trainable_param_report(mcfg, trainer.state)
    resident = (f", resident {report.opt_bytes_resident / (1 << 20):.1f} MiB"
                if report.opt_bytes_resident >= 0 else "")
    print(f"[{args.method}] trainable {report.num_params_trainable:,}/"
          f"{report.num_params_total:,} params "
          f"({report.trainable_fraction:.1%}), "
          f"opt-state {report.opt_bytes / (1 << 20):.1f} MiB (model)"
          f"{resident}  {report.detail}")
    start = trainer.maybe_restore()
    if start:
        print(f"resumed from step {start}")
    log = trainer.train()
    print(f"final loss: {log.losses[-1]:.4f}  "
          f"mean step time: {np.mean(log.step_times[3:]):.3f}s")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"losses": log.losses, "step_times": log.step_times,
                       "metrics": log.metrics}, f)
    if args.trace:
        obs.export_trace(args.trace)
        print(f"trace written to {args.trace} (open in ui.perfetto.dev)")
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(obs.snapshot(), f, indent=2)
        print(f"metrics snapshot written to {args.metrics_json}")
    if args.report:
        from repro.obs import report as obs_report
        print(obs_report.render_selection_trace(obs.selection_trace()))
    if obs_on:
        obs.disable()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
