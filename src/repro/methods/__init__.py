"""Pluggable fine-tuning methods: one strategy API, a string-keyed registry.

    from repro import methods
    m = methods.build("adagradselect", tcfg)     # -> FinetuneMethod
    state = m.init_state(tcfg.model, tcfg.optimizer, seed)
    step = m.make_step(tcfg.model, tcfg.optimizer, mesh=...)

Registered out of the box: ``full`` (alias ``all``), ``adagradselect``,
``topk_grad``, ``random``, ``lisa``, ``grass`` (the masked-selection family,
see methods/selection.py + core/adagradselect.py) and ``lora``
(methods/lora.py). See methods/base.py for the protocol and
methods/registry.py for how to add one.
"""
from repro.methods import lora as _lora  # noqa: F401  (registers "lora")
from repro.methods import selection as _selection  # noqa: F401  (registers family)
from repro.methods.base import FinetuneMethod, TrainableReport  # noqa: F401
from repro.methods.registry import (  # noqa: F401
    available,
    build,
    get_method,
    register,
)
