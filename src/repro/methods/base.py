"""The ``FinetuneMethod`` strategy protocol.

A fine-tuning method owns everything the paper varies between its compared
approaches: what state a training run carries, how one optimization step is
built, which parameters an evaluation should use, and how many parameters /
optimizer bytes the method actually trains. The trainer is method-agnostic:
it only drives data, logging, checkpointing, and the straggler watchdog.

Implementations are registered in ``repro.methods.registry`` under a string
key; ``registry.build(name, train_cfg)`` resolves a ready-to-use instance.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

from repro.configs.base import ModelConfig, OptimizerConfig


@dataclass(frozen=True)
class TrainableReport:
    """What a method actually trains (paper §3.3 memory model surface).

    ``opt_bytes`` is the deterministic §3.3 model (2 * P_selected * B);
    ``opt_bytes_resident`` is the *measured* accelerator-resident bytes of
    the actual ``state["opt"]`` pytree (host-resident leaves excluded) —
    equal to the full m/v footprint under dense residency, and only the
    compact [k]-slot banks under banked residency."""

    method: str
    num_params_total: int      # all model parameters
    num_params_trainable: int  # parameters the method may update per run
    opt_bytes: int             # modeled optimizer-state bytes (m + v)
    detail: str = ""
    opt_bytes_resident: int = -1  # measured device-resident bytes (-1 = n/a)

    @property
    def trainable_fraction(self) -> float:
        return self.num_params_trainable / max(1, self.num_params_total)


@runtime_checkable
class FinetuneMethod(Protocol):
    """Strategy interface every registered method implements."""

    name: str

    def init_state(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                   seed: int = 0, mesh=None) -> dict:
        """Fresh TrainState pytree (params + optimizer + method state).

        ``mesh`` is forwarded when the trainer runs data-parallel; methods
        whose state layout depends on the mesh (the banked full store under
        ``offload == "zero1"`` shards 1/dp over the data axis) use it at
        init, everything else may ignore it.

        For the masked-selection family, ``state["opt"]`` follows
        ``opt_cfg.moment_residency``:

        * ``"device"``: ``{"m", "v", "counts"}`` — full-shape f32 moments
          congruent with params plus per-block bias-correction counts.
        * ``"banked"``: ``{"banks", "slot_map", "counts", "store"}`` —
          per-group compact moment banks ``{"m", "v", "slots"}`` with
          leading axis min(group length, k); ``slot_map`` [num_blocks] i32
          (block -> bank slot, -1 = host-resident, numpy, never enters
          jit); ``store`` the full-shape backing store (numpy host arrays
          under ``opt_cfg.offload == "host"``, device arrays otherwise).
          See core/masked_adamw.init_banked_opt_state for the contract.
        """
        ...

    def make_step(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                  mesh=None, batch_axes=("data",), use_pallas: bool = False,
                  donate: bool = True, state_shardings=None):
        """-> jitted ``(state, batch) -> (state, metrics)``.

        ``state_shardings`` (the method's ``state_shardings()`` tree, passed
        by the trainer when a mesh is active) lets the step pin its state
        outputs to the input layout so data-parallel steps stay
        compile-once; methods without sharded state may ignore it."""
        ...

    def eval_params(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    state: dict) -> dict:
        """Inference-ready parameter pytree for the current state."""
        ...

    def trainable_param_report(self, model_cfg: ModelConfig,
                               state: dict) -> TrainableReport:
        """Trainable-parameter / optimizer-memory accounting."""
        ...
