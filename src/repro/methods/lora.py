"""LoRA as a registered FinetuneMethod (paper §4.2 baseline).

Rank-r adapters on the attention/MLP projections, trained with standard
AdamW while the base weights stay frozen (merge-on-forward, see
optim/lora.py). state = {"base", "lora", "opt", "step"}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig
from repro.core import masked_adamw
from repro.methods import registry
from repro.methods.base import TrainableReport
from repro.models import registry as model_registry
from repro.optim import adamw as plain_adamw
from repro.optim import lora as lora_mod
from repro.optim.schedules import learning_rate
from repro.train import step as step_mod


class LoRAMethod:
    """FinetuneMethod: adapter-only training, frozen base."""

    name = "lora"

    # -------------------------------------------------------------- state
    def init_state(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                   seed: int = 0, mesh=None) -> dict:
        # mesh accepted per the FinetuneMethod protocol; LoRA state is tiny
        # (adapters + their moments) and stays replicated under DP
        model = model_registry.get(model_cfg)
        base = model.init(jax.random.PRNGKey(seed), model_cfg)
        lora_p = lora_mod.init_lora(jax.random.PRNGKey(seed + 1), base,
                                    model_cfg, opt_cfg.lora_rank)
        return {"base": base, "lora": lora_p,
                "opt": plain_adamw.init_opt_state(lora_p),
                "step": jnp.zeros((), jnp.int32)}

    # --------------------------------------------------------------- step
    def make_step(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                  mesh=None, batch_axes=("data",), use_pallas: bool = False,
                  donate: bool = True, state_shardings=None):
        model = model_registry.get(model_cfg)
        rank, alpha = opt_cfg.lora_rank, opt_cfg.lora_alpha

        def step_fn(state, batch):
            def loss_fn(lp, mb):
                merged = lora_mod.merge(state["base"], lp, model_cfg, rank,
                                        alpha)
                return step_mod.model_loss(model, model_cfg, merged, mb,
                                           mesh=mesh, batch_axes=batch_axes)

            (loss, metrics), grads = step_mod.accumulate_grads(
                loss_fn, state["lora"], batch, opt_cfg.microbatch)
            grads, gnorm = masked_adamw.clip_by_global_norm(
                grads, opt_cfg.grad_clip)
            lr = learning_rate(opt_cfg, state["step"])
            lora_p, opt = plain_adamw.update(opt_cfg, state["lora"], grads,
                                             state["opt"], lr)
            new_state = {"base": state["base"], "lora": lora_p, "opt": opt,
                         "step": state["step"] + 1}
            metrics = {**metrics, "loss": loss, "grad_norm": gnorm, "lr": lr}
            return new_state, metrics

        return jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    # --------------------------------------------------------------- eval
    def eval_params(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    state: dict) -> dict:
        return lora_mod.merge(state["base"], state["lora"], model_cfg,
                              opt_cfg.lora_rank, opt_cfg.lora_alpha)

    # ------------------------------------------------------------- report
    def trainable_param_report(self, model_cfg: ModelConfig,
                               state: dict) -> TrainableReport:
        from repro.core.offload import resident_opt_bytes
        total = sum(int(jnp.size(x)) for x in jax.tree.leaves(state["base"]))
        n_lora = lora_mod.num_lora_params(state["lora"])
        return TrainableReport(
            method=self.name, num_params_total=total,
            num_params_trainable=n_lora,
            opt_bytes=2 * n_lora * 4,  # f32 m + v on adapters only
            opt_bytes_resident=resident_opt_bytes(state["opt"])["device"],
            detail=f"adapters on {len(state['lora'])} leaf groups")


registry.register("lora")(lambda tcfg: LoRAMethod())
