"""String-keyed fine-tuning method registry (mirrors models/registry.py).

Entries are factories ``(TrainConfig) -> FinetuneMethod`` so a method can
bind whatever slice of the config it needs (the selection family binds a
``SelectConfig`` with its policy forced; LoRA needs none). Adding a method:

    @register("mymethod")
    def _build(tcfg):
        return MyMethod(...)

and ``Trainer(tcfg, method="mymethod")`` picks it up — no trainer, step, or
launcher edits.
"""
from __future__ import annotations

from typing import Callable

from repro.configs.base import TrainConfig
from repro.methods.base import FinetuneMethod

_METHODS: dict[str, Callable[[TrainConfig], FinetuneMethod]] = {}


def register(name: str, *aliases: str):
    """Decorator: register a method factory under ``name`` (+ aliases)."""
    def deco(factory: Callable[[TrainConfig], FinetuneMethod]):
        for n in (name, *aliases):
            if n in _METHODS:
                raise ValueError(f"fine-tuning method {n!r} already registered")
            _METHODS[n] = factory
        return factory
    return deco


def get_method(name: str) -> Callable[[TrainConfig], FinetuneMethod]:
    """Resolve a registered factory; raises KeyError listing alternatives."""
    try:
        return _METHODS[name]
    except KeyError:
        raise KeyError(f"unknown fine-tuning method {name!r}; "
                       f"available: {available()}") from None


def build(name: str, tcfg: TrainConfig) -> FinetuneMethod:
    """Resolve + instantiate a method for one training configuration."""
    return get_method(name)(tcfg)


def available() -> tuple:
    return tuple(sorted(_METHODS))
