"""The masked-selection method family: one generic step factory, many
policies.

``full`` / ``adagradselect`` / ``topk_grad`` / ``random`` / ``lisa`` /
``grass`` share this implementation — grads -> per-block norms -> in-jit
policy selection (core/adagradselect registry) -> block-masked AdamW. One
compiled program serves every selection outcome: masks are runtime inputs,
so per-step dynamic selection never recompiles.

Two optimizer-state residency layouts (``opt_cfg.moment_residency``):

* ``"device"`` (default, the trajectory oracle): one fused jitted step;
  ``state["opt"] = {"m", "v", "counts"}`` with full-shape f32 moments.
* ``"banked"`` (paper §3.3): ``state["opt"] = {"banks", "slot_map",
  "counts", "store"}`` — only selected blocks' moments are device-resident,
  in compact [k]-slot banks backed by a full store (host RAM under
  ``opt_cfg.offload == "host"``). The step is two compiled phases around a
  selection-change boundary: phase A (forward + backward + in-jit
  selection) yields the mask, the boundary streams evicted/admitted
  blocks' moments store<->banks, phase B applies the banked AdamW on bank
  rows (fused slot-indexed Pallas path included). Under
  ``opt_cfg.async_swap`` (default) the boundary is overlapped: a
  ``core.swap.SwapPlanner`` prefetches the *predicted* next admit set and
  writes predicted evictions back in a background thread while phase B
  runs, so a correct prediction leaves only the bank commit on the
  critical path and a miss falls back to the synchronous swap
  (``step_fn.swap_stats.predicted_hit_rate``). Both phases compile exactly
  once — bank slots and selected indices are runtime vectors of static
  shape, identical with the async bit on or off.

With ``model_cfg.gate_weight_grads`` the mask is decided BEFORE backward
from the policy's cumulative signal and frozen blocks' weight grads are
lax.cond-gated away (DESIGN 3.3); the observed norms are then fed back via
``adagradselect.observe``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.core import (adagradselect, masked_adamw, offload,
                        partition as part_mod, swap as swap_mod)
from repro.core.offload import optimizer_memory_report
from repro.methods import registry
from repro.methods.base import TrainableReport
from repro.models import registry as model_registry
from repro.optim.schedules import learning_rate
from repro.train import step as step_mod


def _constrain(tree, shardings):
    """Pin a traced pytree to its sharding tree inside jit (maxtext-style
    output constraints): GSPMD otherwise *infers* output layouts, and a
    layout that differs from the input's would make the next step's call
    signature — and therefore a recompile — depend on the previous step.
    ``HOST_RESIDENT`` markers (and any non-Sharding entry) pass through."""
    if shardings is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s)
        if isinstance(s, jax.sharding.Sharding) else x,
        tree, shardings)


@dataclasses.dataclass(frozen=True)
class SelectionMethod:
    """FinetuneMethod for block-masked fine-tuning under one policy."""

    name: str
    sel_cfg: SelectConfig

    # -------------------------------------------------------------- state
    def slot_capacity(self, model_cfg: ModelConfig) -> int:
        """Static bank-slot / selected-index capacity: the policy's k plus
        any always-include blocks, capped at num_blocks."""
        nb = model_cfg.num_blocks
        return min(nb, self.sel_cfg.num_selected(nb)
                   + len(self.sel_cfg.always_include))

    def init_state(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                   seed: int = 0, mesh=None) -> dict:
        return step_mod.init_train_state(
            model_cfg, seed, moment_dtype=jnp.dtype(opt_cfg.moment_dtype),
            policy=self.sel_cfg.policy,
            select_k=self.slot_capacity(model_cfg),
            moment_residency=opt_cfg.moment_residency,
            store_policy=opt_cfg.offload, mesh=mesh)

    # ---------------------------------------------------------- sharding
    def state_shardings(self, model_cfg: ModelConfig,
                        opt_cfg: OptimizerConfig, state: dict, mesh) -> dict:
        """Sharding tree congruent with ``init_state``'s TrainState for
        data-parallel (or DP x TP) training on ``mesh``.

        Params follow ``distributed.sharding.param_specs`` (replicated on a
        pure-DP mesh, TP-sharded where the model axis is >1). Dense moments
        follow the params' specs, additionally ZeRO-1-sharded over ``data``
        under ``offload == "zero1"``. Banked residency keeps the compact
        [k]-slot banks replicated (they are the working set every device
        updates) while the full store shards 1/dp over ``data`` under
        ``offload == "zero1"``. Host-resident leaves (``slot_map``, a
        ``"host"``-policy store) carry the ``HOST_RESIDENT`` marker instead
        of a sharding — they are numpy, never device_put.
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed import sharding as shard_rules

        rep = NamedSharding(mesh, P())
        replicate = lambda tree: jax.tree.map(lambda _: rep, tree)  # noqa: E731
        is_spec = lambda x: isinstance(x, P)  # noqa: E731
        canon = lambda specs: jax.tree.map(  # noqa: E731
            lambda s: shard_rules.mesh_canonical_spec(s, mesh), specs,
            is_leaf=is_spec)
        as_shardings = lambda specs: jax.tree.map(  # noqa: E731
            lambda s: NamedSharding(mesh, s), specs, is_leaf=is_spec)
        # canonical specs (no trailing Nones / size-1 axes) so step outputs
        # pinned with with_sharding_constraint compare equal to the initial
        # device_put and every compiled phase stays compile-once
        p_specs = canon(shard_rules.param_specs(model_cfg, state["params"],
                                                mesh))
        p_shard = as_shardings(p_specs)
        out = {"params": p_shard, "sel": replicate(state["sel"]),
               "step": rep}
        opt = state["opt"]
        if opt_cfg.moment_residency == "device":
            if (opt_cfg.offload == "host"
                    and offload.host_memory_kind_supported()):
                # pinned_host memory kinds (TPU/GPU only)
                m_shard = offload.moment_shardings(
                    "host", p_specs, mesh, params_shapes=state["params"])
            else:
                m_specs = p_specs
                if opt_cfg.offload == "zero1":
                    m_specs = canon(shard_rules.apply_zero1(
                        p_specs, state["params"], mesh))
                m_shard = as_shardings(m_specs)
            out["opt"] = {"m": m_shard, "v": m_shard, "counts": rep}
        else:
            partition = part_mod.build_partition(model_cfg)
            opt_sh = {"banks": replicate(opt["banks"]),
                      "slot_map": shard_rules.HOST_RESIDENT,
                      "counts": rep}
            if "store" in opt:
                if opt_cfg.offload == "host":
                    opt_sh["store"] = jax.tree.map(
                        lambda _: shard_rules.HOST_RESIDENT, opt["store"])
                elif opt_cfg.offload == "zero1":
                    opt_sh["store"] = as_shardings(canon(
                        shard_rules.store_specs(partition, opt["store"],
                                                mesh)))
                else:
                    opt_sh["store"] = replicate(opt["store"])
            out["opt"] = opt_sh
        return out

    # --------------------------------------------------------------- step
    def make_step(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                  mesh=None, batch_axes=("data",), use_pallas: bool = False,
                  donate: bool = True, state_shardings=None):
        """-> ``(state, batch) -> (state, metrics)``.

        Dense residency: one jitted function. Banked residency: a Python
        driver around two jitted phases (exposed as ``.forward_select`` /
        ``.apply`` attributes) with the host-side moment swap in between.

        With ``state_shardings`` (Trainer passes the ``state_shardings()``
        tree when it runs on a mesh) every compiled phase pins its state
        outputs to the same layout it consumes, so step N+1 sees exactly the
        shardings step N produced and each phase keeps the compile-once
        guarantee under data parallelism. The batch arrives sharded over the
        data axis; because the loss is a global mean inside one jitted
        (GSPMD) program, gradients are mean-reduced over ``data`` *before*
        the in-jit selection — every device sees identical block norms and
        picks identical blocks by construction.
        """
        sel_cfg = self.sel_cfg
        model = model_registry.get(model_cfg)
        partition = part_mod.build_partition(model_cfg)
        gate = model_cfg.gate_weight_grads

        def forward_select(params, sel_state, batch):
            """Shared phase A: loss, clipped grads, per-block norms, and the
            in-jit policy selection (traced into the fused dense step and
            compiled standalone for the banked step)."""
            # gate mode decides the mask BEFORE backward (cumulative signal)
            pre_mask = None
            if gate:
                pre_mask, sel_state = adagradselect.select(
                    sel_cfg, sel_state,
                    jnp.zeros((partition.num_blocks,), jnp.float32),
                    partition.num_blocks)

            def loss_fn(p, mb):
                masks = (part_mod.layer_masks_dict(partition, pre_mask)
                         if gate else None)
                return step_mod.model_loss(model, model_cfg, p, mb,
                                           mesh=mesh, batch_axes=batch_axes,
                                           masks=masks)

            (loss, metrics), grads = step_mod.accumulate_grads(
                loss_fn, params, batch, opt_cfg.microbatch,
                jnp.dtype(opt_cfg.accum_dtype))

            grads, gnorm = masked_adamw.clip_by_global_norm(
                grads, opt_cfg.grad_clip)
            block_norms = part_mod.block_grad_norms(partition, grads,
                                                    use_pallas=use_pallas)
            if gate:
                mask = pre_mask
                # observe norms post-hoc (only computed blocks contribute)
                sel_state = adagradselect.observe(sel_cfg, sel_state,
                                                  block_norms)
            else:
                mask, sel_state = adagradselect.select(
                    sel_cfg, sel_state, block_norms, partition.num_blocks)
            return grads, mask, sel_state, loss, metrics, gnorm, block_norms

        def step_metrics(metrics, loss, gnorm, lr, mask, block_norms, step):
            return {**metrics, "loss": loss, "grad_norm": gnorm, "lr": lr,
                    "epsilon": adagradselect.epsilon(sel_cfg, step),
                    "num_selected": jnp.sum(mask.astype(jnp.int32)),
                    "mask": mask, "block_norms": block_norms}

        if opt_cfg.moment_residency == "banked":
            return self._make_banked_step(
                opt_cfg, partition, forward_select, step_metrics,
                use_pallas=use_pallas, donate=donate,
                state_shardings=state_shardings, mesh=mesh)
        if opt_cfg.moment_residency != "device":
            raise ValueError(
                f"unknown moment_residency {opt_cfg.moment_residency!r}")

        def step_fn(state, batch):
            grads, mask, sel_state, loss, metrics, gnorm, block_norms = \
                forward_select(state["params"], state["sel"], batch)
            lr = learning_rate(opt_cfg, state["step"])
            params, opt = masked_adamw.update(
                opt_cfg, partition, state["params"], grads, state["opt"],
                mask, lr, use_pallas=use_pallas)
            new_state = {"params": params, "opt": opt, "sel": sel_state,
                         "step": state["step"] + 1}
            new_state = _constrain(new_state, state_shardings)
            return new_state, step_metrics(metrics, loss, gnorm, lr, mask,
                                           block_norms, state["step"])

        return jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    def _make_banked_step(self, opt_cfg, partition, forward_select,
                          step_metrics, *, use_pallas, donate,
                          state_shardings=None, mesh=None):
        shd = state_shardings

        def fwd_fn(params, sel_state, batch):
            out = forward_select(params, sel_state, batch)
            grads, mask, sel_state, loss, metrics, gnorm, block_norms = out
            if shd is not None:
                grads = _constrain(grads, shd["params"])
                sel_state = _constrain(sel_state, shd["sel"])
            return grads, mask, sel_state, loss, metrics, gnorm, block_norms

        fwd = jax.jit(fwd_fn)

        # zero1/none stores re-place through their sharding tree after a
        # checkpoint restore; "host" stores carry markers, not shardings
        store_sh = None
        if shd is not None and isinstance(shd["opt"].get("store"), dict):
            leaves = jax.tree.leaves(shd["opt"]["store"])
            if leaves and isinstance(leaves[0], jax.sharding.Sharding):
                store_sh = shd["opt"]["store"]

        def apply_fn(params, grads, banks, counts, mask, step):
            lr = learning_rate(opt_cfg, step)
            params, banks, counts = masked_adamw.banked_update(
                opt_cfg, partition, params, grads, banks, counts, mask, lr,
                use_pallas=use_pallas)
            if shd is not None:
                params = _constrain(params, shd["params"])
                banks = _constrain(banks, shd["opt"]["banks"])
                counts = _constrain(counts, shd["opt"]["counts"])
            return params, banks, counts, lr

        # params/banks/counts are replaced 1:1 -> donate; grads have no
        # same-shaped output (moments are compact), donating them only warns
        apply = jax.jit(apply_fn,
                        donate_argnums=(0, 2, 3) if donate else ())

        nb = partition.num_blocks
        planner = swap_mod.SwapPlanner(
            partition, self.sel_cfg, nb, enabled=opt_cfg.async_swap,
            # sharded store/bank reads carry collectives: keep the boundary
            # job on this thread so its enqueue order can't interleave with
            # phase B's (see SwapPlanner.__init__)
            inline=mesh is not None and mesh.devices.size > 1)
        stats = planner.stats

        def step_fn(state, batch):
            # phase timing goes through obs.timed — one measurement feeds
            # both the SwapStats histograms (the bench JSON fields are views
            # over them) and, when tracing is on, the phase_a/swap/phase_b
            # spans of the Perfetto timeline
            with obs.timed(stats.phase_a, "phase_a"):
                grads, mask, sel_state, loss, metrics, gnorm, block_norms = \
                    fwd(state["params"], state["sel"], batch)
                # selection-change boundary: stream moments store<->banks.
                # The policy's static-shape [k] indices vector is the one
                # host sync the paper's design pays (k ids, not a
                # [num_blocks] mask).
                idx = np.asarray(sel_state["indices"])
            opt = state["opt"]
            with obs.timed(stats.swap, "swap"):
                store = offload.ensure_store_residency(opt["store"],
                                                       opt_cfg.offload,
                                                       shardings=store_sh)
                # joins any in-flight dispatch; a prediction hit leaves only
                # the commit (a few async scatters) on the critical path, a
                # miss falls back to the synchronous swap (counted in stats)
                banks, slot_map, store = planner.resolve(
                    idx, opt["banks"], store, opt["slot_map"])
            with obs.timed(stats.phase_b, "phase_b"):
                params, banks, counts, lr = apply(
                    state["params"], grads, banks, opt["counts"], mask,
                    state["step"])
                # phase B is in flight: predict step t+1's selection and
                # stage its boundary in the background (device reads inside
                # the job block on apply's outputs there, not here)
                planner.dispatch(sel_state, banks, store, slot_map)
            stats.steps += 1
            new_state = {"params": params,
                         "opt": {"banks": banks, "slot_map": slot_map,
                                 "counts": counts, "store": store},
                         "sel": sel_state, "step": state["step"] + 1}
            return new_state, step_metrics(metrics, loss, gnorm, lr, mask,
                                           block_norms, state["step"])

        # expose the compiled phases (dry-run lowering, recompile tests)
        # and the planner (trainer quiesce hooks, bench stats)
        step_fn.forward_select = fwd
        step_fn.apply = apply
        step_fn.swap_planner = planner
        step_fn.swap_stats = stats
        return step_fn

    # --------------------------------------------------------------- eval
    def eval_params(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    state: dict) -> dict:
        return state["params"]

    # ------------------------------------------------------------- report
    def trainable_param_report(self, model_cfg: ModelConfig,
                               state: dict) -> TrainableReport:
        partition = part_mod.build_partition(model_cfg)
        rep = optimizer_memory_report(partition, state["params"],
                                      self.sel_cfg.k_percent,
                                      opt_state=state["opt"])
        k = self.sel_cfg.num_selected(partition.num_blocks)
        return TrainableReport(
            method=self.name, num_params_total=rep.p_total,
            num_params_trainable=rep.p_selected, opt_bytes=rep.mem_selective,
            opt_bytes_resident=rep.mem_measured_device,
            detail=f"policy={self.sel_cfg.policy} "
                   f"k={self.sel_cfg.k_percent:.0f}% "
                   f"({k}/{partition.num_blocks} blocks/step) "
                   f"resident={rep.mem_measured_device}B "
                   f"host={rep.mem_measured_host}B")


def _selection_factory(policy: str, name: str | None = None, **overrides):
    def factory(tcfg: TrainConfig) -> SelectionMethod:
        sel = dataclasses.replace(tcfg.select, policy=policy, **overrides)
        return SelectionMethod(name=name or policy, sel_cfg=sel)
    return factory


# full FT selects every block every step; k=100% makes the memory/trainable
# accounting agree with that.
registry.register("full", "all")(
    _selection_factory("all", name="full", k_percent=100.0))
registry.register("adagradselect")(_selection_factory("adagradselect"))
registry.register("topk_grad")(_selection_factory("topk_grad"))
registry.register("random")(_selection_factory("random"))
registry.register("lisa")(_selection_factory("lisa"))
registry.register("grass")(_selection_factory("grass"))
