"""The masked-selection method family: one generic step factory, many
policies.

``full`` / ``adagradselect`` / ``topk_grad`` / ``random`` / ``lisa`` /
``grass`` share this implementation — grads -> per-block norms -> in-jit
policy selection (core/adagradselect registry) -> block-masked AdamW. One
compiled program serves every selection outcome: masks are runtime inputs,
so per-step dynamic selection never recompiles.

With ``model_cfg.gate_weight_grads`` the mask is decided BEFORE backward
from the policy's cumulative signal and frozen blocks' weight grads are
lax.cond-gated away (DESIGN 3.3); the observed norms are then fed back via
``adagradselect.observe``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.core import adagradselect, masked_adamw, partition as part_mod
from repro.core.offload import optimizer_memory_report
from repro.methods import registry
from repro.methods.base import TrainableReport
from repro.models import registry as model_registry
from repro.optim.schedules import learning_rate
from repro.train import step as step_mod


@dataclasses.dataclass(frozen=True)
class SelectionMethod:
    """FinetuneMethod for block-masked fine-tuning under one policy."""

    name: str
    sel_cfg: SelectConfig

    # -------------------------------------------------------------- state
    def init_state(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                   seed: int = 0) -> dict:
        return step_mod.init_train_state(
            model_cfg, seed, moment_dtype=jnp.dtype(opt_cfg.moment_dtype),
            policy=self.sel_cfg.policy)

    # --------------------------------------------------------------- step
    def make_step(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                  mesh=None, batch_axes=("data",), use_pallas: bool = False,
                  donate: bool = True):
        """-> jitted (state, batch) -> (state, metrics).

        state = {"params", "opt" {m,v,counts}, "sel" (policy state),
                 "step" i32}.
        """
        sel_cfg = self.sel_cfg
        model = model_registry.get(model_cfg)
        partition = part_mod.build_partition(model_cfg)
        gate = model_cfg.gate_weight_grads

        def step_fn(state, batch):
            sel_state = state["sel"]

            # gate mode decides the mask BEFORE backward (cumulative signal)
            pre_mask = None
            if gate:
                pre_mask, sel_state = adagradselect.select(
                    sel_cfg, sel_state,
                    jnp.zeros((partition.num_blocks,), jnp.float32),
                    partition.num_blocks)

            def loss_fn(params, mb):
                masks = (part_mod.layer_masks_dict(partition, pre_mask)
                         if gate else None)
                return step_mod.model_loss(model, model_cfg, params, mb,
                                           mesh=mesh, batch_axes=batch_axes,
                                           masks=masks)

            (loss, metrics), grads = step_mod.accumulate_grads(
                loss_fn, state["params"], batch, opt_cfg.microbatch,
                jnp.dtype(opt_cfg.accum_dtype))

            grads, gnorm = masked_adamw.clip_by_global_norm(
                grads, opt_cfg.grad_clip)
            block_norms = part_mod.block_grad_norms(partition, grads,
                                                    use_pallas=use_pallas)
            if gate:
                mask = pre_mask
                # observe norms post-hoc (only computed blocks contribute)
                sel_state = adagradselect.observe(sel_cfg, sel_state,
                                                  block_norms)
            else:
                mask, sel_state = adagradselect.select(
                    sel_cfg, state["sel"], block_norms, partition.num_blocks)

            lr = learning_rate(opt_cfg, state["step"])
            params, opt = masked_adamw.update(
                opt_cfg, partition, state["params"], grads, state["opt"],
                mask, lr, use_pallas=use_pallas)
            new_state = {"params": params, "opt": opt, "sel": sel_state,
                         "step": state["step"] + 1}
            metrics = {**metrics, "loss": loss, "grad_norm": gnorm, "lr": lr,
                       "epsilon": adagradselect.epsilon(sel_cfg, state["step"]),
                       "num_selected": jnp.sum(mask.astype(jnp.int32)),
                       "mask": mask, "block_norms": block_norms}
            return new_state, metrics

        return jax.jit(step_fn, donate_argnums=(0,) if donate else ())

    # --------------------------------------------------------------- eval
    def eval_params(self, model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    state: dict) -> dict:
        return state["params"]

    # ------------------------------------------------------------- report
    def trainable_param_report(self, model_cfg: ModelConfig,
                               state: dict) -> TrainableReport:
        partition = part_mod.build_partition(model_cfg)
        rep = optimizer_memory_report(partition, state["params"],
                                      self.sel_cfg.k_percent)
        k = self.sel_cfg.num_selected(partition.num_blocks)
        return TrainableReport(
            method=self.name, num_params_total=rep.p_total,
            num_params_trainable=rep.p_selected, opt_bytes=rep.mem_selective,
            detail=f"policy={self.sel_cfg.policy} "
                   f"k={self.sel_cfg.k_percent:.0f}% "
                   f"({k}/{partition.num_blocks} blocks/step)")


def _selection_factory(policy: str, name: str | None = None, **overrides):
    def factory(tcfg: TrainConfig) -> SelectionMethod:
        sel = dataclasses.replace(tcfg.select, policy=policy, **overrides)
        return SelectionMethod(name=name or policy, sel_cfg=sel)
    return factory


# full FT selects every block every step; k=100% makes the memory/trainable
# accounting agree with that.
registry.register("full", "all")(
    _selection_factory("all", name="full", k_percent=100.0))
registry.register("adagradselect")(_selection_factory("adagradselect"))
registry.register("topk_grad")(_selection_factory("topk_grad"))
registry.register("random")(_selection_factory("random"))
registry.register("lisa")(_selection_factory("lisa"))
registry.register("grass")(_selection_factory("grass"))
