"""Transformer/SSM block definitions with a uniform interface.

Every block apply returns ``(x, aux)`` where aux is a scalar auxiliary loss
(0 where not applicable) so heterogeneous stacks scan uniformly.
Residual connections live inside the block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import attention, mla, mlp, moe, norms, ssm

ZERO = jnp.zeros((), jnp.float32)


# ------------------------------------------------------------- attention block


def attn_block_init(key: jax.Array, cfg: ModelConfig, d_ff: int = 0) -> dict:
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    return {
        "ln1": norms.init(cfg.d_model, dt),
        "attn": (mla.init(k1, cfg) if cfg.use_mla else attention.init(k1, cfg)),
        "ln2": norms.init(cfg.d_model, dt),
        "mlp": mlp.init(k2, cfg.d_model, d_ff or cfg.d_ff, cfg),
    }


def attn_block_apply(params, cfg: ModelConfig, x, *, prefix_len=0, chunk_q=512,
                     positions=None, segment_ids=None):
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h = mla.apply(params["attn"], cfg, h, chunk_q=chunk_q)
    else:
        h = attention.apply(params["attn"], cfg, h, prefix_len=prefix_len,
                            chunk_q=chunk_q, positions=positions,
                            segment_ids=segment_ids)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    x = x + mlp.apply(params["mlp"], cfg, h)
    return x, ZERO


def attn_block_prefill(params, cfg: ModelConfig, x, *, cache_len, prefix_len=0,
                       chunk_q=512):
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, kv = mla.apply_prefill(params["attn"], cfg, h, cache_len=cache_len,
                                  chunk_q=chunk_q)
    else:
        h, kv = attention.apply_prefill(params["attn"], cfg, h,
                                        cache_len=cache_len,
                                        prefix_len=prefix_len, chunk_q=chunk_q)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    x = x + mlp.apply(params["mlp"], cfg, h)
    return x, kv


def attn_block_decode(params, cfg: ModelConfig, x, cache0, cache1, pos):
    """cache0/cache1: (k, v) for GQA or (ckv, kpe) for MLA."""
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, cache0, cache1 = mla.apply_decode(params["attn"], cfg, h, cache0,
                                             cache1, pos)
    else:
        h, cache0, cache1 = attention.apply_decode(params["attn"], cfg, h,
                                                   cache0, cache1, pos)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    x = x + mlp.apply(params["mlp"], cfg, h)
    return x, cache0, cache1


def attn_block_prefill_chunk(params, cfg: ModelConfig, x, k_cache, v_cache,
                             start):
    """One chunk of an incremental prefill (GQA only — chunked prefill
    rejects MLA upstream)."""
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    h, k_cache, v_cache = attention.apply_prefill_chunk(
        params["attn"], cfg, h, k_cache, v_cache, start)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    x = x + mlp.apply(params["mlp"], cfg, h)
    return x, k_cache, v_cache


def attn_block_decode_paged(params, cfg: ModelConfig, x, k_pool, v_pool,
                            pages, pos):
    """Paged-KV decode (GQA only — the paged layout rejects MLA upstream)."""
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    h, k_pool, v_pool = attention.apply_decode_paged(
        params["attn"], cfg, h, k_pool, v_pool, pages, pos)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    x = x + mlp.apply(params["mlp"], cfg, h)
    return x, k_pool, v_pool


# ------------------------------------------------------------- MoE block


def moe_block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    return {
        "ln1": norms.init(cfg.d_model, dt),
        "attn": (mla.init(k1, cfg) if cfg.use_mla else attention.init(k1, cfg)),
        "ln2": norms.init(cfg.d_model, dt),
        "moe": moe.init(k2, cfg),
    }


def moe_block_apply(params, cfg: ModelConfig, x, *, mesh=None,
                    batch_axes=("data",), chunk_q=512, positions=None,
                    segment_ids=None):
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h = mla.apply(params["attn"], cfg, h, chunk_q=chunk_q)
    else:
        h = attention.apply(params["attn"], cfg, h, chunk_q=chunk_q,
                            positions=positions, segment_ids=segment_ids)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    y, aux = moe.apply(params["moe"], cfg, h, mesh=mesh, batch_axes=batch_axes)
    return x + y, aux * cfg.router_aux_loss


def moe_block_prefill(params, cfg: ModelConfig, x, *, cache_len, mesh=None,
                      batch_axes=("data",), chunk_q=512):
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, kv = mla.apply_prefill(params["attn"], cfg, h, cache_len=cache_len,
                                  chunk_q=chunk_q)
    else:
        h, kv = attention.apply_prefill(params["attn"], cfg, h,
                                        cache_len=cache_len, chunk_q=chunk_q)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    y, _ = moe.apply(params["moe"], cfg, h, mesh=mesh, batch_axes=batch_axes)
    return x + y, kv


def moe_block_decode(params, cfg: ModelConfig, x, cache0, cache1, pos, *,
                     mesh=None, batch_axes=("data",)):
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        h, cache0, cache1 = mla.apply_decode(params["attn"], cfg, h, cache0,
                                             cache1, pos)
    else:
        h, cache0, cache1 = attention.apply_decode(params["attn"], cfg, h,
                                                   cache0, cache1, pos)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    if cfg.moe_impl == "ep" and mesh is not None:
        # masked-source EP dispatch: minimal expert FLOPs even though decode
        # activations are model-replicated (see moe.apply_ep_decode)
        y, _ = moe.apply_ep_decode(params["moe"], cfg, h, mesh, batch_axes)
    else:
        y, _ = moe.apply_dense(params["moe"], cfg, h)
    return x + y, cache0, cache1


def moe_block_prefill_chunk(params, cfg: ModelConfig, x, k_cache, v_cache,
                            start, *, mesh=None, batch_axes=("data",)):
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    h, k_cache, v_cache = attention.apply_prefill_chunk(
        params["attn"], cfg, h, k_cache, v_cache, start)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    y, _ = moe.apply(params["moe"], cfg, h, mesh=mesh, batch_axes=batch_axes)
    return x + y, k_cache, v_cache


def moe_block_decode_paged(params, cfg: ModelConfig, x, k_pool, v_pool,
                           pages, pos):
    """Paged-KV MoE decode (dense expert dispatch only — EP-MoE decode is
    mesh-coupled and stays on the dense cache path)."""
    h = norms.apply(params["ln1"], x, cfg.norm_eps)
    h, k_pool, v_pool = attention.apply_decode_paged(
        params["attn"], cfg, h, k_pool, v_pool, pages, pos)
    x = x + h
    h = norms.apply(params["ln2"], x, cfg.norm_eps)
    y, _ = moe.apply_dense(params["moe"], cfg, h)
    return x + y, k_pool, v_pool


# ------------------------------------------------------------- SSM block


def ssm_block_init(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    return {"ln": norms.init(cfg.d_model, dt), "mamba": ssm.init(key, cfg)}


def ssm_block_apply(params, cfg: ModelConfig, x):
    h = norms.apply(params["ln"], x, cfg.norm_eps)
    return x + ssm.apply(params["mamba"], cfg, h), ZERO


def ssm_block_prefill(params, cfg: ModelConfig, x):
    h = norms.apply(params["ln"], x, cfg.norm_eps)
    out, state = ssm.apply(params["mamba"], cfg, h, return_state=True)
    return x + out, state


def ssm_block_decode(params, cfg: ModelConfig, x, conv_state, ssm_state):
    h = norms.apply(params["ln"], x, cfg.norm_eps)
    out, conv_state, ssm_state = ssm.apply_decode(params["mamba"], cfg, h,
                                                  conv_state, ssm_state)
    return x + out, conv_state, ssm_state
