"""Encoder-decoder model (seamless-m4t-medium backbone).

The audio frontend is a stub: ``batch["src_embeds"]`` carries precomputed
frame embeddings [B, S_src, D]. Encoder blocks are bidirectional; decoder
blocks have causal self-attention + cross-attention to the encoder output.
Same stacked-params/scan structure as lm.py so AdaGradSelect treats encoder
and decoder blocks as separate arms.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.layers import attention, mlp, norms
from repro.models.layers import attention_core as core
from repro.models.lm import _logits, _remat, scan_stack, stack_init


# ------------------------------------------------------------- blocks


def enc_block_init(key, cfg: ModelConfig):
    return blocks.attn_block_init(key, cfg)


def enc_block_apply(p_l, cfg: ModelConfig, x):
    h = norms.apply(p_l["ln1"], x, cfg.norm_eps)
    q, k, v = attention._project_qkv(p_l["attn"], cfg, h, jnp.arange(h.shape[1]))
    out = core.chunked_attention(q, k, v, causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p_l["attn"]["wo"])
    h = norms.apply(p_l["ln2"], x, cfg.norm_eps)
    return x + mlp.apply(p_l["mlp"], cfg, h), jnp.zeros((), jnp.float32)


def dec_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "ln1": norms.init(cfg.d_model, dt),
        "self_attn": attention.init(k1, cfg),
        "ln2": norms.init(cfg.d_model, dt),
        "cross_attn": attention.init(k2, cfg),
        "ln3": norms.init(cfg.d_model, dt),
        "mlp": mlp.init(k3, cfg.d_model, cfg.d_ff, cfg),
    }


def _cross_attend(p_attn, cfg: ModelConfig, x, enc_kv, src_len=None):
    """Cross-attention: q from x, (k, v) precomputed from encoder output.
    ``src_len`` ([B] or scalar) masks cache positions beyond each row's true
    encoder length (the slot cache pads sources to max_len // ratio)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p_attn["wq"])
    if cfg.attn_bias:
        q = q + p_attn["bq"]
    k, v = enc_kv
    mask = None
    if src_len is not None:
        sl = jnp.asarray(src_len)
        sl = sl[:, None] if sl.ndim else sl
        mask = jnp.broadcast_to(jnp.arange(k.shape[1])[None, :] < sl,
                                (x.shape[0], k.shape[1]))
    out = core.full_attention(q, k, v, causal=False, kv_len_mask=mask)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p_attn["wo"])


def _enc_kv(p_attn, cfg: ModelConfig, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_attn["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_attn["wv"])
    if cfg.attn_bias:
        k = k + p_attn["bk"]
        v = v + p_attn["bv"]
    return k, v


def dec_block_apply(p_l, cfg: ModelConfig, x, enc_out):
    h = norms.apply(p_l["ln1"], x, cfg.norm_eps)
    h = attention.apply(p_l["self_attn"], cfg, h)
    x = x + h
    h = norms.apply(p_l["ln2"], x, cfg.norm_eps)
    x = x + _cross_attend(p_l["cross_attn"], cfg, h,
                          _enc_kv(p_l["cross_attn"], cfg, enc_out))
    h = norms.apply(p_l["ln3"], x, cfg.norm_eps)
    return x + mlp.apply(p_l["mlp"], cfg, h), jnp.zeros((), jnp.float32)


# ------------------------------------------------------------- model API


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)
    params = {
        "embed": {"tok": (jax.random.normal(keys[0], (cfg.padded_vocab_size,
                                                      cfg.d_model))
                          * cfg.d_model**-0.5).astype(dt)},
        "enc_layers": stack_init(lambda k: enc_block_init(k, cfg), keys[1],
                                 cfg.num_encoder_layers),
        "enc_norm": norms.init(cfg.d_model, dt),
        "dec_layers": stack_init(lambda k: dec_block_init(k, cfg), keys[2],
                                 cfg.num_layers),
        "final_norm": norms.init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": (jax.random.normal(
            keys[3], (cfg.d_model, cfg.padded_vocab_size))
            * cfg.d_model**-0.5).astype(dt)}
    return params


def encode(params, cfg: ModelConfig, src_embeds, masks=None):
    masks = masks or {}
    x = src_embeds.astype(jnp.dtype(cfg.dtype))
    x, aux = scan_stack(cfg, lambda p_l, xx: enc_block_apply(p_l, cfg, xx),
                        x, params["enc_layers"], (masks or {}).get("enc_layers"))
    return norms.apply(params["enc_norm"], x, cfg.norm_eps), aux


def apply_train(params: dict, cfg: ModelConfig, batch: dict, *, mesh=None,
                batch_axes=("data",), masks: dict | None = None):
    masks = masks or {}
    enc_out, aux = encode(params, cfg, batch["src_embeds"], masks)
    x = jnp.take(params["embed"]["tok"], batch["tokens"], axis=0)

    def body(carry, xs):
        x, a = carry
        if cfg.gate_weight_grads and masks.get("dec_layers") is not None:
            from repro.core.gated import gated_block_apply
            p_l, m_l = xs
            y, al = gated_block_apply(
                lambda pp, xx: dec_block_apply(pp, cfg, xx, enc_out), p_l, x, m_l)
        else:
            y, al = dec_block_apply(xs, cfg, x, enc_out)
        return (y, a + al), None

    dmask = masks.get("dec_layers")
    xs = ((params["dec_layers"], dmask) if (cfg.gate_weight_grads and dmask is not None)
          else params["dec_layers"])
    (x, a), _ = jax.lax.scan(_remat(body, cfg), (x, jnp.zeros((), jnp.float32)), xs)
    aux += a
    x = norms.apply(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), aux, {}


# ------------------------------------------------------------- serving


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    kvh, dh = cfg.num_kv_heads, cfg.head_dim
    ld = cfg.num_layers
    src_len = max_len // cfg.frontend_len_ratio
    return {
        "pos": jnp.zeros((batch_size,), jnp.int32),
        "src_len": jnp.zeros((batch_size,), jnp.int32),
        "k": jnp.zeros((ld, batch_size, max_len, kvh, dh), dt),
        "v": jnp.zeros((ld, batch_size, max_len, kvh, dh), dt),
        "ck": jnp.zeros((ld, batch_size, src_len, kvh, dh), dt),
        "cv": jnp.zeros((ld, batch_size, src_len, kvh, dh), dt),
    }


def prefill(params: dict, cfg: ModelConfig, batch: dict, max_len: int, *,
            mesh=None, batch_axes=("data",)):
    """Encodes src, runs the decoder over the target prefix, returns cache
    with self-attn KV + precomputed cross-attn KV."""
    enc_out, _ = encode(params, cfg, batch["src_embeds"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    cache = init_cache(cfg, b, max_len)

    def body(x, p_l):
        h = norms.apply(p_l["ln1"], x, cfg.norm_eps)
        h, kv = attention.apply_prefill(p_l["self_attn"], cfg, h,
                                        cache_len=max_len)
        x = x + h
        h = norms.apply(p_l["ln2"], x, cfg.norm_eps)
        ckv = _enc_kv(p_l["cross_attn"], cfg, enc_out)
        x = x + _cross_attend(p_l["cross_attn"], cfg, h, ckv)
        h = norms.apply(p_l["ln3"], x, cfg.norm_eps)
        x = x + mlp.apply(p_l["mlp"], cfg, h)
        return x, (kv, ckv)

    x, (kv, ckv) = jax.lax.scan(body, x, params["dec_layers"])
    cache["k"], cache["v"] = kv
    s_src = enc_out.shape[1]
    src_cache = max_len // cfg.frontend_len_ratio
    if s_src < src_cache:  # pad to the slot-cache length; decode masks by
        # per-slot src_len, so padding never changes the attention output
        pad = [(0, 0), (0, 0), (0, src_cache - s_src), (0, 0), (0, 0)]
        ckv = (jnp.pad(ckv[0], pad), jnp.pad(ckv[1], pad))
    cache["ck"], cache["cv"] = ckv
    cache["src_len"] = jnp.full((b,), s_src, jnp.int32)
    x = norms.apply(params["final_norm"], x, cfg.norm_eps)
    cache["pos"] = jnp.full((b,), s, jnp.int32)
    return _logits(params, cfg, x[:, -1:, :])[:, 0], cache


def insert_slots(cache: dict, src: dict, slots):
    from repro.models import lm
    return lm.insert_slots(cache, src, slots)


def decode_step(params: dict, cfg: ModelConfig, tokens, cache: dict, *,
                mesh=None, batch_axes=("data",)):
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32),
                           (tokens.shape[0],))
    src_len = cache.get("src_len")
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)

    def body(x, xs):
        p_l, k_c, v_c, ck, cv = xs
        h = norms.apply(p_l["ln1"], x, cfg.norm_eps)
        h, k_c, v_c = attention.apply_decode(p_l["self_attn"], cfg, h, k_c,
                                             v_c, pos)
        x = x + h
        h = norms.apply(p_l["ln2"], x, cfg.norm_eps)
        x = x + _cross_attend(p_l["cross_attn"], cfg, h, (ck, cv),
                              src_len=src_len)
        h = norms.apply(p_l["ln3"], x, cfg.norm_eps)
        x = x + mlp.apply(p_l["mlp"], cfg, h)
        return x, (k_c, v_c)

    x, (k_c, v_c) = jax.lax.scan(body, x, (params["dec_layers"], cache["k"],
                                           cache["v"], cache["ck"], cache["cv"]))
    cache = {**cache, "k": k_c, "v": v_c, "pos": pos + 1}
    x = norms.apply(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x)[:, 0], cache
