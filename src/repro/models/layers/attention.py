"""GQA/MHA attention layer with RoPE, optional QKV bias, KV caching."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import attention_core as core
from repro.models.layers.rope import apply_rope


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    """Q-heads are padded to cfg.padded_heads for TP alignment (NamedSharding
    needs exact divisibility). Padded heads are zero-MASKED at the attention
    output, so their weights receive zero gradient and the function is
    exactly the unpadded model (see _head_mask)."""
    d, kvh, dh = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    hp = cfg.padded_heads
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    sc = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, hp, dh)) * sc).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, kvh, dh)) * sc).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, kvh, dh)) * sc).astype(dt),
        "wo": (jax.random.normal(ks[3], (hp, dh, d)) * sc).astype(dt),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((hp, dh), dt)
        p["bk"] = jnp.zeros((kvh, dh), dt)
        p["bv"] = jnp.zeros((kvh, dh), dt)
    return p


def _head_mask(cfg: ModelConfig, dtype):
    hp = cfg.padded_heads
    if hp == cfg.num_heads:
        return None
    return (jnp.arange(hp) < cfg.num_heads).astype(dtype)[None, None, :, None]


def _hmap(cfg: ModelConfig):
    import numpy as np
    rep = max(1, cfg.num_heads // cfg.num_kv_heads)
    return np.minimum(np.arange(cfg.padded_heads) // rep,
                      cfg.num_kv_heads - 1)


def _project_qkv(params, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.attn_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.seq_shard_kv and x.shape[1] > 1:
        # replicated-kv fallback: force k/v sequence-sharded so GSPMD lowers
        # the projection to a local matmul + (cheap bf16) all-gather in the
        # attention einsum, instead of split-contraction + f32 all-reduce
        from jax.sharding import PartitionSpec as P
        try:
            k = jax.lax.with_sharding_constraint(k, P(None, "model", None, None))
            v = jax.lax.with_sharding_constraint(v, P(None, "model", None, None))
        except (ValueError, RuntimeError):
            pass  # no mesh context (single-device tests)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.partial_rotary_factor)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.partial_rotary_factor)
    return q, k, v


def apply(params: dict, cfg: ModelConfig, x: jax.Array, *, positions=None,
          prefix_len: int = 0, chunk_q: int = 512,
          segment_ids=None) -> jax.Array:
    """Training/prefill forward (causal). x: [B, S, D] -> [B, S, D].

    ``positions``: [S] or [B, S] RoPE positions (packed batches pass
    per-segment-reset positions). ``segment_ids``: [B, S] packed segment
    ids (0 = pad) — attention is block-diagonal over equal segments."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = core.chunked_attention(q, k, v, hmap=_hmap(cfg), chunk_q=chunk_q,
                                 causal=True, prefix_len=prefix_len,
                                 softcap=cfg.attn_logit_softcap,
                                 segment_ids=segment_ids)
    out = out.astype(x.dtype)
    hm = _head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def apply_prefill(params, cfg: ModelConfig, x, *, prefix_len: int = 0,
                  chunk_q: int = 512, cache_len: int = 0):
    """Like apply() but also returns (k, v) padded to cache_len for the cache."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = core.chunked_attention(q, k, v, hmap=_hmap(cfg), chunk_q=chunk_q,
                                 causal=True, prefix_len=prefix_len,
                                 softcap=cfg.attn_logit_softcap)
    out = out.astype(x.dtype)
    hm = _head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    if cache_len and cache_len > s:
        pad = [(0, 0), (0, cache_len - s), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return out, (k, v)


def apply_prefill_chunk(params, cfg: ModelConfig, x, k_cache, v_cache, start):
    """One chunk of an incremental prefill. x: [B, C, D] chunk tokens at
    positions [start, start+C); caches [B, Smax, KVH, Dh] carry every
    earlier chunk's K/V. Writes this chunk's K/V at ``start`` (a traced
    scalar — one compile per chunk shape, not per offset) and attends the
    chunk queries over the whole cache with the causal mask anchored at
    ``q_offset=start``; cache positions past start+C are zero AND causally
    masked, so the result equals the single-shot prefill chunk-for-chunk.
    Returns (out [B,C,D], new_k, new_v)."""
    b, c, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    positions = start + jnp.arange(c)
    q, k, v = _project_qkv(params, cfg, x, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), start, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), start, axis=1)
    out = core.full_attention(q, k_cache, v_cache, hmap=_hmap(cfg),
                              causal=True, q_offset=start,
                              softcap=cfg.attn_logit_softcap)
    out = out.astype(x.dtype)
    hm = _head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, k_cache, v_cache


def apply_decode(params, cfg: ModelConfig, x, k_cache, v_cache, pos):
    """One-token decode. x: [B, 1, D]; caches [B, Smax, KVH, Dh]; pos: scalar
    or per-row [B] vector index of the new token (per-slot positions for
    continuous batching). Returns (out [B,1,D], new_k, new_v)."""
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    q, k, v = _project_qkv(params, cfg, x, positions)
    # batched scatter: row i writes at its own pos[i]; out-of-bounds writes
    # (finished slots stepped past max_len) are dropped
    k_cache = k_cache.at[jnp.arange(b), pos].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[jnp.arange(b), pos].set(v[:, 0].astype(v_cache.dtype))
    out = core.decode_attention(q, k_cache, v_cache, pos + 1,
                                hmap=_hmap(cfg),
                                softcap=cfg.attn_logit_softcap)
    out = out.astype(x.dtype)
    hm = _head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, k_cache, v_cache


def _use_paged_kernel(cfg: ModelConfig) -> bool:
    if cfg.use_pallas == "always":
        return True
    if cfg.use_pallas == "never":
        return False
    return jax.default_backend() == "tpu"


def apply_decode_paged(params, cfg: ModelConfig, x, k_pool, v_pool, pages,
                       pos):
    """One-token decode against a shared page pool. x: [B, 1, D]; pools
    [num_pages, page_size, KVH, Dh] (one layer's slice); pages: [B,
    max_pages] i32 per-slot page tables (entries >= num_pages unallocated);
    pos: per-row [B] write position. The new K/V scatters into pool page
    ``pages[b, pos // page_size]``; writes through sentinel entries (freed
    or overrun slots) land out of bounds and drop, so a finished slot that
    keeps riding the decode chunk can never touch a reassigned page.
    Returns (out [B,1,D], new_k_pool, new_v_pool)."""
    b = x.shape[0]
    num_pages, ps = k_pool.shape[0], k_pool.shape[1]
    maxp = pages.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    q, k, v = _project_qkv(params, cfg, x, positions)
    pidx = pos // ps
    page = jnp.where(pidx < maxp,
                     pages[jnp.arange(b), jnp.minimum(pidx, maxp - 1)],
                     num_pages)
    off = pos % ps
    k_pool = k_pool.at[page, off].set(k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[page, off].set(v[:, 0].astype(v_pool.dtype))
    hmap = _hmap(cfg)
    if _use_paged_kernel(cfg):
        from repro.kernels import ops
        out = ops.paged_decode_attention(q, k_pool, v_pool, pages, pos + 1,
                                         hmap)
    else:
        # reference path: gather the row-major dense view through the table
        # (clamped — garbage rows sit past valid_len and mask to exact
        # zeros) and reuse the dense decode attention, so paged and dense
        # engines are bit-identical on this path
        tbl = jnp.minimum(pages, num_pages - 1)
        kvh, dh = k_pool.shape[2], k_pool.shape[3]
        kd = k_pool[tbl].reshape(b, maxp * ps, kvh, dh)
        vd = v_pool[tbl].reshape(b, maxp * ps, kvh, dh)
        out = core.decode_attention(q, kd, vd, pos + 1, hmap=hmap,
                                    softcap=cfg.attn_logit_softcap)
    out = out.astype(x.dtype)
    hm = _head_mask(cfg, out.dtype)
    if hm is not None:
        out = out * hm
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return out, k_pool, v_pool
