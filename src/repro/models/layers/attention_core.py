"""Attention math shared by GQA and MLA layers.

GQA uses *gather expansion*: each q head gathers its kv group via a static
index map (``head2group``) instead of reshaping H into [KVH, rep]. The
reshape-free form keeps the q-head axis cleanly shardable over the mesh
``model`` axis for ANY head count (GSPMD pads uneven dims), while kv stays
replicated (KVH < shards — the normal GQA case) or KVH-sharded (divisible).
FLOP count is identical to grouped GQA.

Execution paths:
  * ``chunked_attention``  -- q-chunked exact attention via lax.scan; the XLA
    path used for training/prefill (bounds the score-matrix working set to
    [B, H, chunk_q, S_k]).
  * ``decode_attention``   -- single-query attention against a length-masked
    KV cache.
  * Pallas flash kernels (kernels/flash_attention.py) are dispatched by the
    layer when cfg.use_pallas resolves to True on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def head2group(num_heads: int, num_kv_heads: int) -> np.ndarray:
    """Static q-head -> kv-group index map (kv-major grouping)."""
    rep = num_heads // num_kv_heads
    return np.arange(num_heads) // rep


def expand_kv(k: jax.Array, hmap: np.ndarray) -> jax.Array:
    """k: [B, S, KVH, D] -> [B, S, H, D] via static gather (identity when
    KVH == H)."""
    if k.shape[2] == hmap.shape[0] and (hmap == np.arange(len(hmap))).all():
        return k
    return k[:, :, hmap, :]


def _softcap(scores: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return jnp.tanh(scores / cap) * cap
    return scores


def full_attention(q, k, v, *, hmap=None, causal=True, q_offset=0,
                   prefix_len=0, softcap=0.0, kv_len_mask=None,
                   q_seg=None, k_seg=None):
    """Exact attention. q: [B, Sq, H, Dh]; k: [B, Sk, KVH, Dh];
    v: [B, Sk, KVH, Dv]; hmap: head2group map (None -> MHA identity).
    kv_len_mask: [B, Sk] bool of valid cache slots.
    q_seg/k_seg: [B, Sq]/[B, Sk] packed segment ids — scores are masked to
    equal-segment pairs (block-diagonal attention; combined with the causal
    row-position mask this is exactly per-example causal attention). A
    query always keeps its own position (q_seg[i] == k_seg[i] at the same
    index), so no softmax row is ever fully masked."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    if hmap is None:
        hmap = head2group(h, k.shape[2])
    ke = expand_kv(k, hmap).astype(jnp.float32)
    ve = expand_kv(v, hmap).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, ke)
    scores = _softcap(scores, softcap)
    if causal:
        q_pos = q_offset + jnp.arange(sq)
        k_pos = jnp.arange(sk)
        mask = q_pos[:, None] >= k_pos[None, :]
        if prefix_len:
            mask = mask | (k_pos[None, :] < prefix_len)
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    if kv_len_mask is not None:
        scores = jnp.where(kv_len_mask[:, None, None, :], scores, NEG_INF)
    if q_seg is not None:
        seg_ok = q_seg[:, None, :, None] == k_seg[:, None, None, :]
        scores = jnp.where(seg_ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, ve)
    return out.astype(q.dtype)


def chunked_attention(q, k, v, *, hmap=None, chunk_q=512, causal=True,
                      prefix_len=0, softcap=0.0, remat_chunks=True,
                      segment_ids=None):
    """Exact causal attention, scanned over query chunks to bound memory.
    S must be divisible by chunk_q (or <= chunk_q).

    ``segment_ids``: [B, S] packed segment ids (0 = pad) — block-diagonal
    masking as in full_attention; the query-side ids are chunked along with
    q, the key side stays whole.

    ``remat_chunks``: rematerialize each chunk's probs in the backward
    instead of stashing [nq, B, H, chunk, S] f32 residuals (that tensor is
    what blows the training peak otherwise — flash attention's backward
    makes the same trade on real hardware)."""
    b, s, h, dh = q.shape
    if s <= chunk_q:
        return full_attention(q, k, v, hmap=hmap, causal=causal,
                              prefix_len=prefix_len, softcap=softcap,
                              q_seg=segment_ids, k_seg=segment_ids)
    assert s % chunk_q == 0, (s, chunk_q)
    nq = s // chunk_q
    qs = q.reshape(b, nq, chunk_q, h, dh).transpose(1, 0, 2, 3, 4)
    segs = (None if segment_ids is None
            else segment_ids.reshape(b, nq, chunk_q).transpose(1, 0, 2))

    def body(_, args):
        i, qc, qsc = args if segs is not None else (*args, None)
        out = full_attention(qc, k, v, hmap=hmap, causal=causal,
                             q_offset=i * chunk_q, prefix_len=prefix_len,
                             softcap=softcap, q_seg=qsc,
                             k_seg=None if qsc is None else segment_ids)
        return None, out

    if remat_chunks:
        body = jax.checkpoint(body)
    xs = ((jnp.arange(nq), qs) if segs is None
          else (jnp.arange(nq), qs, segs))
    _, outs = jax.lax.scan(body, None, xs)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, v.shape[-1])


def decode_attention(q, k_cache, v_cache, cache_len, *, hmap=None, softcap=0.0):
    """q: [B, 1, H, Dh]; caches [B, Smax, KVH, D*]; cache_len: scalar int or
    per-row [B] vector — number of valid cache slots per row (the new
    token's k/v already written)."""
    sk = k_cache.shape[1]
    cl = jnp.asarray(cache_len)
    cl = cl[:, None] if cl.ndim else cl
    valid = jnp.arange(sk)[None, :] < cl
    valid = jnp.broadcast_to(valid, (q.shape[0], sk))
    return full_attention(q, k_cache, v_cache, hmap=hmap, causal=False,
                          kv_len_mask=valid, softcap=softcap)
