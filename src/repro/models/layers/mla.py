"""Multi-head Latent Attention (deepseek-v3).

Training/prefill use the *naive* expansion (latent -> per-head K/V, exact);
decode uses the *absorbed* form that attends directly in latent space, so the
KV cache is only [B, S, kv_lora_rank + qk_rope_head_dim] per layer — the
property that makes long-context decode cheap for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import attention_core as core
from repro.models.layers import norms
from repro.models.layers.rope import apply_rope


def dims(cfg: ModelConfig):
    return (cfg.q_lora_rank, cfg.kv_lora_rank, cfg.qk_nope_head_dim,
            cfg.qk_rope_head_dim, cfg.v_head_dim)


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr, nd, rd, vd = dims(cfg)
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.dtype)

    def w(k, shape, fan_in):
        return (jax.random.normal(k, shape) * fan_in**-0.5).astype(dt)

    p = {
        "wq_a": w(ks[0], (d, qr), d),
        "q_norm": norms.init(qr, dt),
        "wq_b": w(ks[1], (qr, h, nd + rd), qr),
        "wkv_a": w(ks[2], (d, kvr + rd), d),
        "kv_norm": norms.init(kvr, dt),
        "wk_b": w(ks[3], (kvr, h, nd), kvr),   # latent -> per-head K_nope
        "wv_b": w(ks[4], (kvr, h, vd), kvr),   # latent -> per-head V
        "wo": w(ks[5], (h, vd, d), h * vd),
    }
    return p


def _q_proj(params, cfg, x, positions):
    """-> q_nope [B,S,H,nd], q_pe [B,S,H,rd]"""
    qr, kvr, nd, rd, vd = dims(cfg)
    cq = norms.apply(params["q_norm"], x @ params["wq_a"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", cq, params["wq_b"])
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return q_nope, q_pe


def _kv_latent(params, cfg, x, positions):
    """-> c_kv [B,S,kvr] (normed), k_pe [B,S,1,rd] (roped, head-shared)."""
    qr, kvr, nd, rd, vd = dims(cfg)
    kv = x @ params["wkv_a"]
    c_kv, k_pe = kv[..., :kvr], kv[..., kvr:]
    c_kv = norms.apply(params["kv_norm"], c_kv, cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)
    return c_kv, k_pe


def apply(params: dict, cfg: ModelConfig, x: jax.Array, *, positions=None,
          chunk_q: int = 512) -> jax.Array:
    """Training/prefill: naive expansion, exact attention. [B,S,D]->[B,S,D]."""
    b, s, _ = x.shape
    qr, kvr, nd, rd, vd = dims(cfg)
    if positions is None:
        positions = jnp.arange(s)
    q_nope, q_pe = _q_proj(params, cfg, x, positions)
    c_kv, k_pe = _kv_latent(params, cfg, x, positions)
    k_nope = jnp.einsum("bsc,chk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsc,chk->bshk", c_kv, params["wv_b"])
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (b, s, cfg.num_heads, rd))], axis=-1)
    # chunked_attention scales by q.shape[-1]**-0.5 == (nd+rd)**-0.5 itself.
    out = core.chunked_attention(q, k, v, chunk_q=chunk_q, causal=True)
    return jnp.einsum("bshv,hvd->bsd", out.astype(x.dtype), params["wo"])


def apply_prefill(params, cfg, x, *, chunk_q: int = 512, cache_len: int = 0):
    """Prefill returning the latent cache (c_kv, k_pe)."""
    b, s, _ = x.shape
    out = apply(params, cfg, x, chunk_q=chunk_q)
    c_kv, k_pe = _kv_latent(params, cfg, x, jnp.arange(s))
    k_pe = k_pe[:, :, 0, :]
    if cache_len and cache_len > s:
        c_kv = jnp.pad(c_kv, [(0, 0), (0, cache_len - s), (0, 0)])
        k_pe = jnp.pad(k_pe, [(0, 0), (0, cache_len - s), (0, 0)])
    return out, (c_kv, k_pe)


def apply_decode(params, cfg: ModelConfig, x, ckv_cache, kpe_cache, pos):
    """Absorbed-form decode. x [B,1,D]; ckv_cache [B,Smax,kvr];
    kpe_cache [B,Smax,rd]. Scores computed in latent space:
      score = q_nope @ Wk_b^T · c_kv + q_pe · k_pe
      out   = (probs @ c_kv) @ Wv_b  (then Wo)
    Per-token cost is O(S·(kvr+rd)·H) instead of O(S·H·(nd+rd)) with a
    materialized per-head cache ~9x larger.
    """
    b = x.shape[0]
    qr, kvr, nd, rd, vd = dims(cfg)
    h = cfg.num_heads
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    q_nope, q_pe = _q_proj(params, cfg, x, positions)          # [B,1,H,nd],[B,1,H,rd]
    c_new, kpe_new = _kv_latent(params, cfg, x, positions)     # [B,1,kvr],[B,1,1,rd]
    rows = jnp.arange(b)
    ckv_cache = ckv_cache.at[rows, pos].set(c_new[:, 0].astype(ckv_cache.dtype))
    kpe_cache = kpe_cache.at[rows, pos].set(
        kpe_new[:, 0, 0, :].astype(kpe_cache.dtype))
    # absorb: q_lat [B,1,H,kvr]
    q_lat = jnp.einsum("bshn,chn->bshc", q_nope, params["wk_b"])
    smax = ckv_cache.shape[1]
    scale = (nd + rd) ** -0.5
    scores = (jnp.einsum("bshc,btc->bhst", q_lat.astype(jnp.float32),
                         ckv_cache.astype(jnp.float32))
              + jnp.einsum("bshr,btr->bhst", q_pe.astype(jnp.float32),
                           kpe_cache.astype(jnp.float32))) * scale
    valid = (jnp.arange(smax)[None, :] <= pos[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, core.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)                    # [B,H,1,Smax]
    o_lat = jnp.einsum("bhst,btc->bshc", probs, ckv_cache.astype(jnp.float32))
    out = jnp.einsum("bshc,chv->bshv", o_lat, params["wv_b"].astype(jnp.float32))
    out = jnp.einsum("bshv,hvd->bsd", out.astype(x.dtype), params["wo"])
    return out, ckv_cache, kpe_cache
