"""Gated (SwiGLU/GeGLU) feed-forward layer — the U/G/D projections LoRA targets."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def init(key: jax.Array, d_model: int, d_ff: int, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wg": (jax.random.normal(ks[0], (d_model, d_ff)) * d_model**-0.5).astype(dt),
        "wu": (jax.random.normal(ks[1], (d_model, d_ff)) * d_model**-0.5).astype(dt),
        "wd": (jax.random.normal(ks[2], (d_ff, d_model)) * d_ff**-0.5).astype(dt),
    }


def apply(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = _act(cfg.act)(x @ params["wg"]) * (x @ params["wu"])
    return h @ params["wd"]
