"""Mixture-of-Experts FFN: top-k routing, shared experts, two execution paths.

``moe_impl="dense"``  — oracle: every expert computes every token, outputs are
    combined with the (sparse) routing weights. Exact top-k semantics with no
    capacity drops; used for small configs, tests, and as the reference the EP
    path is validated against.

``moe_impl="ep"``     — production expert parallelism: tokens are sharded over
    the mesh, experts are sharded over the ``model`` axis, and routing happens
    via sort + capacity-bucketed ``all_to_all`` inside ``shard_map`` (the
    deepseek-style dispatch/combine pattern, TPU-ICI native rather than a
    NCCL port). Overflowing tokens beyond capacity are dropped (standard).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers.mlp import _act


# ---------------------------------------------------------------- params


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    dt = jnp.dtype(cfg.dtype)

    def w(k, shape, fan_in):
        return (jax.random.normal(k, shape) * fan_in**-0.5).astype(dt)

    p = {
        "router": w(ks[0], (d, e), d).astype(jnp.float32),  # router kept f32
        "wg": w(ks[1], (e, d, f), d),
        "wu": w(ks[2], (e, d, f), d),
        "wd": w(ks[3], (e, f, d), f),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        sk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": w(sk[0], (d, fs), d),
            "wu": w(sk[1], (d, fs), d),
            "wd": w(sk[2], (fs, d), fs),
        }
    return p


def _route(cfg: ModelConfig, router_w, x_tokens):
    """x_tokens [T, D] -> (gates [T, K] f32, ids [T, K] i32, aux_loss scalar)."""
    logits = x_tokens.astype(jnp.float32) @ router_w          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, ids = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gates = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)
    # load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)                               # mean prob per expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce)
    return gates, ids, aux


def _expert_ffn(cfg: ModelConfig, wg, wu, wd, x):
    """x [..., D] with per-expert weights already selected."""
    h = _act(cfg.act)(x @ wg) * (x @ wu)
    return h @ wd


def _shared_ffn(cfg: ModelConfig, p, x):
    h = _act(cfg.act)(x @ p["wg"]) * (x @ p["wu"])
    return h @ p["wd"]


# ---------------------------------------------------------------- dense oracle


def apply_dense(params: dict, cfg: ModelConfig, x: jax.Array):
    """[B, S, D] -> ([B, S, D], aux_loss). Every expert runs on every token."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    gates, ids, aux = _route(cfg, params["router"], xt)
    # combine weights [T, E]: sum of gate where expert chosen
    comb = jnp.zeros((xt.shape[0], cfg.num_experts), jnp.float32)
    comb = comb.at[jnp.arange(xt.shape[0])[:, None], ids].add(gates)
    # all experts on all tokens: [E, T, D]
    outs = jax.vmap(lambda wg, wu, wd: _expert_ffn(cfg, wg, wu, wd, xt))(
        params["wg"], params["wu"], params["wd"])
    y = jnp.einsum("etd,te->td", outs.astype(jnp.float32), comb).astype(x.dtype)
    if cfg.num_shared_experts:
        y = y + _shared_ffn(cfg, params["shared"], xt)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------- EP path


def _masked_gather(x, idx, valid):
    """rows = x_padded[idx'] with invalid entries reading a zero pad row —
    self-masking, so no full-size select/where ever materializes (XLA:CPU
    loop-fuses selects with re-reads of every operand per output tile)."""
    n = x.shape[0]
    x_pad = jnp.pad(x, ((0, 1), (0, 0)))
    idx2 = jnp.where(valid, idx, n)          # [rows] int op — cheap
    return x_pad[idx2]


@jax.custom_vjp
def _permute_rows(x, fwd_idx, bwd_idx, fwd_valid, bwd_valid):
    """Gather-only row permutation: out[i] = fwd_valid[i] ? x[fwd_idx[i]] : 0.

    The VJP of a gather is a scatter-add — which XLA:CPU lowers to a serial
    row-update loop and which is the slow path on TPU too. Because our
    dispatch indices form a (partial) permutation, the backward is itself a
    gather with the precomputed inverse index map, so we define it that way:
        dx[j] = bwd_valid[j] ? dout[bwd_idx[j]] : 0.
    Both directions are single fused zero-padded gathers (Megablocks-style
    dispatch)."""
    return _masked_gather(x, fwd_idx, fwd_valid)


def _permute_rows_fwd(x, fwd_idx, bwd_idx, fwd_valid, bwd_valid):
    return _masked_gather(x, fwd_idx, fwd_valid), \
        (fwd_idx, bwd_idx, fwd_valid, bwd_valid)


def _permute_rows_bwd(res, g):
    import numpy as np
    fwd_idx, bwd_idx, fwd_valid, bwd_valid = res
    dx = _masked_gather(g, bwd_idx, bwd_valid)
    f0 = lambda a: np.zeros(a.shape, jax.dtypes.float0)  # noqa: E731
    return dx, f0(fwd_idx), f0(bwd_idx), f0(fwd_valid), f0(bwd_valid)


_permute_rows.defvjp(_permute_rows_fwd, _permute_rows_bwd)


def _dispatch_indices(dest, num_buckets, capacity):
    """dest [N] int32 bucket ids -> (slot [N] int32 in [0, buckets*cap], valid [N]).

    Entries are packed in stable order within each bucket; rank >= capacity
    is dropped (valid=False). Invalid entries get slot == buckets*capacity —
    callers must allocate one extra trash row so scatters never clobber
    real slots.
    """
    n = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    d_sorted = dest[order]
    counts = jnp.bincount(dest, length=num_buckets)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(n) - starts[d_sorted]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    valid = rank < capacity
    slot = jnp.where(valid, dest * capacity + rank, num_buckets * capacity)
    return slot, valid


def _ep_local(cfg: ModelConfig, params, x_loc, *, axis_name, num_shards,
              extra_axes=(), source_mask=None):
    """Body run per-device inside shard_map. x_loc: [t_loc, D].
    ``source_mask``: optional scalar bool — False disables dispatch from this
    device entirely (used by the decode path, where x is model-replicated)."""
    t_loc, d = x_loc.shape
    k = cfg.num_experts_per_tok
    e_loc = cfg.num_experts // num_shards
    gates, ids, aux = _route(cfg, params["router"], x_loc)
    for ax in (axis_name, *extra_axes):
        aux = jax.lax.pmean(aux, ax)

    n = t_loc * k
    fid = ids.reshape(n)                                  # global expert id per entry
    src = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
    gate_flat = gates.reshape(n)
    dest_shard = fid // e_loc

    cap_send = max(1, int(-(-n // num_shards) * cfg.capacity_factor))
    slot, valid = _dispatch_indices(dest_shard, num_shards, cap_send)
    if source_mask is not None:
        valid = valid & source_mask
        slot = jnp.where(valid, slot, num_shards * cap_send)

    # --- entry-expanded tokens via broadcast (VJP = reshape-sum, no scatter)
    xe = jnp.broadcast_to(x_loc[:, None, :], (t_loc, k, d)).reshape(n, d)

    # --- send buffers via gather-only permutation (index arrays built with
    # cheap int32 scatters; row movement is gathers in fwd AND bwd)
    inv = jnp.full((num_shards * cap_send + 1,), n, jnp.int32)
    inv = inv.at[slot].set(jnp.arange(n, dtype=jnp.int32))    # slot -> entry
    inv = inv[:-1]
    slot_valid = inv < n
    inv_c = jnp.minimum(inv, n - 1)
    slot_c = jnp.minimum(slot, num_shards * cap_send - 1)
    sbuf = _permute_rows(xe, inv_c, slot_c, slot_valid, valid)
    sbuf = sbuf.reshape(num_shards, cap_send, d)
    fid_padded = jnp.concatenate([fid, jnp.zeros((1,), fid.dtype)])
    s_eid = jnp.where(slot_valid, (fid_padded[inv_c] % e_loc).astype(jnp.int32),
                      -1).reshape(num_shards, cap_send)

    # --- all_to_all: row j of rbuf is what shard j sent to me
    rbuf = jax.lax.all_to_all(sbuf, axis_name, 0, 0, tiled=True)
    r_eid = jax.lax.all_to_all(s_eid, axis_name, 0, 0, tiled=True)

    # --- local expert compute with a second capacity bucketing by expert
    rows = rbuf.reshape(num_shards * cap_send, d)
    eids = r_eid.reshape(num_shards * cap_send)
    nr = rows.shape[0]
    r_valid = eids >= 0
    cap_e = max(1, int(-(-(num_shards * cap_send) // e_loc) * cfg.capacity_factor))
    eslot, evalid = _dispatch_indices(jnp.where(r_valid, eids, 0), e_loc, cap_e)
    evalid = evalid & r_valid
    eslot = jnp.where(evalid, eslot, e_loc * cap_e)       # invalids -> trash
    einv = jnp.full((e_loc * cap_e + 1,), nr, jnp.int32)
    einv = einv.at[eslot].set(jnp.arange(nr, dtype=jnp.int32))
    einv = einv[:-1]
    e_valid_slot = einv < nr
    einv_c = jnp.minimum(einv, nr - 1)
    eslot_c = jnp.minimum(eslot, e_loc * cap_e - 1)
    ebuf = _permute_rows(rows, einv_c, eslot_c, e_valid_slot, evalid)
    ebuf = ebuf.reshape(e_loc, cap_e, d)
    h = jax.vmap(lambda wg, wu, wd, xe_: _expert_ffn(cfg, wg, wu, wd, xe_))(
        params["wg"], params["wu"], params["wd"], ebuf)     # [e_loc, cap_e, D]
    out_rows = _permute_rows(h.reshape(e_loc * cap_e, d), eslot_c, einv_c,
                             evalid, e_valid_slot)

    # --- reply all_to_all back to senders (same [shard, cap] layout)
    obuf = out_rows.reshape(num_shards, cap_send, d)
    back = jax.lax.all_to_all(obuf, axis_name, 0, 0, tiled=True)
    back = back.reshape(num_shards * cap_send, d)

    # --- combine at source: entries are token-major, so the combine is a
    # reshape-sum (no scatter-add)
    contrib = _permute_rows(back, slot_c, inv_c, valid, slot_valid)
    y = (contrib.astype(jnp.float32) * gate_flat[:, None]).reshape(
        t_loc, k, d).sum(axis=1)
    y = y.astype(x_loc.dtype)
    if cfg.num_shared_experts:
        y = y + _shared_ffn(cfg, params["shared"], x_loc)
    return y, aux


def apply_ep(params: dict, cfg: ModelConfig, x: jax.Array, mesh,
             batch_axes=("data",), model_axis="model"):
    """[B, S, D] -> ([B, S, D], aux). Tokens sharded over (batch_axes x
    model); experts over ``cfg.ep_axes`` (e.g. ("model",) for <=16-way EP,
    ("model","data") for deepseek's 256-expert 1-per-chip layout). The
    dispatch/combine all_to_all spans exactly the ep_axes plane."""
    ep_axes = tuple(ax for ax in cfg.ep_axes if ax in mesh.shape)
    num_shards = 1
    for ax in ep_axes:
        num_shards *= mesh.shape[ax]
    assert cfg.num_experts % num_shards == 0, (cfg.num_experts, num_shards)
    other_axes = tuple(ax for ax in (*batch_axes, model_axis)
                       if ax not in ep_axes)

    def body(xs, router, wg, wu, wd, shared):
        p = {"router": router, "wg": wg, "wu": wu, "wd": wd}
        if shared is not None:
            p["shared"] = shared
        b_loc, s_loc, d = xs.shape
        y, aux = _ep_local(cfg, p, xs.reshape(b_loc * s_loc, d),
                           axis_name=ep_axes, num_shards=num_shards,
                           extra_axes=other_axes)
        return y.reshape(b_loc, s_loc, d), aux

    shared = params.get("shared")
    espec = P(ep_axes)
    in_specs = (
        P(batch_axes, model_axis, None),           # x: batch over data, seq over model
        P(), espec, espec, espec,
        None if shared is None else P(),
    )
    out_specs = (P(batch_axes, model_axis, None), P())
    from repro.distributed.sharding import shard_map
    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(x, params["router"], params["wg"], params["wu"], params["wd"], shared)
    return y, aux


def apply_ep_decode(params: dict, cfg: ModelConfig, x: jax.Array, mesh,
                    batch_axes=("data",), model_axis="model"):
    """Decode-time EP: x [B, 1, D] is *replicated* along the model axis (the
    attention path keeps activations model-replicated at decode). Only the
    model-rank-0 copy dispatches tokens — otherwise every expert shard would
    compute ``model``-many duplicates — and the combined output is psum-
    broadcast back along the model axis."""
    ep_axes = tuple(ax for ax in cfg.ep_axes if ax in mesh.shape)
    num_shards = 1
    for ax in ep_axes:
        num_shards *= mesh.shape[ax]
    other_axes = tuple(ax for ax in (*batch_axes, model_axis)
                       if ax not in ep_axes)

    def body(xs, router, wg, wu, wd, shared):
        p = {"router": router, "wg": wg, "wu": wu, "wd": wd}
        if shared is not None:
            p["shared"] = shared
        b_loc, s_loc, d = xs.shape
        x_loc = xs.reshape(b_loc * s_loc, d)
        is_src = jax.lax.axis_index(model_axis) == 0
        y, aux = _ep_local(cfg, p, jnp.where(is_src, x_loc, 0),
                           axis_name=ep_axes, num_shards=num_shards,
                           extra_axes=other_axes, source_mask=is_src)
        y = jax.lax.psum(jnp.where(is_src, y, 0), model_axis)
        return y.reshape(b_loc, s_loc, d), aux

    shared = params.get("shared")
    espec = P(ep_axes)
    in_specs = (
        P(batch_axes, None, None),
        P(), espec, espec, espec,
        None if shared is None else P(),
    )
    out_specs = (P(batch_axes, None, None), P())
    from repro.distributed.sharding import shard_map
    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )(x, params["router"], params["wg"], params["wu"], params["wd"], shared)
    return y, aux


def apply(params: dict, cfg: ModelConfig, x: jax.Array, mesh=None,
          batch_axes=("data",), model_axis="model"):
    if cfg.moe_impl == "ep" and mesh is not None:
        return apply_ep(params, cfg, x, mesh, batch_axes, model_axis)
    return apply_dense(params, cfg, x)
