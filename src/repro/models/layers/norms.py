"""RMSNorm (f32 statistics, cast back to input dtype)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def apply(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
