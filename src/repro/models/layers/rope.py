"""Rotary position embeddings with partial-rotary support (chatglm '2d RoPE')."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rotary_angles(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [*P] -> (cos, sin) each [*P, dim//2] in f32."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # [*P, dim//2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               partial_factor: float = 1.0) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] or [S]. Rotates the first
    ``partial_factor * Dh`` dims (interleaved-pair convention), passes the
    rest through unchanged."""
    dh = x.shape[-1]
    rot = int(dh * partial_factor)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    if positions.ndim == 1:
        positions = positions[None, :]
    cos, sin = rotary_angles(positions, rot, theta)          # [B, S, rot//2]
    cos = cos[:, :, None, :]                                  # [B, S, 1, rot//2]
    sin = sin[:, :, None, :]
    xf = x_rot.astype(jnp.float32)
    x1, x2 = xf[..., 0::2], xf[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape).astype(x.dtype)
    return jnp.concatenate([out, x_pass], axis=-1) if rot < dh else out
