"""Mamba2 block (SSD — state-space duality), chunked-scan implementation.

The SSD computation follows the minimal discrete form of arXiv:2405.21060:
within-chunk quadratic (attention-like) term + inter-chunk linear recurrence,
scanned over chunks so the [B, H, Q, Q] score tensor for only one chunk is
live at a time. All SSD math in f32.

Projections are SPLIT (z / x / B / C / dt instead of one fused in_proj) so
every channel dimension shards cleanly over the mesh ``model`` axis and the
x-channel sharding aligns with SSD head boundaries (heads_per_shard * P
channels per shard) — a fused projection would put the z/xBC/dt split points
inside shards and force resharding collectives (see distributed/sharding.py).

Decode keeps O(1) state per layer: conv windows [B, K-1, channels] per
conv'd stream and SSM state [B, H, P, N].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import norms


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    gn = cfg.ssm_ngroups * cfg.ssm_state
    return d_inner, nheads, gn


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, nheads, gn = dims(cfg)
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.dtype)

    def w(k, shape, fan_in):
        return (jax.random.normal(k, shape) * fan_in**-0.5).astype(dt)

    return {
        "proj_z": w(ks[0], (d, d_inner), d),
        "proj_x": w(ks[1], (d, d_inner), d),
        "proj_b": w(ks[2], (d, gn), d),
        "proj_c": w(ks[3], (d, gn), d),
        "proj_dt": w(ks[4], (d, nheads), d),
        "conv_x": (jax.random.normal(ks[5], (cfg.ssm_conv, d_inner)) * 0.1).astype(dt),
        "conv_b_mat": (jax.random.normal(ks[6], (cfg.ssm_conv, gn)) * 0.1).astype(dt),
        "conv_c_mat": (jax.random.normal(ks[7], (cfg.ssm_conv, gn)) * 0.1).astype(dt),
        "cbias_x": jnp.zeros((d_inner,), dt),
        "cbias_b": jnp.zeros((gn,), dt),
        "cbias_c": jnp.zeros((gn,), dt),
        "A_log": jnp.log(jnp.arange(1, nheads + 1, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(
            jax.random.uniform(jax.random.fold_in(key, 99), (nheads,),
                               jnp.float32, jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": norms.init(d_inner, dt),
        "out_proj": w(jax.random.fold_in(key, 100), (d_inner, d), d_inner),
    }


def _causal_conv(x, conv_w, conv_b):
    """Depthwise causal conv + SiLU. x [B, S, C]; conv_w [K, C]."""
    k = conv_w.shape[0]
    pad = jnp.pad(x, [(0, 0), (k - 1, 0), (0, 0)])
    out = sum(pad[:, i:i + x.shape[1], :] * conv_w[i] for i in range(k))
    return jax.nn.silu(out + conv_b)


def _expand_groups(m, nheads, g):
    """[.., G, N] -> [.., H, N] by repeating each group H//G times."""
    if g == 1:
        return jnp.broadcast_to(m, (*m.shape[:-2], nheads, m.shape[-1]))
    return jnp.repeat(m, nheads // g, axis=-2)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, initial_state=None):
    """SSD scan. x [B,S,H,P] (f32), dt [B,S,H], a [H] (negative),
    b_mat/c_mat [B,S,H,N]. Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    u = x * dt[..., None]                      # [B,S,H,P]
    adt = a[None, None, :] * dt                # [B,S,H]

    def resh(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))    # [NC, B, Q, ...]

    u_c, adt_c, b_c, c_c = map(resh, (u, adt, b_mat, c_mat))
    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32)
          if initial_state is None else initial_state)

    def body(state, args):
        uq, aq, bq, cq = args                  # [B,Q,H,P], [B,Q,H], [B,Q,H,N] x2
        cum = jnp.cumsum(aq, axis=1)           # [B,Q,H]
        cum_last = cum[:, -1]                  # [B,H]
        # within-chunk decay L[i,j] = exp(cum_i - cum_j), i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]   # [B,Qi,Qj,H]
        q = aq.shape[1]
        tri = jnp.tril(jnp.ones((q, q), bool))
        l_mat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", cq, bq)
        y_diag = jnp.einsum("bijh,bjhp->bihp", scores * l_mat, uq)
        # contribution of the incoming state
        decay_in = jnp.exp(cum)                # [B,Q,H]
        y_off = jnp.einsum("bihn,bhpn,bih->bihp", cq, state, decay_in)
        # this chunk's contribution to the state
        decay_out = jnp.exp(cum_last[:, None] - cum)  # [B,Q,H]
        chunk_state = jnp.einsum("bjhn,bjh,bjhp->bhpn", bq, decay_out, uq)
        new_state = jnp.exp(cum_last)[..., None, None] * state + chunk_state
        return new_state, y_diag + y_off

    final_state, ys = jax.lax.scan(body, s0, (u_c, adt_c, b_c, c_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, h, p)
    return y, final_state


def _project(params, cfg: ModelConfig, x):
    d_inner, nheads, gn = dims(cfg)
    z = x @ params["proj_z"]
    xr = x @ params["proj_x"]
    br = x @ params["proj_b"]
    cr = x @ params["proj_c"]
    dt_raw = x @ params["proj_dt"]
    return z, xr, br, cr, dt_raw


def apply(params: dict, cfg: ModelConfig, x: jax.Array,
          return_state: bool = False):
    """Training/prefill forward. x [B, S, D] -> [B, S, D]
    (+ (conv_state {x,b,c}, ssm_state) if return_state)."""
    bsz, s, d = x.shape
    d_inner, nheads, gn = dims(cfg)
    z, xr, br, cr, dt_raw = _project(params, cfg, x)
    xc = _causal_conv(xr, params["conv_x"], params["cbias_x"])
    bc = _causal_conv(br, params["conv_b_mat"], params["cbias_b"])
    cc = _causal_conv(cr, params["conv_c_mat"], params["cbias_c"])
    xs = xc.reshape(bsz, s, nheads, cfg.ssm_head_dim)
    b_mat = bc.reshape(bsz, s, cfg.ssm_ngroups, cfg.ssm_state)
    c_mat = cc.reshape(bsz, s, cfg.ssm_ngroups, cfg.ssm_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    b_h = _expand_groups(b_mat, nheads, cfg.ssm_ngroups).astype(jnp.float32)
    c_h = _expand_groups(c_mat, nheads, cfg.ssm_ngroups).astype(jnp.float32)
    chunk = min(cfg.ssm_chunk, s)
    y, final_state = ssd_chunked(xs.astype(jnp.float32), dt, a, b_h, c_h, chunk)
    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = norms.apply(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"]
    if not return_state:
        return out
    km1 = cfg.ssm_conv - 1
    conv_state = {
        "x": xr[:, s - km1:, :].astype(x.dtype),
        "b": br[:, s - km1:, :].astype(x.dtype),
        "c": cr[:, s - km1:, :].astype(x.dtype),
    }
    return out, (conv_state, final_state)


def _conv_step(window, new, conv_w, conv_b):
    """window [B, K-1, C]; new [B, 1, C] -> (act [B, C], new window)."""
    w = jnp.concatenate([window, new.astype(window.dtype)], axis=1)
    out = jnp.einsum("bkc,kc->bc", w.astype(jnp.float32),
                     conv_w.astype(jnp.float32))
    return jax.nn.silu(out + conv_b.astype(jnp.float32)), w[:, 1:, :]


def apply_decode(params: dict, cfg: ModelConfig, x, conv_state, ssm_state):
    """One-token step. x [B, 1, D]; conv_state {x,b,c}: [B, K-1, C];
    ssm_state [B, H, P, N] (f32). Returns (out, conv_state, ssm_state)."""
    bsz = x.shape[0]
    d_inner, nheads, gn = dims(cfg)
    z, xr, br, cr, dt_raw = _project(params, cfg, x)
    xa, wx = _conv_step(conv_state["x"], xr, params["conv_x"], params["cbias_x"])
    ba, wb = _conv_step(conv_state["b"], br, params["conv_b_mat"], params["cbias_b"])
    ca, wc = _conv_step(conv_state["c"], cr, params["conv_c_mat"], params["cbias_c"])
    xs = xa.reshape(bsz, nheads, cfg.ssm_head_dim)
    b_mat = ba.reshape(bsz, cfg.ssm_ngroups, cfg.ssm_state)
    c_mat = ca.reshape(bsz, cfg.ssm_ngroups, cfg.ssm_state)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    b_h = _expand_groups(b_mat, nheads, cfg.ssm_ngroups)
    c_h = _expand_groups(c_mat, nheads, cfg.ssm_ngroups)
    da = jnp.exp(a[None] * dt)                                # [B,H]
    new_state = (ssm_state * da[..., None, None]
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt, b_h, xs))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, c_h)
    y = y + xs * params["D"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = norms.apply(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"x": wx, "b": wb, "c": wc}, new_state
