"""Decoder-only language model assembly (dense / moe / ssm / hybrid / vlm).

Parameters for the L transformer blocks are STACKED (leading axis L) and the
body is a ``lax.scan`` over layers. This is what makes AdaGradSelect's
per-step dynamic block selection recompile-free: block masks become runtime
vectors indexed by scan position (see core/partition.py).

Uniform API (registry.py exposes the same for encdec):
    init(key, cfg)                                    -> params
    apply_train(params, cfg, batch, ...)              -> (logits, aux, extra)
    init_cache(cfg, batch_size, max_len)              -> cache
    prefill(params, cfg, batch, max_len, ...)         -> (last_logits, cache)
    decode_step(params, cfg, tokens, cache, ...)      -> (logits, cache)

``batch``: {"tokens": [B,S] i32, optional "patch_embeds": [B,Np,D]}.
Returned logits are aligned with batch["tokens"] positions for every family.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.layers import norms

# --------------------------------------------------------------- utilities


def stack_init(init_fn, key, n):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


def _gated(apply_fn, p_l, x, mask_bit):
    from repro.core.gated import gated_block_apply
    return gated_block_apply(apply_fn, p_l, x, mask_bit)


def scan_stack(cfg: ModelConfig, apply_fn, x, stacked, masks=None):
    """Scan ``apply_fn(params_l, x) -> (x, aux)`` over a stacked param group.
    If cfg.gate_weight_grads and masks ([L] f32/bool) given, frozen layers
    skip their weight-gradient computation via lax.cond (DESIGN 3.3)."""
    gate = cfg.gate_weight_grads and masks is not None

    def body(carry, xs):
        x, aux = carry
        if gate:
            p_l, m_l = xs
            y, a = _gated(apply_fn, p_l, x, m_l)
        else:
            y, a = apply_fn(xs, x)
        return (y, aux + a), None

    xs = (stacked, masks) if gate else stacked
    (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux


def _embed_tokens(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if cfg.family == "vlm":  # gemma-style embedding scale
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _logits(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]["w"])
    if cfg.logits_softcap:
        out = jnp.tanh(out / cfg.logits_softcap) * cfg.logits_softcap
    vp = cfg.padded_vocab_size
    if vp != cfg.vocab_size:
        # TP-alignment vocab padding: pad logits masked to -inf (exact CE,
        # never decoded)
        bias = jnp.where(jnp.arange(vp) < cfg.vocab_size, 0.0, -1e30)
        out = out + bias.astype(out.dtype)
    return out


def _hybrid_split(cfg: ModelConfig):
    p = cfg.shared_attn_period
    nsite = cfg.num_layers // p
    rem = cfg.num_layers - nsite * p
    return p, nsite, rem


# --------------------------------------------------------------- init


def init(key: jax.Array, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": {"tok": (jax.random.normal(keys[0], (cfg.padded_vocab_size,
                                                      cfg.d_model))
                          * cfg.d_model**-0.5).astype(dt)},
        "final_norm": norms.init(cfg.d_model, dt),
    }
    if cfg.family in ("dense", "vlm"):
        params["layers"] = stack_init(
            lambda k: blocks.attn_block_init(k, cfg), keys[1], cfg.num_layers)
    elif cfg.family == "moe":
        if cfg.first_k_dense:
            params["dense_layers"] = stack_init(
                lambda k: blocks.attn_block_init(k, cfg), keys[1], cfg.first_k_dense)
        params["moe_layers"] = stack_init(
            lambda k: blocks.moe_block_init(k, cfg), keys[2],
            cfg.num_layers - cfg.first_k_dense)
    elif cfg.family == "ssm":
        params["layers"] = stack_init(
            lambda k: blocks.ssm_block_init(k, cfg), keys[1], cfg.num_layers)
    elif cfg.family == "hybrid":
        params["layers"] = stack_init(
            lambda k: blocks.ssm_block_init(k, cfg), keys[1], cfg.num_layers)
        params["shared_attn"] = blocks.attn_block_init(keys[2], cfg)
    else:
        raise ValueError(cfg.family)
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": (jax.random.normal(
            keys[3], (cfg.d_model, cfg.padded_vocab_size))
            * cfg.d_model**-0.5).astype(dt)}
    if cfg.mtp_depth:
        params["mtp"] = {
            "norm_h": norms.init(cfg.d_model, dt),
            "norm_e": norms.init(cfg.d_model, dt),
            "proj": (jax.random.normal(keys[4], (2 * cfg.d_model, cfg.d_model))
                     * (2 * cfg.d_model)**-0.5).astype(dt),
            "block": (blocks.moe_block_init(keys[5], cfg) if cfg.family == "moe"
                      else blocks.attn_block_init(keys[5], cfg)),
        }
    return params


# --------------------------------------------------------------- train fwd


def _check_packed_support(cfg: ModelConfig):
    """Packed batches need block-diagonal attention; families whose token
    mixing is not per-position-maskable (SSM state scans, the shared-attn
    hybrid) and the MLA/vlm paths don't implement it — reject loudly rather
    than silently training across example boundaries."""
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"segment-packed batches are not supported for family="
            f"{cfg.family!r}: the SSM state scan carries context across "
            f"segment boundaries. Use the unpacked pipeline (pack=False) "
            f"for this architecture.")
    if cfg.family == "vlm":
        raise ValueError(
            "segment-packed batches are not supported for family='vlm' "
            "(the patch prefix is shared by every row); use pack=False")
    if cfg.use_mla:
        raise ValueError(
            "segment-packed batches are not implemented for MLA attention; "
            "use pack=False or a GQA/MHA architecture")
    if cfg.mtp_depth:
        raise ValueError(
            "segment-packed batches are not implemented for mtp_depth > 0: "
            "the MTP head's attention is not segment-masked and its "
            "shift-2 loss would cross example boundaries; use pack=False")


def apply_train(params: dict, cfg: ModelConfig, batch: dict, *, mesh=None,
                batch_axes=("data",), masks: dict | None = None):
    """-> (logits aligned to batch['tokens'], aux_loss, extra).

    Packed SFT batches (data/pipeline) additionally carry
    ``segment_ids`` [B, S] (0 = pad) and ``positions`` [B, S]
    (per-segment reset): attention becomes block-diagonal over segments and
    RoPE sees each example at its unpacked positions, so the packed forward
    equals running every segment as its own row."""
    tokens = batch["tokens"]
    masks = masks or {}
    segment_ids = batch.get("segment_ids")
    positions = batch.get("positions")
    if segment_ids is not None:
        _check_packed_support(cfg)
    x = _embed_tokens(params, cfg, tokens)
    prefix_len = 0
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]

    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "vlm"):
        fn = partial(_apply_attn_block, cfg, prefix_len, positions,
                     segment_ids)
        x, a = scan_stack(cfg, fn, x, params["layers"], masks.get("layers"))
        aux += a
    elif cfg.family == "moe":
        if cfg.first_k_dense:
            fn = partial(_apply_attn_block, cfg, 0, positions, segment_ids)
            x, a = scan_stack(cfg, fn, x, params["dense_layers"],
                              masks.get("dense_layers"))
            aux += a
        fn = partial(_apply_moe_block, cfg, mesh, batch_axes, positions,
                     segment_ids)
        x, a = scan_stack(cfg, fn, x, params["moe_layers"], masks.get("moe_layers"))
        aux += a
    elif cfg.family == "ssm":
        fn = partial(_apply_ssm_block, cfg)
        x, a = scan_stack(cfg, fn, x, params["layers"], masks.get("layers"))
        aux += a
    elif cfg.family == "hybrid":
        x, a = _hybrid_train(params, cfg, x, masks)
        aux += a

    h_pre = x
    x = norms.apply(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    if cfg.family == "vlm":
        logits = logits[:, prefix_len:]

    extra = {}
    if cfg.mtp_depth:
        extra["mtp_logits"] = _mtp_logits(params, cfg, h_pre, tokens, mesh,
                                          batch_axes)
    return logits, aux, extra


def _apply_attn_block(cfg, prefix_len, positions, segment_ids, p_l, x):
    return blocks.attn_block_apply(p_l, cfg, x, prefix_len=prefix_len,
                                   positions=positions,
                                   segment_ids=segment_ids)


def _apply_moe_block(cfg, mesh, batch_axes, positions, segment_ids, p_l, x):
    return blocks.moe_block_apply(p_l, cfg, x, mesh=mesh,
                                  batch_axes=batch_axes, positions=positions,
                                  segment_ids=segment_ids)


def _apply_ssm_block(cfg, p_l, x):
    return blocks.ssm_block_apply(p_l, cfg, x)


def _hybrid_train(params, cfg: ModelConfig, x, masks):
    """ssm layers with the shared attn block applied every period layers.
    Shared-block weight sharing = same params closed over at every site."""
    p, nsite, rem = _hybrid_split(cfg)
    stacked = params["layers"]
    lmask = masks.get("layers")
    grouped = jax.tree.map(
        lambda t: t[: nsite * p].reshape(nsite, p, *t.shape[1:]), stacked)
    gmask = (None if lmask is None
             else lmask[: nsite * p].reshape(nsite, p))
    shared = params["shared_attn"]
    smask = masks.get("shared_attn")

    def outer(carry, xs):
        x, aux = carry
        grp, gm = xs if gmask is not None else (xs, None)
        x, a = scan_stack(cfg, partial(_apply_ssm_block, cfg), x, grp, gm)
        aux += a
        shared_fn = lambda p_l, xx: blocks.attn_block_apply(p_l, cfg, xx)  # noqa: E731
        if cfg.gate_weight_grads and smask is not None:
            x, a2 = _gated(shared_fn, shared, x, smask)
        else:
            x, a2 = shared_fn(shared, x)
        return (x, aux + a2), None

    xs = (grouped, gmask) if gmask is not None else grouped
    (x, aux), _ = jax.lax.scan(_remat(outer, cfg),
                               (x, jnp.zeros((), jnp.float32)), xs)
    if rem:
        tail = jax.tree.map(lambda t: t[nsite * p:], stacked)
        tmask = None if lmask is None else lmask[nsite * p:]
        x, a = scan_stack(cfg, partial(_apply_ssm_block, cfg), x, tail, tmask)
        aux += a
    return x, aux


def _mtp_logits(params, cfg: ModelConfig, h_pre, tokens, mesh, batch_axes):
    """Deepseek-style depth-1 multi-token prediction head: predict t+2."""
    m = params["mtp"]
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    e = _embed_tokens(params, cfg, nxt)
    h = jnp.concatenate([norms.apply(m["norm_h"], h_pre, cfg.norm_eps),
                         norms.apply(m["norm_e"], e, cfg.norm_eps)], axis=-1)
    h = h @ m["proj"]
    if cfg.family == "moe":
        h, _ = blocks.moe_block_apply(m["block"], cfg, h, mesh=mesh,
                                      batch_axes=batch_axes)
    else:
        h, _ = blocks.attn_block_apply(m["block"], cfg, h)
    h = norms.apply(params["final_norm"], h, cfg.norm_eps)
    return _logits(params, cfg, h)


# --------------------------------------------------------------- caches


def _attn_layer_count(cfg: ModelConfig) -> int:
    if cfg.family in ("dense", "vlm"):
        return cfg.num_layers
    if cfg.family == "moe":
        return cfg.num_layers
    return 0


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    """Slot-based cache: every leaf has a batch/slot axis (axis 1 for the
    stacked per-layer leaves, axis 0 for ``pos``). ``pos`` is a PER-SLOT
    [B] i32 vector — the number of tokens written per slot — so slots at
    mixed decode progress can coexist (continuous batching)."""
    dt = jnp.dtype(cfg.dtype)
    cache: dict = {"pos": jnp.zeros((batch_size,), jnp.int32)}
    b = batch_size
    if cfg.family in ("dense", "vlm", "moe"):
        n = _attn_layer_count(cfg)
        if cfg.use_mla:
            cache["ckv"] = jnp.zeros((n, b, max_len, cfg.kv_lora_rank), dt)
            cache["kpe"] = jnp.zeros((n, b, max_len, cfg.qk_rope_head_dim), dt)
        else:
            kvh, dh = cfg.num_kv_heads, cfg.head_dim
            cache["k"] = jnp.zeros((n, b, max_len, kvh, dh), dt)
            cache["v"] = jnp.zeros((n, b, max_len, kvh, dh), dt)
    if cfg.family in ("ssm", "hybrid"):
        from repro.models.layers import ssm as ssm_mod
        d_inner, nheads, gn = ssm_mod.dims(cfg)
        lc = cfg.num_layers
        km1 = cfg.ssm_conv - 1
        cache["conv"] = {"x": jnp.zeros((lc, b, km1, d_inner), dt),
                         "b": jnp.zeros((lc, b, km1, gn), dt),
                         "c": jnp.zeros((lc, b, km1, gn), dt)}
        cache["state"] = jnp.zeros((lc, b, nheads, cfg.ssm_head_dim,
                                    cfg.ssm_state), jnp.float32)
    if cfg.family == "hybrid":
        p, nsite, rem = _hybrid_split(cfg)
        kvh, dh = cfg.num_kv_heads, cfg.head_dim
        cache["ak"] = jnp.zeros((nsite, b, max_len, kvh, dh), dt)
        cache["av"] = jnp.zeros((nsite, b, max_len, kvh, dh), dt)
    return cache


# --------------------------------------------------------------- prefill


def prefill(params: dict, cfg: ModelConfig, batch: dict, max_len: int, *,
            mesh=None, batch_axes=("data",), lengths=None):
    """``lengths`` (optional [B] i32): true prompt lengths when ``tokens`` is
    right-padded to a shared bucket (bucketed prefill). Per-row logits are
    gathered at ``lengths - 1`` and ``cache["pos"] = lengths``; K/V
    projections are pointwise in sequence and attention is causal, so rows
    are exact regardless of pad tokens to their right. Only length-indexed
    KV families support this (dense/moe, incl. MLA) — SSM state scans would
    absorb the pad tokens, so ssm/hybrid/vlm reject ``lengths``."""
    if lengths is not None and cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"bucket-padded prefill (lengths=) is not supported for family="
            f"{cfg.family!r}: its recurrent/prefix state would absorb the "
            f"pad tokens. Serve this family with exact-length prefill "
            f"(ServeEngine falls back automatically).")
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_tokens(params, cfg, tokens)
    prefix_len = 0
    if cfg.family == "vlm":
        patches = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
    cache = init_cache(cfg, b, max_len)
    seq = x.shape[1]

    if cfg.family in ("dense", "vlm"):
        def body(x, p_l):
            x, kv = blocks.attn_block_prefill(p_l, cfg, x, cache_len=max_len,
                                              prefix_len=prefix_len)
            return x, kv
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        cache["k"], cache["v"] = ks, vs
    elif cfg.family == "moe":
        kss, vss = [], []
        if cfg.first_k_dense:
            def body_d(x, p_l):
                return blocks.attn_block_prefill(p_l, cfg, x, cache_len=max_len)
            x, kv_d = jax.lax.scan(body_d, x, params["dense_layers"])
            kss.append(kv_d[0]); vss.append(kv_d[1])

        def body_m(x, p_l):
            return blocks.moe_block_prefill(p_l, cfg, x, cache_len=max_len,
                                            mesh=mesh, batch_axes=batch_axes)
        x, kv_m = jax.lax.scan(body_m, x, params["moe_layers"])
        kss.append(kv_m[0]); vss.append(kv_m[1])
        if cfg.use_mla:
            cache["ckv"] = jnp.concatenate(kss, axis=0)
            cache["kpe"] = jnp.concatenate(vss, axis=0)
        else:
            cache["k"] = jnp.concatenate(kss, axis=0)
            cache["v"] = jnp.concatenate(vss, axis=0)
    elif cfg.family == "ssm":
        def body_s(x, p_l):
            x, st = blocks.ssm_block_prefill(p_l, cfg, x)
            return x, st
        x, (convs, states) = jax.lax.scan(body_s, x, params["layers"])
        cache["conv"], cache["state"] = convs, states
    elif cfg.family == "hybrid":
        x, cache = _hybrid_prefill(params, cfg, x, cache, max_len)

    x = norms.apply(params["final_norm"], x, cfg.norm_eps)
    if lengths is None:
        logits = _logits(params, cfg, x[:, -1:, :])[:, 0]
        cache["pos"] = jnp.full((b,), seq, jnp.int32)
    else:
        lv = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
        xl = x[jnp.arange(b), jnp.clip(lv - 1, 0, seq - 1)][:, None]
        logits = _logits(params, cfg, xl)[:, 0]
        cache["pos"] = lv
    return logits, cache


def prefill_chunk(params: dict, cfg: ModelConfig, tokens, cache: dict, start,
                  lengths, last_logits, *, mesh=None, batch_axes=("data",)):
    """One chunk of an incremental prefill over a scratch dense cache.

    ``tokens``: [B, C] chunk at positions [start, start+C) (``start`` is a
    traced i32 scalar — one compile per chunk SHAPE, not per offset);
    ``cache``: {"k", "v"} scratch [L, B, S_bucket, KVH, Dh] carrying earlier
    chunks' K/V; ``lengths``: [B] true prompt lengths; ``last_logits``:
    [B, V] carried last-position logits, updated for rows whose final prompt
    token falls inside this chunk. Returns (last_logits', cache'). Chunked
    prefill needs per-chunk KV append + offset attention, which the MLA and
    recurrent families don't implement — dense/moe GQA only."""
    if cfg.family not in ("dense", "moe") or cfg.use_mla:
        raise ValueError(
            f"chunked prefill is not supported for family={cfg.family!r}"
            f"{' with MLA' if cfg.use_mla else ''}: it needs per-chunk KV "
            f"append with offset attention. Use single-shot prefill "
            f"(prefill_chunk=0) for this architecture.")
    b, c = tokens.shape
    start = jnp.asarray(start, jnp.int32)
    lengths = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))
    x = _embed_tokens(params, cfg, tokens)

    if cfg.family == "dense":
        def body(x, xs):
            p_l, k_l, v_l = xs
            x, k_l, v_l = blocks.attn_block_prefill_chunk(p_l, cfg, x, k_l,
                                                          v_l, start)
            return x, (k_l, v_l)
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                             cache["v"]))
        cache = {**cache, "k": ks, "v": vs}
    else:
        kss, vss = [], []
        off = 0
        if cfg.first_k_dense:
            def body_d(x, xs):
                p_l, k_l, v_l = xs
                x, k_l, v_l = blocks.attn_block_prefill_chunk(p_l, cfg, x,
                                                              k_l, v_l, start)
                return x, (k_l, v_l)
            nd = cfg.first_k_dense
            x, (k_d, v_d) = jax.lax.scan(
                body_d, x, (params["dense_layers"], cache["k"][:nd],
                            cache["v"][:nd]))
            kss.append(k_d); vss.append(v_d); off = nd

        def body_m(x, xs):
            p_l, k_l, v_l = xs
            x, k_l, v_l = blocks.moe_block_prefill_chunk(
                p_l, cfg, x, k_l, v_l, start, mesh=mesh, batch_axes=batch_axes)
            return x, (k_l, v_l)
        x, (k_m, v_m) = jax.lax.scan(
            body_m, x, (params["moe_layers"], cache["k"][off:],
                        cache["v"][off:]))
        kss.append(k_m); vss.append(v_m)
        cache = {**cache, "k": jnp.concatenate(kss, axis=0),
                 "v": jnp.concatenate(vss, axis=0)}

    x = norms.apply(params["final_norm"], x, cfg.norm_eps)
    # rows whose last prompt token lives in this chunk pick up their logits
    idx = jnp.clip(lengths - 1 - start, 0, c - 1)
    sel = _logits(params, cfg, x[jnp.arange(b), idx][:, None])[:, 0]
    hit = (lengths - 1 >= start) & (lengths - 1 < start + c)
    last_logits = jnp.where(hit[:, None], sel.astype(last_logits.dtype),
                            last_logits)
    return last_logits, cache


def init_paged_cache(cfg: ModelConfig, batch_size: int, max_len: int,
                     page_size: int, num_pages: int) -> dict:
    """Paged serve cache: K/V live in shared pools [L, num_pages, page_size,
    KVH, Dh] and each slot maps virtual positions through ``pages``
    [B, max_pages] (i32; the ``num_pages`` sentinel marks unallocated
    entries — see serve/pages.py). ``pos`` semantics are identical to the
    dense cache. SSM has no length-indexed KV, so paging is a no-op and the
    regular cache is returned; families whose decode state the paged layout
    cannot express raise with the supported alternatives."""
    if cfg.family == "ssm":
        return init_cache(cfg, batch_size, max_len)
    if cfg.family not in ("dense", "moe") or cfg.use_mla:
        raise ValueError(
            f"paged KV cache is not supported for family={cfg.family!r}"
            f"{' with MLA' if cfg.use_mla else ''}: only plain GQA/MHA "
            f"dense and moe stacks (and ssm, where it is a no-op) have a "
            f"paged decode path. Use kv_layout='dense' for this "
            f"architecture.")
    dt = jnp.dtype(cfg.dtype)
    maxp = -(-max_len // page_size)
    n = _attn_layer_count(cfg)
    kvh, dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "pos": jnp.zeros((batch_size,), jnp.int32),
        "pages": jnp.full((batch_size, maxp), num_pages, jnp.int32),
        "k": jnp.zeros((n, num_pages, page_size, kvh, dh), dt),
        "v": jnp.zeros((n, num_pages, page_size, kvh, dh), dt),
    }


def insert_slots_paged(cache: dict, src: dict, slots, lengths,
                       starts=None) -> dict:
    """Scatter a dense prefill cache (``src``: k/v [L, n, S, KVH, Dh]) into
    the page pools through the device-mirrored table ``cache["pages"]``.
    ``slots``: [n] i32 slot per row (entries == num_slots are admission
    padding — their writes drop); ``lengths``: [n] true prompt lengths —
    positions >= length route to the OOB sentinel and drop, so bucket-pad
    garbage never reaches the pool. ``starts`` (optional, [n] or scalar
    i32): first position to write per row — positions below it also drop,
    which is the prefix-cache aliased-page write rule: table entries below
    ``start`` map to pages shared read-only with other slots (or the radix
    tree) and must never be written through; the suffix scatter begins at
    the slot's first private (or copied-on-write) page."""
    k_pool, v_pool = cache["k"], cache["v"]
    num_pages, ps = k_pool.shape[1], k_pool.shape[2]
    num_slots, maxp = cache["pages"].shape
    slots = jnp.asarray(slots, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    s_max = src["k"].shape[2]
    valid_slot = slots < num_slots
    tbl = jnp.where(valid_slot[:, None],
                    cache["pages"][jnp.minimum(slots, num_slots - 1)],
                    num_pages)                                   # [n, maxp]
    t = jnp.arange(s_max)
    page = tbl[:, jnp.minimum(t // ps, maxp - 1)]                # [n, s_max]
    ok = (t[None, :] < lengths[:, None]) & (t[None, :] // ps < maxp)
    if starts is not None:
        starts = jnp.broadcast_to(jnp.asarray(starts, jnp.int32),
                                  lengths.shape)
        ok = ok & (t[None, :] >= starts[:, None])
    page = jnp.where(ok, page, num_pages)
    off = jnp.broadcast_to(t % ps, page.shape)
    k_pool = k_pool.at[:, page, off].set(src["k"].astype(k_pool.dtype))
    v_pool = v_pool.at[:, page, off].set(src["v"].astype(v_pool.dtype))
    pos = cache["pos"].at[slots].set(lengths)
    return {**cache, "k": k_pool, "v": v_pool, "pos": pos}


def insert_slots(cache: dict, src: dict, slots) -> dict:
    """Write the rows of ``src`` (a cache of batch size n, e.g. from a fresh
    prefill) into ``cache`` at slot indices ``slots`` ([n] i32). Every cache
    leaf carries the slot axis at position 1 (stacked [L, B, ...]) except
    the per-slot scalars ``pos``/``src_len`` ([B]). Out-of-range slot
    indices are dropped (JAX scatter semantics), which admission code uses
    to pad groups to a fixed batch."""
    slots = jnp.asarray(slots, jnp.int32)
    out = {}
    for key, val in cache.items():
        if key in ("pos", "src_len"):
            out[key] = val.at[slots].set(src[key].astype(val.dtype))
        else:
            out[key] = jax.tree.map(
                lambda c, s: c.at[:, slots].set(s.astype(c.dtype)),
                val, src[key])
    return out


def _hybrid_prefill(params, cfg, x, cache, max_len):
    p, nsite, rem = _hybrid_split(cfg)
    stacked = params["layers"]
    grouped = jax.tree.map(
        lambda t: t[: nsite * p].reshape(nsite, p, *t.shape[1:]), stacked)
    shared = params["shared_attn"]

    def outer(x, grp):
        def inner(x, p_l):
            x, st = blocks.ssm_block_prefill(p_l, cfg, x)
            return x, st
        x, states = jax.lax.scan(inner, x, grp)
        x, akv = blocks.attn_block_prefill(shared, cfg, x, cache_len=max_len)
        return x, (states, akv)

    x, (sts, akvs) = jax.lax.scan(outer, x, grouped)
    convs, states = sts
    # [nsite, p, B, ...] -> [nsite*p, B, ...] (convs is a {x,b,c} dict)
    flat2 = lambda t: t.reshape(nsite * p, *t.shape[2:])  # noqa: E731
    convs = jax.tree.map(flat2, convs)
    states = flat2(states)
    cache["ak"], cache["av"] = akvs
    if rem:
        tail = jax.tree.map(lambda t: t[nsite * p:], stacked)

        def inner_t(x, p_l):
            x, st = blocks.ssm_block_prefill(p_l, cfg, x)
            return x, st
        x, (convs_t, states_t) = jax.lax.scan(inner_t, x, tail)
        convs = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                             convs, convs_t)
        states = jnp.concatenate([states, states_t], axis=0)
    cache["conv"], cache["state"] = convs, states
    return x, cache


# --------------------------------------------------------------- decode


def decode_step(params: dict, cfg: ModelConfig, tokens, cache: dict, *,
                mesh=None, batch_axes=("data",)):
    """tokens [B, 1] -> (logits [B, V], new cache). ``cache["pos"]`` may be
    a scalar (legacy caches) or a per-slot [B] vector; each row attends over
    and writes at its own position."""
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32),
                           (tokens.shape[0],))
    x = _embed_tokens(params, cfg, tokens)

    paged = "pages" in cache
    if cfg.family in ("dense", "vlm") and paged:
        pages = cache["pages"]

        def body_p(x, xs):
            p_l, kp, vp = xs
            x, kp, vp = blocks.attn_block_decode_paged(p_l, cfg, x, kp, vp,
                                                       pages, pos)
            return x, (kp, vp)
        x, (kp, vp) = jax.lax.scan(body_p, x, (params["layers"], cache["k"],
                                               cache["v"]))
        cache = {**cache, "k": kp, "v": vp}
    elif cfg.family == "moe" and paged:
        pages = cache["pages"]
        c0s, c1s = [], []
        off = 0
        if cfg.first_k_dense:
            def body_dp(x, xs):
                p_l, kp, vp = xs
                x, kp, vp = blocks.attn_block_decode_paged(p_l, cfg, x, kp,
                                                           vp, pages, pos)
                return x, (kp, vp)
            nd = cfg.first_k_dense
            x, (kp, vp) = jax.lax.scan(
                body_dp, x, (params["dense_layers"], cache["k"][:nd],
                             cache["v"][:nd]))
            c0s.append(kp); c1s.append(vp); off = nd

        def body_mp(x, xs):
            p_l, kp, vp = xs
            x, kp, vp = blocks.moe_block_decode_paged(p_l, cfg, x, kp, vp,
                                                      pages, pos)
            return x, (kp, vp)
        x, (kp, vp) = jax.lax.scan(
            body_mp, x, (params["moe_layers"], cache["k"][off:],
                         cache["v"][off:]))
        c0s.append(kp); c1s.append(vp)
        cache = {**cache, "k": jnp.concatenate(c0s, axis=0),
                 "v": jnp.concatenate(c1s, axis=0)}
    elif cfg.family in ("dense", "vlm"):
        def body(x, xs):
            p_l, c0, c1 = xs
            x, c0, c1 = blocks.attn_block_decode(p_l, cfg, x, c0, c1, pos)
            return x, (c0, c1)
        keys = ("ckv", "kpe") if cfg.use_mla else ("k", "v")
        x, (c0, c1) = jax.lax.scan(body, x, (params["layers"],
                                             cache[keys[0]], cache[keys[1]]))
        cache = {**cache, keys[0]: c0, keys[1]: c1}
    elif cfg.family == "moe":
        keys = ("ckv", "kpe") if cfg.use_mla else ("k", "v")
        c0s, c1s = [], []
        off = 0
        if cfg.first_k_dense:
            def body_d(x, xs):
                p_l, c0, c1 = xs
                x, c0, c1 = blocks.attn_block_decode(p_l, cfg, x, c0, c1, pos)
                return x, (c0, c1)
            nd = cfg.first_k_dense
            x, (c0, c1) = jax.lax.scan(
                body_d, x, (params["dense_layers"],
                            cache[keys[0]][:nd], cache[keys[1]][:nd]))
            c0s.append(c0); c1s.append(c1); off = nd

        def body_m(x, xs):
            p_l, c0, c1 = xs
            x, c0, c1 = blocks.moe_block_decode(p_l, cfg, x, c0, c1, pos,
                                                mesh=mesh, batch_axes=batch_axes)
            return x, (c0, c1)
        x, (c0, c1) = jax.lax.scan(
            body_m, x, (params["moe_layers"],
                        cache[keys[0]][off:], cache[keys[1]][off:]))
        c0s.append(c0); c1s.append(c1)
        cache = {**cache, keys[0]: jnp.concatenate(c0s, axis=0),
                 keys[1]: jnp.concatenate(c1s, axis=0)}
    elif cfg.family == "ssm":
        def body_s(x, xs):
            p_l, cv, st = xs
            x, cv, st = blocks.ssm_block_decode(p_l, cfg, x, cv, st)
            return x, (cv, st)
        x, (cv, st) = jax.lax.scan(body_s, x, (params["layers"],
                                               cache["conv"], cache["state"]))
        cache = {**cache, "conv": cv, "state": st}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_decode(params, cfg, x, cache, pos)

    x = norms.apply(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)[:, 0]
    cache = {**cache, "pos": pos + 1}
    return logits, cache


def _hybrid_decode(params, cfg, x, cache, pos):
    p, nsite, rem = _hybrid_split(cfg)
    stacked = params["layers"]
    grouped = jax.tree.map(
        lambda t: t[: nsite * p].reshape(nsite, p, *t.shape[1:]), stacked)
    shared = params["shared_attn"]
    grp2 = lambda t: t[: nsite * p].reshape(nsite, p, *t.shape[1:])  # noqa: E731
    cv_g = jax.tree.map(grp2, cache["conv"])
    st_g = grp2(cache["state"])

    def outer(x, xs):
        grp, cv, st, ak, av = xs

        def inner(x, ys):
            p_l, cvl, stl = ys
            x, cvl, stl = blocks.ssm_block_decode(p_l, cfg, x, cvl, stl)
            return x, (cvl, stl)
        x, (cv, st) = jax.lax.scan(inner, x, (grp, cv, st))
        x, ak, av = blocks.attn_block_decode(shared, cfg, x, ak, av, pos)
        return x, (cv, st, ak, av)

    x, (cv, st, ak, av) = jax.lax.scan(
        outer, x, (grouped, cv_g, st_g, cache["ak"], cache["av"]))
    flat2 = lambda t: t.reshape(nsite * p, *t.shape[2:])  # noqa: E731
    conv = jax.tree.map(flat2, cv)
    state = flat2(st)
    if rem:
        tail = jax.tree.map(lambda t: t[nsite * p:], stacked)

        def inner_t(x, ys):
            p_l, cvl, stl = ys
            x, cvl, stl = blocks.ssm_block_decode(p_l, cfg, x, cvl, stl)
            return x, (cvl, stl)
        x, (cv_t, st_t) = jax.lax.scan(
            inner_t, x, (tail, jax.tree.map(lambda t: t[nsite * p:], cache["conv"]),
                         cache["state"][nsite * p:]))
        conv = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                            conv, cv_t)
        state = jnp.concatenate([state, st_t], axis=0)
    cache = {**cache, "conv": conv, "state": state, "ak": ak, "av": av}
    return x, cache
