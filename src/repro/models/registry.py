"""Family -> model module dispatch. All modules expose the same API
(init, apply_train, init_cache, prefill, decode_step)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import encdec, lm


def get(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else lm
