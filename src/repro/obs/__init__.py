"""Unified telemetry: process-global metrics registry + gated span tracing.

Two layers with different cost contracts:

* **Metrics registry** (``obs.metrics``) — ALWAYS ON. Counters, gauges, and
  bounded histograms are host-side Python with sub-microsecond record cost
  (the same order as the ad-hoc stat dicts they replaced). Subsystems
  register instruments under a subsystem label and everything exports as
  one JSON document via ``obs.snapshot()``.

* **Span tracing + selection telemetry** — OFF by default. ``obs.span``
  returns a shared no-op context manager until ``obs.enable()`` installs a
  ``Tracer``; instrumentation sites that would force a host sync (reading
  a device mask, per-request timestamps into trace tracks) guard on
  ``obs.enabled()``. Disabled mode therefore adds **no host syncs and no
  measurable step-time cost** — step trajectories are bit-identical with
  obs on or off (pinned in tests), and the ``obs_overhead`` bench row
  regression-gates the disabled-mode cost at 3%.

Typical wiring (see train/trainer.py, core/swap.py, serve/engine.py):

    hist = obs.metrics.histogram("step_time_us", subsystem="train")
    with obs.timed(hist, "phase_a"):      # histogram always, span if on
        ...
    with obs.span("decode_chunk"):         # no-op when disabled
        ...
    obs.metrics.register("stats", engine_stats_callable, subsystem="serve")

Launchers expose ``--trace PATH`` (Chrome trace-event JSON, loadable in
Perfetto / chrome://tracing) and ``--metrics-json PATH``
(``obs.snapshot()``, rendered by ``launch/inspect.py``).
"""
from __future__ import annotations

import time

from repro.obs.registry import (Counter, Gauge, Histogram,  # noqa: F401
                                MetricsRegistry)
from repro.obs.selection import SelectionTrace  # noqa: F401
from repro.obs.trace import (NOOP_SPAN, Tracer,  # noqa: F401
                             validate_trace, validate_trace_file)

# the process-global registry: always on, cheap, snapshot-exportable
metrics = MetricsRegistry()

_tracer: Tracer | None = None
_selection: SelectionTrace | None = None


def enabled() -> bool:
    """True when span tracing (and selection telemetry) is active."""
    return _tracer is not None


def enable(*, jax_profiler: bool = False, selection: bool = True,
           max_events: int = 1_000_000) -> Tracer:
    """Install a fresh ``Tracer`` (and, by default, a fresh
    ``SelectionTrace``). Idempotent in spirit: calling again replaces the
    active tracer so each run exports a self-contained trace."""
    global _tracer, _selection
    _tracer = Tracer(jax_profiler=jax_profiler, max_events=max_events)
    _selection = SelectionTrace() if selection else None
    return _tracer


def disable() -> None:
    global _tracer, _selection
    _tracer = None
    _selection = None


def tracer() -> Tracer | None:
    return _tracer


def selection_trace() -> SelectionTrace | None:
    return _selection


def span(name: str, args: dict | None = None):
    """Duration span context manager; the disabled path returns a shared
    no-op singleton (one global read + one ``is None`` check)."""
    tr = _tracer
    return NOOP_SPAN if tr is None else tr.span(name, args)


def instant(name: str, args: dict | None = None) -> None:
    tr = _tracer
    if tr is not None:
        tr.instant(name, args)


class _Timed:
    """Times its body with ``perf_counter`` and records the elapsed
    microseconds into ``hist`` — always; additionally emits a trace span
    when tracing is on. The one timing source of truth for phase timings
    (SwapStats et al. are views over these histograms)."""

    __slots__ = ("_hist", "_name", "_args", "_span", "_t0")

    def __init__(self, hist: Histogram, name: str, args: dict | None = None):
        self._hist = hist
        self._name = name
        self._args = args
        self._span = None

    def __enter__(self):
        tr = _tracer
        if tr is not None:
            self._span = tr.span(self._name, self._args)
            self._span.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt_us = (time.perf_counter() - self._t0) * 1e6
        self._hist.record(dt_us)
        if self._span is not None:
            self._span.__exit__(*exc)
            self._span = None
        return False


def timed(hist: Histogram, name: str, args: dict | None = None) -> _Timed:
    return _Timed(hist, name, args)


def snapshot() -> dict:
    """One JSON-able document: every registered metric by subsystem, plus
    the selection telemetry under ``"selection"`` when enabled."""
    doc = metrics.snapshot()
    if _selection is not None and len(_selection):
        doc["selection"] = _selection.snapshot()
    return doc


def export_trace(path: str) -> None:
    if _tracer is None:
        raise RuntimeError("obs.export_trace: tracing is not enabled "
                           "(call obs.enable() before the run)")
    _tracer.export(path)
