"""Process-global metrics registry: counters, gauges, bounded histograms.

The registry is ALWAYS ON — its instruments are plain host-side Python
(lock-guarded ints, floats, and a fixed-size bucket array), so recording
into them costs what the pre-existing ad-hoc stat dicts cost (an attribute
access and an add under a lock, sub-microsecond). Nothing here ever touches
a device value: anything that would force a host sync (reading a jax array,
``block_until_ready``) belongs behind ``obs.enabled()`` at the call site,
never inside an instrument. That split is the disabled-mode guarantee:
tracing off means zero *added* host syncs and no measurable step-time cost.

Instruments are keyed ``(subsystem, name)``. Get-or-create accessors
(``registry.counter/gauge/histogram``) return the shared instrument;
``register`` binds an externally-owned instrument (or a zero-arg callable
polled at snapshot time) under a key, last-writer-wins — the idiom for
per-instance stats like a trainer's ``SwapStats`` histograms, where "the
current trainer owns the name" is the useful semantic. ``snapshot()``
renders everything as one nested JSON-able dict
``{subsystem: {name: value}}``.

``Histogram`` is log-bucketed and bounded: geometric bucket boundaries with
growth ``2**(1/8)`` per bucket, so any recorded value lands within ~4.4%
relative error of its bucket's geometric-midpoint representative, and the
bucket array is a fixed-size list regardless of how many values stream in.
Quantiles are nearest-rank (``floor(q * (n - 1))``, numpy's ``lower``
method) over the bucket counts.
"""
from __future__ import annotations

import math
import threading


class Counter:
    """Monotonic counter; ``inc`` is thread-safe (background swap/streamout
    threads record into the same instrument as the main loop)."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self):
        return self._value


class Gauge:
    """Last-write-wins scalar (queue depth, pool occupancy)."""

    __slots__ = ("_value",)

    def __init__(self):
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self):
        return self._value


# 8 buckets per octave: bucket width 2**(1/8) ~ 1.0905x, representative at
# the geometric midpoint -> worst-case relative error 2**(1/16)-1 ~ 4.4%
_BPO = 8
# bucket index range: 2**-16 .. 2**48 covers sub-ns .. ~3 days in us
_IDX_LO = -16 * _BPO
_IDX_HI = 48 * _BPO
_NBUCKETS = _IDX_HI - _IDX_LO + 1


class Histogram:
    """Bounded log-bucketed histogram with nearest-rank quantiles.

    Fixed memory: one int per bucket (``num_buckets`` total) plus running
    count/total/min/max — independent of how many values are recorded.
    Non-positive values land in a dedicated zero bucket whose
    representative is 0.0. ``record`` is thread-safe.
    """

    __slots__ = ("_counts", "_zero", "count", "total", "min", "max", "_lock")

    num_buckets = _NBUCKETS

    def __init__(self):
        self._counts = [0] * _NBUCKETS
        self._zero = 0  # values <= 0
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = threading.Lock()

    @staticmethod
    def _index(v: float) -> int:
        i = math.floor(math.log2(v) * _BPO)
        return min(max(int(i), _IDX_LO), _IDX_HI) - _IDX_LO

    @staticmethod
    def _representative(bucket: int) -> float:
        return 2.0 ** ((bucket + _IDX_LO + 0.5) / _BPO)

    def record(self, v: float) -> None:
        v = float(v)
        with self._lock:
            if v > 0.0:
                self._counts[self._index(v)] += 1
            else:
                self._zero += 1
            self.count += 1
            self.total += v
            self.min = v if v < self.min else self.min
            self.max = v if v > self.max else self.max

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile (rank ``floor(q * (count - 1))``): the
        bucket representative is within ~4.4% of the true order statistic."""
        with self._lock:
            if not self.count:
                return 0.0
            rank = math.floor(min(max(q, 0.0), 1.0) * (self.count - 1))
            if rank < self._zero:
                return 0.0
            seen = self._zero
            for b, c in enumerate(self._counts):
                seen += c
                if seen > rank:
                    return self._representative(b)
            return self.max  # unreachable unless counts raced; be safe

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "sum": self.total, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def snapshot(self):
        return self.summary()


class MetricsRegistry:
    """Named instruments grouped by subsystem, snapshot-exportable as one
    nested dict. See module docstring for the ownership idioms."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, subsystem: str, cls):
        key = (subsystem, name)
        with self._lock:
            m = self._metrics.get(key)
            if m is None or not isinstance(m, cls):
                m = self._metrics[key] = cls()
            return m

    def counter(self, name: str, subsystem: str = "") -> Counter:
        return self._get_or_create(name, subsystem, Counter)

    def gauge(self, name: str, subsystem: str = "") -> Gauge:
        return self._get_or_create(name, subsystem, Gauge)

    def histogram(self, name: str, subsystem: str = "") -> Histogram:
        return self._get_or_create(name, subsystem, Histogram)

    def register(self, name: str, metric, subsystem: str = "") -> None:
        """Bind an externally-owned instrument — or a zero-arg callable
        polled at snapshot time — under ``(subsystem, name)``. Last writer
        wins: re-registering (a new trainer, a new engine) replaces the
        previous owner's binding."""
        with self._lock:
            self._metrics[(subsystem, name)] = metric

    def unregister(self, name: str, subsystem: str = "") -> None:
        with self._lock:
            self._metrics.pop((subsystem, name), None)

    def snapshot(self) -> dict:
        """-> ``{subsystem: {name: value}}``, JSON-able. Counter -> int,
        gauge -> float, histogram -> summary dict, callable -> its return
        value (errors render as ``{"error": ...}`` rather than poisoning
        the whole snapshot)."""
        with self._lock:
            items = list(self._metrics.items())
        out: dict = {}
        for (subsystem, name), metric in items:
            try:
                value = (metric.snapshot() if hasattr(metric, "snapshot")
                         else metric() if callable(metric) else metric)
            except Exception as e:  # noqa: BLE001 — snapshot must not raise
                value = {"error": repr(e)}
            out.setdefault(subsystem or "default", {})[name] = value
        return out

    def reset(self) -> None:
        """Drop every instrument (tests)."""
        with self._lock:
            self._metrics.clear()
