"""Exploration->exploitation rendering over selection telemetry.

The paper's dynamical claim is that AdaGradSelect samples blocks broadly
early (Dirichlet prior + epsilon-greedy) and concentrates on the
high-signal blocks as cumulative gradient norms separate. ``summarize``
bins the [T, num_blocks] selection series into time windows and computes
per-window selection rates and the normalized entropy of the selection
distribution; ``render`` draws it as a unicode heatmap (blocks x time)
with the entropy trend and a one-line verdict. Works for any selection
policy (``adagradselect``, ``lisa``, ``grass``, ...) — the series is just
masks.
"""
from __future__ import annotations

import numpy as np

_SHADES = " ▁▂▃▄▅▆▇█"


def summarize(masks: np.ndarray, bins: int = 12) -> dict:
    """-> {bins, edges, rates [nb, bins], entropy [bins], mean_selected}.

    ``rates[b, w]`` is block b's selection rate inside time window w;
    ``entropy[w]`` is the entropy of the per-window selection distribution
    normalized to [0, 1] (1 = uniform exploration, -> 0 = concentrated
    exploitation). Windows are equal step spans (the last may be short).
    """
    masks = np.asarray(masks)
    if masks.ndim != 2 or not masks.size:
        raise ValueError(f"need a [T, num_blocks] mask series, got shape "
                         f"{masks.shape}")
    t, nb = masks.shape
    bins = max(1, min(int(bins), t))
    edges = np.linspace(0, t, bins + 1).astype(int)
    rates = np.zeros((nb, bins))
    entropy = np.zeros((bins,))
    for w in range(bins):
        window = masks[edges[w]:edges[w + 1]]
        rates[:, w] = window.mean(axis=0)
        total = rates[:, w].sum()
        if total > 0 and nb > 1:
            p = rates[:, w] / total
            nz = p[p > 0]
            entropy[w] = float(-(nz * np.log(nz)).sum() / np.log(nb))
    return {"bins": bins, "edges": edges.tolist(), "rates": rates,
            "entropy": entropy,
            "mean_selected": float(masks.sum(axis=1).mean())}


def _verdict(entropy: np.ndarray) -> str:
    third = max(1, len(entropy) // 3)
    early, late = float(np.mean(entropy[:third])), \
        float(np.mean(entropy[-third:]))
    if early - late > 0.05:
        trend = (f"exploration->exploitation: selection entropy "
                 f"{early:.2f} -> {late:.2f} (concentrating)")
    elif late - early > 0.05:
        trend = (f"selection entropy {early:.2f} -> {late:.2f} "
                 f"(broadening over time)")
    else:
        trend = (f"selection entropy steady at ~{late:.2f} "
                 f"(schedule/uniform policy)")
    return trend


def render(masks: np.ndarray, bins: int = 12, counts=None) -> str:
    """Heatmap string: one row per block, one column per time window,
    shaded by that window's selection rate; entropy row + verdict below."""
    s = summarize(masks, bins)
    rates, entropy = s["rates"], s["entropy"]
    nb, nbins = rates.shape
    lines = [f"selection heatmap — {masks.shape[0]} steps x {nb} blocks, "
             f"{nbins} windows (column = "
             f"~{masks.shape[0] / nbins:.0f} steps)"]
    counts = (np.asarray(masks).sum(axis=0) if counts is None
              else np.asarray(counts))
    for b in range(nb):
        cells = "".join(_SHADES[int(round(r * (len(_SHADES) - 1)))]
                        for r in np.clip(rates[b], 0, 1))
        lines.append(f"  block {b:3d} |{cells}| "
                     f"selected {int(counts[b])}x")
    ent = "".join(_SHADES[int(round(e * (len(_SHADES) - 1)))]
                  for e in np.clip(entropy, 0, 1))
    lines.append(f"  entropy   |{ent}|")
    lines.append(f"  {_verdict(entropy)}")
    return "\n".join(lines)


def render_selection_trace(trace, bins: int = 12) -> str:
    """Render a live ``SelectionTrace`` (or one rebuilt from a snapshot)."""
    if not len(trace):
        return "selection telemetry: no steps recorded (obs enabled?)"
    return render(trace.masks(), bins=bins, counts=trace.counts)


def render_metrics(snapshot: dict) -> str:
    """Flat text table of a ``registry.snapshot()`` document (histograms
    show count/mean/p50/p95/p99)."""
    lines = []
    for subsystem in sorted(k for k in snapshot if k != "selection"):
        lines.append(f"[{subsystem}]")
        for name, value in sorted(snapshot[subsystem].items()):
            if isinstance(value, dict) and "p50" in value:
                lines.append(
                    f"  {name:32s} n={value['count']:<8d} "
                    f"mean={value['mean']:.1f} p50={value['p50']:.1f} "
                    f"p95={value['p95']:.1f} p99={value['p99']:.1f}")
            else:
                lines.append(f"  {name:32s} {value}")
    return "\n".join(lines) if lines else "(no metrics recorded)"
