"""Selection telemetry: the paper-facing per-block time series.

``SelectionTrace`` accumulates, once per trainer step, the block-selection
mask and (optionally) the per-block gradient-norm snapshot the policy saw
at that selection boundary. The running ``counts`` vector is the sum of
recorded masks — by construction the same accumulation
``masked_adamw.update`` / ``banked_update`` perform on
``state["opt"]["counts"]`` (``counts + mask`` per step), so telemetry and
optimizer state must agree exactly at every boundary (pinned in
tests/test_obs.py). Masks are integer-valued, so the float accumulation is
exact far beyond any realistic step count.

Recording happens in the trainer and only when obs is enabled: pulling the
mask off the device is a host sync, which the disabled-mode contract
forbids adding.
"""
from __future__ import annotations

import threading

import numpy as np


class SelectionTrace:
    def __init__(self):
        self._lock = threading.Lock()
        self._steps: list[int] = []
        self._masks: list[np.ndarray] = []
        self._norms: list[np.ndarray | None] = []
        self._counts: np.ndarray | None = None

    def record(self, step: int, mask, block_norms=None) -> None:
        mask = np.asarray(mask).astype(bool)
        norms = (None if block_norms is None
                 else np.asarray(block_norms, np.float64).copy())
        with self._lock:
            if self._counts is None:
                self._counts = np.zeros(mask.shape, np.float64)
            self._counts += mask
            self._steps.append(int(step))
            self._masks.append(mask.copy())
            self._norms.append(norms)

    def __len__(self) -> int:
        return len(self._steps)

    @property
    def num_blocks(self) -> int:
        return 0 if self._counts is None else int(self._counts.shape[0])

    @property
    def counts(self) -> np.ndarray:
        """Cumulative per-block selection counts over the recorded steps —
        must equal ``state["opt"]["counts"]`` when recording started at
        step 0."""
        with self._lock:
            return (np.zeros((0,)) if self._counts is None
                    else self._counts.copy())

    def masks(self) -> np.ndarray:
        """[T, num_blocks] bool: the per-step selection series."""
        with self._lock:
            return (np.zeros((0, 0), bool) if not self._masks
                    else np.stack(self._masks))

    def norms(self) -> np.ndarray | None:
        """[T, num_blocks] gradient-norm snapshots, or None if never
        provided."""
        with self._lock:
            if not self._norms or all(n is None for n in self._norms):
                return None
            nb = self._counts.shape[0]
            return np.stack([n if n is not None else np.full(nb, np.nan)
                             for n in self._norms])

    def snapshot(self) -> dict:
        """JSON-able document (embedded in ``obs.snapshot()`` under the
        ``"selection"`` key and consumed by ``launch/inspect.py``)."""
        with self._lock:
            norms = [None if n is None else n.tolist() for n in self._norms]
            return {
                "steps": list(self._steps),
                "counts": ([] if self._counts is None
                           else self._counts.tolist()),
                "masks": [m.astype(int).tolist() for m in self._masks],
                "block_norms": norms,
            }

    @staticmethod
    def from_snapshot(doc: dict) -> "SelectionTrace":
        tr = SelectionTrace()
        for i, step in enumerate(doc.get("steps", [])):
            norms = (doc.get("block_norms") or [None] * (i + 1))[i]
            tr.record(step, np.asarray(doc["masks"][i], bool), norms)
        return tr
