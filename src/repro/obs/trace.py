"""Span tracing with Chrome trace-event JSON export (Perfetto-loadable).

``Tracer`` records duration spans (``B``/``E`` pairs), instant events
(``i``), and retroactive complete events (``X`` with explicit begin/end
timestamps — used for per-request latency spans whose endpoints were
stamped before the span could be emitted). Events are thread-aware: each OS
thread gets its own ``tid`` plus a ``thread_name`` metadata event, so the
swap planner's background dispatch shows up as its own track; logical
tracks (one lane per in-flight serve request) are synthetic tids allocated
by label via ``track=``.

Timestamps are ``time.perf_counter_ns()`` relative to tracer start,
exported in microseconds (the trace-event unit). Export writes
``{"traceEvents": [...]}``, the JSON object form both Perfetto and
``chrome://tracing`` load directly. The event buffer is bounded
(``max_events``); overflow drops new events and counts them, so a runaway
trace can't exhaust host memory.

An optional ``jax.profiler`` bridge makes every span also enter a
``jax.profiler.TraceAnnotation``, so spans frame XLA activity when the
tracer runs inside ``jax.profiler.trace(...)``.

``validate_trace`` is the structural checker the tests and CI use in place
of opening the file by hand: per-tid matched/properly-nested B/E pairs with
non-decreasing timestamps, non-negative X durations, known phase types.
"""
from __future__ import annotations

import json
import threading
import time


class _Span:
    """Context manager emitting one B/E pair (and optionally framing a
    ``jax.profiler.TraceAnnotation``)."""

    __slots__ = ("_tracer", "_name", "_args", "_ann")

    def __init__(self, tracer, name, args=None):
        self._tracer = tracer
        self._name = name
        self._args = args
        self._ann = None

    def __enter__(self):
        tr = self._tracer
        if tr._jax_bridge:
            self._ann = tr._annotation(self._name)
            if self._ann is not None:
                self._ann.__enter__()
        tr._emit("B", self._name, args=self._args)
        return self

    def __exit__(self, *exc):
        self._tracer._emit("E", self._name)
        if self._ann is not None:
            self._ann.__exit__(*exc)
        return False


class _NoopSpan:
    """Shared do-nothing span: the disabled-mode fast path returns this
    singleton, so ``with obs.span(...)`` costs one attribute check."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NOOP_SPAN = _NoopSpan()


class Tracer:
    def __init__(self, *, jax_profiler: bool = False,
                 max_events: int = 1_000_000):
        self._t0_ns = time.perf_counter_ns()
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._max_events = int(max_events)
        self.dropped = 0
        self._named_threads: set[int] = set()
        self._tracks: dict[str, int] = {}  # label -> synthetic tid
        self._jax_bridge = bool(jax_profiler)

    # ------------------------------------------------------------ plumbing
    @staticmethod
    def _annotation(name):
        try:
            import jax
            return jax.profiler.TraceAnnotation(name)
        except Exception:  # noqa: BLE001 — the bridge is best-effort
            return None

    def _us(self, t_ns: int | None = None) -> float:
        t_ns = time.perf_counter_ns() if t_ns is None else t_ns
        return (t_ns - self._t0_ns) / 1e3

    def _append(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) >= self._max_events:
                self.dropped += 1
                return
            self._events.append(ev)

    def _thread_tid(self) -> int:
        tid = threading.get_ident()
        if tid not in self._named_threads:
            self._named_threads.add(tid)
            self._append({"ph": "M", "name": "thread_name", "pid": 0,
                          "tid": tid,
                          "args": {"name": threading.current_thread().name}})
        return tid

    def track_tid(self, label: str) -> int:
        """Synthetic tid for a logical track (e.g. one lane per serve
        request), named ``label`` in the viewer."""
        with self._lock:
            tid = self._tracks.get(label)
            if tid is not None:
                return tid
            tid = 1_000_000_000 + len(self._tracks)
            self._tracks[label] = tid
        self._append({"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                      "args": {"name": label}})
        return tid

    def _emit(self, ph: str, name: str, *, args=None) -> None:
        ev = {"ph": ph, "name": name, "pid": 0, "tid": self._thread_tid(),
              "ts": self._us()}
        if args:
            ev["args"] = args
        self._append(ev)

    # ------------------------------------------------------------- surface
    def span(self, name: str, args: dict | None = None) -> _Span:
        return _Span(self, name, args)

    def instant(self, name: str, args: dict | None = None) -> None:
        ev = {"ph": "i", "name": name, "pid": 0, "tid": self._thread_tid(),
              "ts": self._us(), "s": "t"}
        if args:
            ev["args"] = args
        self._append(ev)

    def complete(self, name: str, t0_ns: int, t1_ns: int, *,
                 track: str | None = None, args: dict | None = None) -> None:
        """Retroactive span from raw ``perf_counter_ns`` endpoints (an ``X``
        event). Endpoints stamped before the tracer started are dropped —
        they have no meaningful position on this trace's timeline."""
        if t0_ns < self._t0_ns or t1_ns < t0_ns:
            return
        tid = (self.track_tid(track) if track is not None
               else self._thread_tid())
        ev = {"ph": "X", "name": name, "pid": 0, "tid": tid,
              "ts": self._us(t0_ns), "dur": (t1_ns - t0_ns) / 1e3}
        if args:
            ev["args"] = args
        self._append(ev)

    # -------------------------------------------------------------- export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events(),
                       "displayTimeUnit": "ms"}, f)


def validate_trace(events: list[dict]) -> None:
    """Structural well-formedness of a trace-event list; raises
    ``AssertionError`` with context on the first violation.

    Checks: every event has ph/name/pid/tid (+ts for non-metadata); B/E
    pairs match by name and nest properly per tid; timestamps are
    non-decreasing per tid in emission order for B/E/i (X events are
    retroactive, so only their ``dur >= 0`` is checked); no unterminated
    spans."""
    stacks: dict[int, list] = {}
    last_ts: dict[int, float] = {}
    for i, ev in enumerate(events):
        assert isinstance(ev, dict), f"event {i} is not an object"
        for k in ("ph", "name", "pid", "tid"):
            assert k in ev, f"event {i} missing {k!r}: {ev}"
        ph, tid = ev["ph"], ev["tid"]
        assert ph in ("B", "E", "i", "I", "X", "M"), \
            f"event {i}: unknown phase {ph!r}"
        if ph == "M":
            continue
        ts = ev.get("ts")
        assert isinstance(ts, (int, float)) and ts >= 0, \
            f"event {i} ({ev['name']}): bad ts {ts!r}"
        if ph == "X":
            assert ev.get("dur", -1) >= 0, \
                f"event {i} ({ev['name']}): X needs dur >= 0"
            continue
        prev = last_ts.get(tid)
        assert prev is None or ts >= prev, \
            f"event {i} ({ev['name']}): ts {ts} < {prev} on tid {tid}"
        last_ts[tid] = ts
        if ph == "B":
            stacks.setdefault(tid, []).append(ev["name"])
        elif ph == "E":
            stack = stacks.get(tid) or []
            assert stack, f"event {i}: E {ev['name']!r} with empty stack"
            top = stack.pop()
            assert top == ev["name"], \
                f"event {i}: E {ev['name']!r} closes B {top!r} (tid {tid})"
    open_spans = {t: s for t, s in stacks.items() if s}
    assert not open_spans, f"unterminated spans: {open_spans}"


def validate_trace_file(path: str) -> list[dict]:
    """Load + validate an exported trace file; returns its event list."""
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc, dict) and isinstance(doc.get("traceEvents"), list)
    validate_trace(doc["traceEvents"])
    return doc["traceEvents"]
