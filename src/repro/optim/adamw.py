"""Reference (unmasked) AdamW — used by the LoRA baseline and as the oracle
the masked optimizer is tested against."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def init_opt_state(params) -> dict:
    z = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)  # noqa: E731
    return {"m": z(params), "v": z(params), "count": jnp.zeros((), jnp.float32)}


def update(cfg: OptimizerConfig, params, grads, opt_state, lr):
    c = opt_state["count"] + 1.0

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mhat = m2 / (1 - cfg.b1 ** c)
        vhat = v2 / (1 - cfg.b2 ** c)
        pf = p.astype(jnp.float32)
        p2 = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        return p2.astype(p.dtype), m2, v2

    flat = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    tup = lambda i: jax.tree.map(lambda t: t[i], flat,  # noqa: E731
                                 is_leaf=lambda t: isinstance(t, tuple))
    return tup(0), {"m": tup(1), "v": tup(2), "count": c}
