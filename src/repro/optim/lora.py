"""LoRA baseline (paper §4.2): rank-r adapters on the Q, K, V, O, G, U, D
projections, trained with standard AdamW while base weights stay frozen.

Adapters are kept in a FLAT dict keyed by canonical leaf path (a valid jax
pytree), mirroring the stacked-params layout: a target leaf of shape
[L, in..., out...] gets a: [L, fan_in, r] and b: [L, r, fan_out] (leading L
only for stacked groups), merged on the forward as
    w_eff = w + (alpha / r) * reshape(a @ b).
"""
from __future__ import annotations

import math
import zlib

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.utils.trees import tree_leaves_with_path, tree_map_with_path

# leaf basenames LoRA targets (paper: Q, K, V, O, U, D, G projections)
TARGETS = ("wq", "wk", "wv", "wo", "wg", "wu", "wd")
# leaves whose fan-in spans all axes but the last (o-proj style)
_SPLIT_LAST = ("wo",)


def _is_target(path: str) -> bool:
    return path.split("/")[-1] in TARGETS


def _is_stacked(path: str) -> bool:
    return path.split("/")[0].endswith("layers")


def _fan_split(path: str, shape: tuple, stacked: bool):
    core = shape[1:] if stacked else shape
    base = path.split("/")[-1]
    if base in _SPLIT_LAST:
        return int(math.prod(core[:-1])), int(core[-1])
    return int(core[0]), int(math.prod(core[1:]))


def init_lora(key: jax.Array, params: dict, cfg: ModelConfig, rank: int) -> dict:
    """-> flat dict {leaf_path: {"a": ..., "b": ...}} for targeted leaves."""
    out = {}
    for path, leaf in tree_leaves_with_path(params):
        if not _is_target(path) or leaf.ndim < 2:
            continue
        stacked = _is_stacked(path)
        fan_in, fan_out = _fan_split(path, leaf.shape, stacked)
        # crc32, not hash(): string hashing is salted per process, which
        # would make adapter init irreproducible across runs/hosts.
        k = jax.random.fold_in(key, zlib.crc32(path.encode()) % (2**31))
        shape_a = (leaf.shape[0], fan_in, rank) if stacked else (fan_in, rank)
        shape_b = (leaf.shape[0], rank, fan_out) if stacked else (rank, fan_out)
        out[path] = {
            "a": (jax.random.normal(k, shape_a) * fan_in**-0.5).astype(leaf.dtype),
            "b": jnp.zeros(shape_b, leaf.dtype),
        }
    return out


def merge(params: dict, lora_params: dict, cfg: ModelConfig,
          rank: int, alpha: float) -> dict:
    """w_eff = w + scale * a@b for targeted leaves; others pass through.
    Differentiable wrt lora_params only (base is stop_gradient-ed)."""
    scale = alpha / rank

    def one(path, w):
        w = jax.lax.stop_gradient(w)
        ab = lora_params.get(path)
        if ab is None:
            return w
        a, b = ab["a"], ab["b"]
        if a.ndim == 3:  # stacked
            delta = jnp.einsum("lir,lro->lio", a, b).reshape(w.shape)
        else:
            delta = (a @ b).reshape(w.shape)
        return w + (scale * delta).astype(w.dtype)

    return tree_map_with_path(one, params)


def num_lora_params(lora_params) -> int:
    return sum(int(jnp.size(x)) for x in jax.tree.leaves(lora_params))
