"""Learning-rate schedules (pure functions of the step, jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def learning_rate(cfg: OptimizerConfig, step) -> jnp.ndarray:
    t = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (t + 1) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        factor = 1.0
    elif cfg.schedule == "linear":
        frac = jnp.clip((t - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
        factor = 1.0 - frac
    elif cfg.schedule == "cosine":
        frac = jnp.clip((t - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0, 1)
        factor = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * factor
