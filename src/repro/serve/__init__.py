"""Serving subsystem: continuous-batching engine + paged KV pool + scheduler
+ radix prefix cache + background stream-out."""
from repro.serve.engine import (ServeEngine, clear_fn_cache, fn_cache_info,
                                generate, generate_legacy, set_fn_cache_limit)
from repro.serve.pages import PageAllocator, PoolExhausted, pages_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import FCFSScheduler, Request
from repro.serve.streamout import StreamOut

__all__ = ["ServeEngine", "FCFSScheduler", "Request", "generate",
           "generate_legacy", "fn_cache_info", "set_fn_cache_limit",
           "clear_fn_cache", "PageAllocator", "PoolExhausted", "pages_for",
           "PrefixCache", "StreamOut"]
