"""Serving subsystem: continuous-batching engine + paged KV pool + scheduler."""
from repro.serve.engine import (ServeEngine, clear_fn_cache, fn_cache_info,
                                generate, generate_legacy, set_fn_cache_limit)
from repro.serve.pages import PageAllocator, PoolExhausted, pages_for
from repro.serve.scheduler import FCFSScheduler, Request

__all__ = ["ServeEngine", "FCFSScheduler", "Request", "generate",
           "generate_legacy", "fn_cache_info", "set_fn_cache_limit",
           "clear_fn_cache", "PageAllocator", "PoolExhausted", "pages_for"]
