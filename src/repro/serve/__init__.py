"""Serving subsystem: continuous-batching engine + paged KV pool + scheduler
+ radix prefix cache + admission policies + cross-engine prefix persistence
+ background stream-out.

The surface is ``ServeEngine(cfg, params, ServeConfig(...))``; results come
back as ``Completion`` records. The pre-engine static-batch loop
(``generate_legacy``) is a test/parity module now — import it from
``repro.serve._oracle`` if you need the oracle."""
from repro.serve.config import ServeConfig
from repro.serve.engine import (ServeEngine, clear_fn_cache, fn_cache_info,
                                generate, set_fn_cache_limit)
from repro.serve.pages import PageAllocator, PoolExhausted, pages_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.prefix_store import PrefixStore
from repro.serve.results import Completion, RunResult
from repro.serve.scheduler import (AdmissionPolicy, FCFSScheduler,
                                   PrefixAwareAdmission, Request)
from repro.serve.streamout import StreamOut

__all__ = ["ServeEngine", "ServeConfig", "Completion", "RunResult",
           "FCFSScheduler", "AdmissionPolicy", "PrefixAwareAdmission",
           "Request", "generate", "fn_cache_info", "set_fn_cache_limit",
           "clear_fn_cache", "PageAllocator", "PoolExhausted", "pages_for",
           "PrefixCache", "PrefixStore", "StreamOut"]
