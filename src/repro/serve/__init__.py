"""Serving subsystem: continuous-batching engine + request scheduler."""
from repro.serve.engine import (ServeEngine, fn_cache_info, generate,
                                generate_legacy)
from repro.serve.scheduler import FCFSScheduler, Request

__all__ = ["ServeEngine", "FCFSScheduler", "Request", "generate",
           "generate_legacy", "fn_cache_info"]
