"""Parity oracle: the pre-engine static-batch decode loop.

``generate_legacy`` is no longer part of the public serving surface — use
``ServeEngine`` (or the module-level ``repro.serve.generate`` wrapper) for
real decoding. It stays importable here because it defines two contracts
the engine is tested against:

- **token parity**: the engine's continuous-batching output must match
  this loop token-for-token under greedy decoding (the paper's eval
  protocol), so the tests diff against it;
- **the historical rng stream**: sampled decoding draws one batch-wide
  categorical per step from a split-per-step key; ``generate``'s sampled
  path routes here so seeds from older runs keep reproducing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.engine import _prompt_prefix, make_decode_fn, make_prefill_fn


def generate_legacy(params, cfg: ModelConfig, batch: dict, *,
                    max_new_tokens: int, max_len: int | None = None,
                    temperature: float = 0.0, rng: jax.Array | None = None,
                    mesh=None, batch_axes=("data",), eos_id: int | None = None):
    """The pre-engine static-batch loop: batched prefill + one decode_step
    (and one host sync) per token, full max_new_tokens always decoded, EOS
    masked post-hoc. Kept as the engine's parity oracle and as the sampled-
    decoding path; its prefill/decode closures come from the process-wide
    cache instead of recompiling per call."""
    b, s = batch["tokens"].shape
    max_len = max_len or (s + _prompt_prefix(cfg, batch) + max_new_tokens)
    prefill_fn = make_prefill_fn(cfg, max_len, mesh=mesh, batch_axes=batch_axes)
    decode_fn = make_decode_fn(cfg, mesh=mesh, batch_axes=batch_axes)
    logits, cache = prefill_fn(params, batch)
    out = []
    tok = None
    for _ in range(max_new_tokens):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits.astype(jnp.float32) / temperature)
        else:
            tok = jnp.argmax(logits, axis=-1)
        out.append(np.asarray(tok))
        logits, cache = decode_fn(params, tok[:, None].astype(jnp.int32), cache)
    gen = np.stack(out, axis=1)
    if eos_id is not None:
        # zero out everything after the first EOS per row
        ended = np.cumsum(gen == eos_id, axis=1) > 0
        ended = np.concatenate([np.zeros((b, 1), bool), ended[:, :-1]], axis=1)
        gen = np.where(ended, 0, gen)
    return gen
