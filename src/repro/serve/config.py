"""``ServeConfig`` — the validated serving-side configuration.

``ServeEngine(cfg, params, serve_cfg)`` consolidates what used to be ~18
loose keyword arguments into one dataclass, validated once at
construction (``__post_init__``) instead of failing piecemeal deep inside
the engine: power-of-two chunk/bucket shapes, layered features that
require the paged layout (prefix cache, preemption, prefix-aware
admission), and page/bucket divisibility for the prefix path. Model-
family-dependent checks (which families can page, bucket, or chunk) stay
in the engine where the family is known.

Only serving policy lives here — the model config (``ModelConfig``) and
params stay separate positional arguments: one ``ServeConfig`` is reused
across checkpoints and archs in eval sweeps.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

_ADMISSION_POLICIES = ("fcfs", "prefix_aware")


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@dataclass(eq=False)
class ServeConfig:
    """Serving configuration for ``ServeEngine`` (see module docstring).

    Capacity: ``max_len`` (cache positions per request), ``num_slots``
    (concurrent residents). Decoding: ``eos_id``/``pad_id``/
    ``decode_chunk``/``temperature``/``rng``. Placement: ``mesh``/
    ``batch_axes``. KV layout: ``kv_layout`` + ``page_size``/``num_pages``
    (paged pool sizing). Prefill: ``prefill_chunk`` (chunked),per-bucket
    ``min_bucket``, ``prefill_rows`` (rows per bucketed/grouped call).
    Layered features: ``prefix_cache``/``prefix_cache_pages`` (radix
    tree), ``preempt``, ``on_complete``/``stream_out`` (background
    stream-out of ``Completion`` records). Scheduling: ``admission``
    ("fcfs" keeps strict arrival order; "prefix_aware" may admit a queued
    request early when its cached prefix pages sit at the LRU eviction
    frontier, bounded by ``admission_max_skips`` bypasses per waiting
    request), ``admission_frontier_pages`` (frontier depth; default
    2x pages-per-request). Persistence: ``prefix_store`` (a server-level
    ``PrefixStore`` the engine adopts warm pages from and hands its radix
    tree to at ``close()``).
    """

    max_len: int
    num_slots: int
    eos_id: int | None = None
    pad_id: int = 0
    decode_chunk: int = 8
    temperature: float = 0.0
    rng: Any = None
    mesh: Any = None
    batch_axes: tuple = ("data",)
    kv_layout: str = "dense"
    page_size: int = 16
    num_pages: int | None = None
    prefill_chunk: int = 0
    min_bucket: int = 16
    prefill_rows: int = 1
    prefix_cache: bool = False
    prefix_cache_pages: int | None = None
    preempt: bool = False
    on_complete: Callable | None = None
    stream_out: bool = True
    admission: str = "fcfs"
    admission_max_skips: int = 4
    admission_frontier_pages: int | None = None
    prefix_store: Any = None
    extra: dict = field(default_factory=dict)

    def __post_init__(self):
        self.max_len = int(self.max_len)
        self.num_slots = int(self.num_slots)
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.num_slots < 1:
            raise ValueError(f"num_slots must be >= 1, got {self.num_slots}")
        if self.prefill_rows < 1:
            raise ValueError(
                f"prefill_rows must be >= 1, got {self.prefill_rows}")
        if self.decode_chunk < 1:
            raise ValueError(
                f"decode_chunk must be >= 1, got {self.decode_chunk}")
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', "
                             f"got {self.kv_layout!r}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages is not None and int(self.num_pages) < 1:
            raise ValueError(f"num_pages must be >= 1 (or None for full "
                             f"capacity), got {self.num_pages}")
        # pow2 shape checks: chunked prefill tiles pow2 buckets, and the
        # prefix path builds pow2 suffix chunks/scratches — non-pow2 values
        # would mint a compile key per odd shape
        if self.prefill_chunk and not _is_pow2(self.prefill_chunk):
            raise ValueError(f"prefill_chunk must be a power of two "
                             f"(got {self.prefill_chunk}) so chunk shapes "
                             f"tile the pow2 buckets")
        if not _is_pow2(self.min_bucket):
            raise ValueError(f"min_bucket must be a power of two, "
                             f"got {self.min_bucket}")
        # layered features require the paged pool
        if self.prefix_cache and self.kv_layout != "paged":
            raise ValueError(
                "prefix_cache=True requires kv_layout='paged': page "
                "aliasing needs the shared pool (dense rows cannot be "
                "shared between slots)")
        if self.preempt and self.kv_layout != "paged":
            raise ValueError(
                "preempt=True requires kv_layout='paged' with a page pool "
                "(preemption frees and re-acquires pages; the dense layout "
                "has nothing to reclaim)")
        if self.prefix_cache:
            if not _is_pow2(self.page_size):
                raise ValueError(
                    f"prefix_cache=True requires a power-of-two page_size "
                    f"(got {self.page_size}): suffix starts are page-"
                    f"aligned and must tile the pow2 prefill buckets")
            if (self.min_bucket % self.page_size
                    and self.page_size % self.min_bucket):
                raise ValueError(
                    f"prefix_cache=True requires min_bucket and page_size "
                    f"to divide one another (got min_bucket="
                    f"{self.min_bucket}, page_size={self.page_size}) so "
                    f"page-aligned suffix starts land on bucket-tileable "
                    f"boundaries")
        if self.admission not in _ADMISSION_POLICIES:
            raise ValueError(f"admission must be one of "
                             f"{_ADMISSION_POLICIES}, got {self.admission!r}")
        if self.admission == "prefix_aware" and not self.prefix_cache:
            raise ValueError(
                "admission='prefix_aware' requires prefix_cache=True: the "
                "policy schedules around the radix tree's LRU eviction "
                "frontier")
        if self.admission_max_skips < 1:
            raise ValueError(f"admission_max_skips must be >= 1, "
                             f"got {self.admission_max_skips}")
        if (self.admission_frontier_pages is not None
                and self.admission_frontier_pages < 1):
            raise ValueError(f"admission_frontier_pages must be >= 1 (or "
                             f"None for the default), got "
                             f"{self.admission_frontier_pages}")
        if self.prefix_store is not None and not self.prefix_cache:
            raise ValueError(
                "prefix_store requires prefix_cache=True: the store "
                "persists the radix tree (and its pages) across engines")
        self.batch_axes = tuple(self.batch_axes)
