"""Batched serving: prefill + greedy/temperature decode loop.

Used by the examples, the synthetic-math evaluator (the GSM8K-protocol
proxy: zero-shot greedy decoding, temperature 0), and the serve dry-run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry


def make_decode_fn(cfg: ModelConfig, *, mesh=None, batch_axes=("data",)):
    model = registry.get(cfg)

    @jax.jit
    def decode_fn(params, tokens, cache):
        return model.decode_step(params, cfg, tokens, cache, mesh=mesh,
                                 batch_axes=batch_axes)

    return decode_fn


def make_prefill_fn(cfg: ModelConfig, max_len: int, *, mesh=None,
                    batch_axes=("data",)):
    model = registry.get(cfg)

    @partial(jax.jit, static_argnames=())
    def prefill_fn(params, batch):
        return model.prefill(params, cfg, batch, max_len, mesh=mesh,
                             batch_axes=batch_axes)

    return prefill_fn


def generate(params, cfg: ModelConfig, batch: dict, *, max_new_tokens: int,
             max_len: int | None = None, temperature: float = 0.0,
             rng: jax.Array | None = None, mesh=None, batch_axes=("data",),
             eos_id: int | None = None):
    """Greedy (temperature=0, the paper's eval protocol) or sampled decoding.
    batch["tokens"]: [B, S_prompt]. Returns np.ndarray [B, max_new_tokens]."""
    b, s = batch["tokens"].shape
    max_len = max_len or (s + max_new_tokens)
    prefill_fn = make_prefill_fn(cfg, max_len, mesh=mesh, batch_axes=batch_axes)
    decode_fn = make_decode_fn(cfg, mesh=mesh, batch_axes=batch_axes)
    logits, cache = prefill_fn(params, batch)
    out = []
    tok = None
    for i in range(max_new_tokens):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits.astype(jnp.float32) / temperature)
        else:
            tok = jnp.argmax(logits, axis=-1)
        out.append(np.asarray(tok))
        logits, cache = decode_fn(params, tok[:, None].astype(jnp.int32), cache)
    gen = np.stack(out, axis=1)
    if eos_id is not None:
        # zero out everything after the first EOS per row
        ended = np.cumsum(gen == eos_id, axis=1) > 0
        ended = np.concatenate([np.zeros((b, 1), bool), ended[:, :-1]], axis=1)
        gen = np.where(ended, 0, gen)
    return gen
