"""Continuous-batching serve engine with a slot-based KV cache.

``ServeEngine`` compiles prefill/decode ONCE per (cfg, max_len, num_slots)
— the jitted closures live in a module-level cache keyed on the static
configuration, so fresh engine instances (and the legacy ``generate`` path)
never pay compile time twice. The engine owns a persistent slot-based KV
cache with per-slot position/finished state: requests with different prompt
lengths are admitted into free slots as others finish (continuous
batching), EOS terminates a slot on-device, and decode runs as a jitted
fixed-chunk ``lax.scan`` with a single host sync per chunk instead of per
token.

Used by the examples, the synthetic-math evaluator (the GSM8K-protocol
proxy: zero-shot greedy decoding, temperature 0), the serve launcher, and
``benchmarks/bench_serve.py``. The pre-engine static-batch loop is kept as
``generate_legacy`` (the parity oracle); ``generate`` keeps its original
signature and reproduces the legacy outputs exactly.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serve.scheduler import FCFSScheduler, Request

# ------------------------------------------------------ compiled-fn caching
#
# jax.jit caches on function identity: rebuilding a closure per call (the
# pre-engine behavior) recompiles every time. All jitted serving closures
# are built once per static key and reused process-wide.

_FN_CACHE: dict = {}
_FN_STATS = {"hits": 0, "misses": 0}


def _cached_fn(key, build):
    fn = _FN_CACHE.get(key)
    if fn is None:
        fn = _FN_CACHE[key] = build()
        _FN_STATS["misses"] += 1
    else:
        _FN_STATS["hits"] += 1
    return fn


def fn_cache_info() -> dict:
    """{hits, misses, size} of the process-wide compiled-fn cache. A stable
    ``misses`` count across calls means nothing was rebuilt (and therefore
    nothing recompiled)."""
    return dict(_FN_STATS, size=len(_FN_CACHE))


def clear_fn_cache() -> None:
    _FN_CACHE.clear()
    _FN_STATS.update(hits=0, misses=0)


def make_decode_fn(cfg: ModelConfig, *, mesh=None, batch_axes=("data",)):
    key = ("decode", cfg, mesh, tuple(batch_axes))

    def build():
        model = registry.get(cfg)

        @jax.jit
        def decode_fn(params, tokens, cache):
            return model.decode_step(params, cfg, tokens, cache, mesh=mesh,
                                     batch_axes=batch_axes)

        return decode_fn

    return _cached_fn(key, build)


def make_prefill_fn(cfg: ModelConfig, max_len: int, *, mesh=None,
                    batch_axes=("data",)):
    key = ("prefill", cfg, max_len, mesh, tuple(batch_axes))

    def build():
        model = registry.get(cfg)

        @jax.jit
        def prefill_fn(params, batch):
            return model.prefill(params, cfg, batch, max_len, mesh=mesh,
                                 batch_axes=batch_axes)

        return prefill_fn

    return _cached_fn(key, build)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _prompt_prefix(cfg: ModelConfig, batch: dict) -> int:
    """Non-token cache positions a prompt occupies (vlm patch prefix).
    Batch-derived, not cfg-derived: a vlm batch without patch_embeds
    prefills with prefix 0 (see lm.prefill)."""
    if cfg.family == "vlm" and "patch_embeds" in batch:
        return int(batch["patch_embeds"].shape[1])
    return 0


def _sample(logits, temperature: float, keys):
    """Greedy (paper eval protocol) or per-slot temperature sampling — each
    slot consumes its own key stream so the admission order of OTHER slots
    never perturbs a request's tokens."""
    if temperature > 0:
        return jax.vmap(lambda k, lg: jax.random.categorical(
            k, lg.astype(jnp.float32) / temperature))(
                keys, logits).astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------- engine


class ServeEngine:
    """Slot-based continuous-batching engine.

    The KV cache has ``num_slots`` rows; each slot holds at most one
    in-flight request with its own position (``cache["pos"]`` [B]) and
    on-device finished flag. Admission batches same-shape pending requests
    (FCFS), prefills them in one call, and scatters the new rows into free
    slots (``insert_slots``); group sizes are padded up to a power of two
    with the pad rows scattered to the out-of-range slot index (dropped),
    bounding prefill compile keys to log2(num_slots) per prompt shape.

    ``submit`` then ``step`` drive it incrementally; ``run`` drains a whole
    request list. Arrivals are measured in engine steps (one ``step`` = one
    admission pass + one decode chunk).

    Caveat: with ``moe_impl="ep"`` on a mesh, expert capacity buckets depend
    on the batch's token count, so (as with any capacity-routed MoE under
    rebatching) a request's tokens can depend on what shares its decode
    batch; admission groups are never pow2-padded for ep configs.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_len: int,
                 num_slots: int, eos_id: int | None = None, pad_id: int = 0,
                 decode_chunk: int = 8, temperature: float = 0.0,
                 rng: jax.Array | None = None, mesh=None,
                 batch_axes=("data",)):
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.cfg, self.params = cfg, params
        self.model = registry.get(cfg)
        self.max_len, self.num_slots = int(max_len), int(num_slots)
        self.eos_id = None if eos_id is None else int(eos_id)
        self.pad_id = int(pad_id)
        self.decode_chunk = int(decode_chunk)
        self.temperature = float(temperature)
        self.mesh, self.batch_axes = mesh, tuple(batch_axes)
        self.scheduler = FCFSScheduler()

        self.cache = self.model.init_cache(cfg, self.num_slots, self.max_len)
        self.finished = jnp.ones((self.num_slots,), bool)  # idle slots are inert
        self.last_tok = jnp.full((self.num_slots,), self.pad_id, jnp.int32)
        base = rng if rng is not None else jax.random.PRNGKey(0)
        self._base_rng = base
        self.keys = jax.random.split(base, self.num_slots)

        self._slot_req: list[Request | None] = [None] * self.num_slots
        self._out: dict[int, list[int]] = {}      # uid -> emitted tokens
        self._left: dict[int, int] = {}           # uid -> remaining budget
        self.clock = 0                            # admission step counter
        self.stats = {"decode_chunks": 0, "decode_steps": 0, "prefills": 0,
                      "admitted": 0, "completed": 0}

    # ---------------------------------------------------- compiled closures

    def _static_key(self) -> tuple:
        return (self.cfg, self.max_len, self.num_slots, self.eos_id,
                self.pad_id, self.temperature, self.mesh, self.batch_axes)

    def _chunk_fn(self):
        # the build closure must capture only statics (no `self`): the jitted
        # fn lives in the process-wide cache and would otherwise pin the
        # first engine instance's params + KV cache for the process lifetime
        key = ("chunk", self.decode_chunk) + self._static_key()
        model, cfg = self.model, self.cfg
        mesh, axes = self.mesh, self.batch_axes
        eos, pad, steps = self.eos_id, self.pad_id, self.decode_chunk
        temperature = self.temperature

        def build():
            @jax.jit
            def chunk_fn(params, cache, last_tok, finished, keys):
                def body(carry, _):
                    cache, tok, fin, keys = carry
                    logits, cache = model.decode_step(
                        params, cfg, tok[:, None], cache, mesh=mesh,
                        batch_axes=axes)
                    ks = jax.vmap(jax.random.split)(keys)
                    nxt = _sample(logits, temperature, ks[:, 1])
                    keys = ks[:, 0] if temperature > 0 else keys
                    nxt = jnp.where(fin, pad, nxt)
                    if eos is not None:
                        fin = fin | (nxt == eos)
                    return (cache, nxt, fin, keys), nxt

                carry = (cache, last_tok, finished, keys)
                (cache, tok, fin, keys), toks = jax.lax.scan(
                    body, carry, None, length=steps)
                return cache, tok, fin, keys, toks.T  # toks: [B, steps]

            return chunk_fn

        return _cached_fn(key, build)

    def _admit_fn(self, group_size: int, sig: tuple):
        key = ("admit", group_size, sig) + self._static_key()
        model, cfg, max_len = self.model, self.cfg, self.max_len
        mesh, axes, eos = self.mesh, self.batch_axes, self.eos_id
        temperature = self.temperature

        def build():
            @jax.jit
            def admit_fn(params, cache, batch, slots, last_tok, finished,
                         keys, req_keys):
                logits, new_cache = model.prefill(params, cfg, batch, max_len,
                                                  mesh=mesh, batch_axes=axes)
                cache = model.insert_slots(cache, new_cache, slots)
                ks = jax.vmap(jax.random.split)(req_keys)
                tok0 = _sample(logits, temperature, ks[:, 1])
                fin0 = ((tok0 == eos) if eos is not None
                        else jnp.zeros(tok0.shape, bool))
                last_tok = last_tok.at[slots].set(tok0)
                finished = finished.at[slots].set(fin0)
                keys = keys.at[slots].set(ks[:, 0])
                return cache, last_tok, finished, keys, tok0

            return admit_fn

        return _cached_fn(key, build)

    # ----------------------------------------------------------- lifecycle

    def submit(self, req: Request) -> None:
        prefix = 0
        if self.cfg.family == "vlm" and "patch_embeds" in req.extras:
            prefix = int(np.asarray(req.extras["patch_embeds"]).shape[0])
        need = prefix + req.prompt_len + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache positions "
                f"(prefix {prefix} + prompt {req.prompt_len} + "
                f"{req.max_new_tokens} new) but max_len={self.max_len}")
        self.scheduler.submit(req)

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self._slot_req) if r is None]

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def _complete(self, slot: int, completed: list) -> None:
        req = self._slot_req[slot]
        self._slot_req[slot] = None
        self.stats["completed"] += 1
        completed.append((req.uid, np.asarray(self._out.pop(req.uid),
                                              np.int32)))
        self._left.pop(req.uid, None)

    def _admit(self, group: list[Request], completed: list) -> None:
        free = self._free_slots()
        g = len(group)
        assert g <= len(free)
        slot_ids = free[:g]
        # pad the group to a power of two: duplicate rows, scattered to the
        # out-of-range slot index so insert_slots drops them — one prefill
        # compile per (pow2 size, prompt signature). EP MoE is exempt: its
        # capacity buckets depend on the batch's token count, so pad rows
        # would perturb the real rows' routing
        gp = g if self.cfg.moe_impl == "ep" else _next_pow2(g)
        tokens = np.stack([r.tokens for r in group]).astype(np.int32)
        extras = {k: np.stack([np.asarray(r.extras[k]) for r in group])
                  for k in group[0].extras}
        if gp > g:
            rep = [(0, gp - g)] + [(0, 0)] * (tokens.ndim - 1)
            tokens = np.pad(tokens, rep, mode="edge")
            extras = {k: np.pad(v, [(0, gp - g)] + [(0, 0)] * (v.ndim - 1),
                                mode="edge") for k, v in extras.items()}
        slots = np.asarray(slot_ids + [self.num_slots] * (gp - g), np.int32)
        batch = {"tokens": tokens, **extras}
        if self.temperature > 0:
            req_keys = jnp.stack(
                [jax.random.fold_in(self._base_rng, r.uid) for r in group]
                + [self._base_rng] * (gp - g))
        else:
            req_keys = jnp.zeros((gp,) + self.keys.shape[1:], self.keys.dtype)

        fn = self._admit_fn(gp, group[0].signature())
        self.cache, self.last_tok, self.finished, self.keys, tok0 = fn(
            self.params, self.cache, batch, slots, self.last_tok,
            self.finished, self.keys, req_keys)
        self.stats["prefills"] += 1
        self.stats["admitted"] += g

        tok0 = np.asarray(tok0)[:g]
        for req, slot, t in zip(group, slot_ids, tok0):
            self._slot_req[slot] = req
            self._out[req.uid] = [int(t)]
            self._left[req.uid] = req.max_new_tokens - 1
            if ((self.eos_id is not None and int(t) == self.eos_id)
                    or self._left[req.uid] == 0):
                self._complete(slot, completed)

    def step(self) -> list[tuple[int, np.ndarray]]:
        """One engine step: admit every runnable same-shape group into free
        slots, then run one jitted decode chunk (a single host sync).
        Returns (uid, tokens) for requests completed this step."""
        completed: list[tuple[int, np.ndarray]] = []
        while True:
            group = self.scheduler.next_group(len(self._free_slots()),
                                              now=self.clock)
            if not group:
                break
            self._admit(group, completed)

        if self.num_active:
            fn = self._chunk_fn()
            self.cache, self.last_tok, self.finished, self.keys, toks = fn(
                self.params, self.cache, self.last_tok, self.finished,
                self.keys)
            self.stats["decode_chunks"] += 1
            self.stats["decode_steps"] += self.decode_chunk
            toks = np.asarray(toks)  # [num_slots, chunk] — the host sync
            for slot in range(self.num_slots):
                req = self._slot_req[slot]
                if req is None:
                    continue
                for t in toks[slot]:
                    self._out[req.uid].append(int(t))
                    self._left[req.uid] -= 1
                    if ((self.eos_id is not None and int(t) == self.eos_id)
                            or self._left[req.uid] == 0):
                        self._complete(slot, completed)
                        break
        self.clock += 1
        return completed

    def run(self, requests=()) -> dict[int, np.ndarray]:
        """Submit ``requests`` and drive steps until queue and slots drain.
        Returns {uid: generated tokens (ends at EOS if hit)}."""
        for r in requests:
            self.submit(r)
        results: dict[int, np.ndarray] = {}
        while self.scheduler.pending or self.num_active:
            for uid, toks in self.step():
                results[uid] = toks
        return results

    def generate(self, batch: dict, *, max_new_tokens: int) -> np.ndarray:
        """Static-batch convenience: decode ``batch`` (all prompts the same
        length, batch size <= num_slots) and return [B, max_new_tokens] with
        ``pad_id`` after EOS — the legacy ``generate`` output contract."""
        b = batch["tokens"].shape[0]
        if b > self.num_slots:
            raise ValueError(f"batch {b} > num_slots {self.num_slots}")
        reqs = [Request(uid=i, tokens=np.asarray(batch["tokens"][i]),
                        max_new_tokens=max_new_tokens,
                        extras={k: np.asarray(batch[k][i]) for k in batch
                                if k != "tokens"})
                for i in range(b)]
        res = self.run(reqs)
        out = np.full((b, max_new_tokens), self.pad_id, np.int32)
        for i in range(b):
            toks = res[i][:max_new_tokens]
            out[i, :len(toks)] = toks
        return out


# ------------------------------------------------------------- public API


def generate(params, cfg: ModelConfig, batch: dict, *, max_new_tokens: int,
             max_len: int | None = None, temperature: float = 0.0,
             rng: jax.Array | None = None, mesh=None, batch_axes=("data",),
             eos_id: int | None = None, num_slots: int | None = None,
             decode_chunk: int = 8):
    """Greedy (temperature=0, the paper's eval protocol) or sampled decoding.
    batch["tokens"]: [B, S_prompt]. Returns np.ndarray [B, max_new_tokens].

    Compat wrapper over ``ServeEngine`` — token-for-token identical to the
    pre-engine loop (``generate_legacy``). Sampled decoding keeps the legacy
    path so the historical rng stream (one batch-wide categorical per step)
    is preserved exactly."""
    if temperature > 0:
        return generate_legacy(params, cfg, batch,
                               max_new_tokens=max_new_tokens, max_len=max_len,
                               temperature=temperature, rng=rng, mesh=mesh,
                               batch_axes=batch_axes, eos_id=eos_id)
    b, s = batch["tokens"].shape
    max_len = max_len or (s + _prompt_prefix(cfg, batch) + max_new_tokens)
    engine = ServeEngine(cfg, params, max_len=max_len,
                         num_slots=num_slots or b, eos_id=eos_id,
                         decode_chunk=decode_chunk, mesh=mesh,
                         batch_axes=batch_axes)
    return engine.generate(batch, max_new_tokens=max_new_tokens)


def generate_legacy(params, cfg: ModelConfig, batch: dict, *,
                    max_new_tokens: int, max_len: int | None = None,
                    temperature: float = 0.0, rng: jax.Array | None = None,
                    mesh=None, batch_axes=("data",), eos_id: int | None = None):
    """The pre-engine static-batch loop: batched prefill + one decode_step
    (and one host sync) per token, full max_new_tokens always decoded, EOS
    masked post-hoc. Kept as the engine's parity oracle and as the sampled-
    decoding path; its prefill/decode closures now come from the process-
    wide cache instead of recompiling per call."""
    b, s = batch["tokens"].shape
    max_len = max_len or (s + _prompt_prefix(cfg, batch) + max_new_tokens)
    prefill_fn = make_prefill_fn(cfg, max_len, mesh=mesh, batch_axes=batch_axes)
    decode_fn = make_decode_fn(cfg, mesh=mesh, batch_axes=batch_axes)
    logits, cache = prefill_fn(params, batch)
    out = []
    tok = None
    for _ in range(max_new_tokens):
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, logits.astype(jnp.float32) / temperature)
        else:
            tok = jnp.argmax(logits, axis=-1)
        out.append(np.asarray(tok))
        logits, cache = decode_fn(params, tok[:, None].astype(jnp.int32), cache)
    gen = np.stack(out, axis=1)
    if eos_id is not None:
        # zero out everything after the first EOS per row
        ended = np.cumsum(gen == eos_id, axis=1) > 0
        ended = np.concatenate([np.zeros((b, 1), bool), ended[:, :-1]], axis=1)
        gen = np.where(ended, 0, gen)
    return gen
