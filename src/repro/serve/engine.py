"""Continuous-batching serve engine: slot KV cache, paged pool, bucketed prefill.

``ServeEngine`` compiles prefill/decode ONCE per static configuration — the
jitted closures live in a bounded module-level LRU cache — so fresh engine
instances (and the legacy ``generate`` path) never pay compile time twice.
The engine owns a persistent slot-based KV cache with per-slot position and
on-device finished state: requests with different prompt lengths are
admitted into free slots as others finish (continuous batching), EOS
terminates a slot on-device, and decode runs as a jitted fixed-chunk
``lax.scan`` with a single host sync per chunk instead of per token.

Two KV layouts:

- ``kv_layout="dense"`` (default): every slot owns a ``max_len`` cache row.
- ``kv_layout="paged"``: K/V live in a shared page pool sized by
  ``num_pages`` and each slot maps positions through a page table
  (serve/pages.py + lm.init_paged_cache). Cache memory scales with live
  tokens instead of ``num_slots * max_len``; when the pool runs dry the
  engine admits what fits and leaves the rest queued (admission
  backpressure) instead of failing. Supported for plain GQA/MHA dense and
  moe stacks; a no-op for ssm (no length-indexed KV); other families raise.

Three optional layers ride on the paged pool:

- ``prefix_cache=True``: a host-side radix tree (serve/prefix_cache.py)
  over page-granular token prefixes. Admission looks up the longest cached
  prefix, aliases those pages read-only into the new slot's table
  (refcounted — see serve/pages.py), and prefills ONLY the uncached suffix
  (the bucketed prefill path gains a traced ``start`` offset). When the
  whole prompt is cached the last matched page is copied-on-write before
  the final-token recompute so a shared page is never written through.
  Completed requests insert their prompt pages back into the tree under an
  LRU cap with refcount-aware eviction.
- ``preempt=True``: when the pool is exhausted and the FCFS head cannot
  fit, the engine first evicts prefix-cache pages, then preempts the
  resident with the most remaining budget — its private pages free (shared
  prefix pages just decref), it requeues at the scheduler head carrying its
  already-generated tokens (original arrival preserved), and re-admits via
  the normal — prefix-accelerated, its own prompt+generated pages are
  inserted into the tree first — prefill path. Re-admission is token-exact
  vs the never-preempted run: greedy decoding is deterministic in the
  context, and sampled decoding saves the slot's key at preemption so the
  per-request key stream continues bit-exactly.
- ``on_complete=...``: finished sequences hand off to a background
  detokenize/stream-out worker (serve/streamout.py) so ``step()`` never
  blocks on host-side decode.

Prefill is prompt-length-BUCKETED for dense/moe: prompts are right-padded
to the smallest bucket in {min_bucket, 2*min_bucket, ..., max_len} and
admission groups are padded to ``num_slots`` rows, so the prefill compile
count is bounded by ``len(prefill_buckets)`` — not by the number of
distinct prompt lengths (lm.prefill gathers each row's logits at its true
``lengths - 1``; causal attention makes the pad tokens inert). Long
prefills can additionally be CHUNKED (``prefill_chunk=N``): the bucket is
prefilled N tokens per engine step, interleaved between decode chunks, so
a long prompt never stalls resident decodes for its whole prefill.
Families without a length-indexed KV cache (ssm/hybrid/vlm/encdec, and
EP-MoE whose routing sees pad rows) keep the legacy exact-length
signature-grouped admission path.

Serving policy is carried by one validated ``ServeConfig``
(serve/config.py): ``ServeEngine(cfg, params, ServeConfig(...))`` is the
surface; the historical ``ServeEngine(cfg, params, **kwargs)`` spelling
still works for one release behind a ``DeprecationWarning``. Every
delivery path (``step``/``run``/``generate``/``on_complete``) hands back
``Completion`` records (serve/results.py). Admission order is pluggable
through ``scheduler.AdmissionPolicy`` — ``admission="prefix_aware"``
schedules around the radix tree's LRU eviction frontier — and a
server-level ``PrefixStore`` (serve/prefix_store.py) carries the radix
tree + page pool across engine instances (``close()`` hands them over; the
next engine over the same params adopts them warm).

Observability: the engine records into the process-global obs registry
(``repro.obs``) — per-request queue-wait/TTFT/time-per-output-token/e2e
latency histograms (wall-clock, stamped at submit/admission/completion),
page-pool and fn-cache gauges — and, when ``obs.enable()`` tracing is on,
emits admission/prefill/decode spans plus one retroactive ``e2e``+``ttft``
span lane per request in the exported Perfetto trace.
``stats_snapshot()`` consolidates every stat surface into one nested dict:

- ``engine`` — the per-engine counters (``self.stats``): ``decode_chunks``
  (jitted chunk dispatches), ``decode_steps`` (ACTUAL emitted decode
  positions, including a terminal EOS — not ``chunks * decode_chunk``),
  ``prefills``/``prefill_chunks``/``prefill_tokens``, ``admitted``/
  ``completed``, ``backpressure``/``preempted``, ``prefix_hits``/
  ``prefix_pages_shared``.
- ``latency_us`` — ``queue_wait``/``ttft``/``tpot``/``e2e`` histogram
  summaries (count, mean, min/max, p50/p95/p99), microseconds.
- ``pages`` — ``PageAllocator.stats()`` (num/live/free/peak pages,
  utilization); None for the dense layout.
- ``scheduler`` — ``pending`` queue depth + the admission policy's
  counters (``bypass_admissions``/``bypassed``/``aging_forced`` for
  ``prefix_aware``; None for plain FCFS).
- ``prefix_cache`` — radix-tree ``pages``/``capacity_pages``; None when
  the prefix cache is off.
- ``stream_out`` — background detokenize queue ``pending``; None when no
  stream-out worker runs.
- ``fn_cache`` — the process-wide compiled-fn cache counters
  (``fn_cache_info()``).

Used by the examples, the synthetic-math evaluator (the GSM8K-protocol
proxy: zero-shot greedy decoding, temperature 0), the serve launcher, and
``benchmarks/bench_serve.py``. The pre-engine static-batch loop lives in
``serve/_oracle.py`` (the parity oracle); ``generate`` keeps its original
signature and reproduces the legacy outputs exactly.
"""
from __future__ import annotations

import time
import warnings
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs.base import ModelConfig
from repro.models import registry
from repro.serve.config import ServeConfig
from repro.serve.pages import PageAllocator, PoolExhausted, pages_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.results import Completion, RunResult, TokenBatch
from repro.serve.scheduler import (FCFSScheduler, PrefixAwareAdmission,
                                   Request)
from repro.serve.streamout import StreamOut

# ------------------------------------------------------ compiled-fn caching
#
# jax.jit caches on function identity: rebuilding a closure per call (the
# pre-engine behavior) recompiles every time. All jitted serving closures
# are built once per static key and reused process-wide. The cache is a
# bounded LRU: a long-lived server that cycles through many configurations
# (or bucket sizes) evicts the coldest closure instead of growing without
# bound. The default limit comfortably covers one engine's full key set
# (buckets + chunk shapes + decode); size it up for multi-model servers.

_FN_CACHE: OrderedDict = OrderedDict()
_FN_LIMIT = 64
_FN_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def set_fn_cache_limit(limit: int) -> None:
    """Bound the compiled-fn LRU to ``limit`` entries (evicts immediately
    if already over)."""
    global _FN_LIMIT
    if limit < 1:
        raise ValueError(f"fn-cache limit must be >= 1, got {limit}")
    _FN_LIMIT = int(limit)
    while len(_FN_CACHE) > _FN_LIMIT:
        _FN_CACHE.popitem(last=False)
        _FN_STATS["evictions"] += 1


def _cached_fn(key, build):
    fn = _FN_CACHE.get(key)
    if fn is None:
        fn = _FN_CACHE[key] = build()
        _FN_STATS["misses"] += 1
        while len(_FN_CACHE) > _FN_LIMIT:
            _FN_CACHE.popitem(last=False)
            _FN_STATS["evictions"] += 1
    else:
        _FN_CACHE.move_to_end(key)
        _FN_STATS["hits"] += 1
    return fn


def fn_cache_info() -> dict:
    """{hits, misses, evictions, size, limit} of the process-wide
    compiled-fn cache. A stable ``misses`` count across calls means nothing
    was rebuilt (and therefore nothing recompiled)."""
    return dict(_FN_STATS, size=len(_FN_CACHE), limit=_FN_LIMIT)


def clear_fn_cache() -> None:
    _FN_CACHE.clear()
    _FN_STATS.update(hits=0, misses=0, evictions=0)


def make_decode_fn(cfg: ModelConfig, *, mesh=None, batch_axes=("data",)):
    key = ("decode", cfg, mesh, tuple(batch_axes))

    def build():
        model = registry.get(cfg)

        @jax.jit
        def decode_fn(params, tokens, cache):
            return model.decode_step(params, cfg, tokens, cache, mesh=mesh,
                                     batch_axes=batch_axes)

        return decode_fn

    return _cached_fn(key, build)


def make_prefill_fn(cfg: ModelConfig, max_len: int, *, mesh=None,
                    batch_axes=("data",)):
    key = ("prefill", cfg, max_len, mesh, tuple(batch_axes))

    def build():
        model = registry.get(cfg)

        @jax.jit
        def prefill_fn(params, batch):
            return model.prefill(params, cfg, batch, max_len, mesh=mesh,
                                 batch_axes=batch_axes)

        return prefill_fn

    return _cached_fn(key, build)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _admit_pad_size(g: int, moe_impl: str) -> int:
    """Padded row count for a legacy admission group of ``g`` requests:
    next power of two (bounds prefill compile keys to log2(num_slots) per
    signature). EP MoE is exempt — its expert-capacity buckets depend on
    the batch's total token count, so duplicated pad rows would perturb
    the real rows' routing."""
    return g if moe_impl == "ep" else _next_pow2(g)


def _make_buckets(max_len: int, min_bucket: int = 16) -> tuple[int, ...]:
    """Prompt-length buckets: powers of two from ``min_bucket`` up, capped
    at ``max_len`` (the last bucket is exactly max_len)."""
    buckets, b = [], min_bucket
    while b < max_len:
        buckets.append(b)
        b *= 2
    buckets.append(max_len)
    return tuple(buckets)


def _prompt_prefix(cfg: ModelConfig, batch: dict) -> int:
    """Non-token cache positions a prompt occupies (vlm patch prefix).
    Batch-derived, not cfg-derived: a vlm batch without patch_embeds
    prefills with prefix 0 (see lm.prefill)."""
    if cfg.family == "vlm" and "patch_embeds" in batch:
        return int(batch["patch_embeds"].shape[1])
    return 0


def _sample(logits, temperature: float, keys):
    """Greedy (paper eval protocol) or per-slot temperature sampling — each
    slot consumes its own key stream so the admission order of OTHER slots
    never perturbs a request's tokens."""
    if temperature > 0:
        return jax.vmap(lambda k, lg: jax.random.categorical(
            k, lg.astype(jnp.float32) / temperature))(
                keys, logits).astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------- engine


class ServeEngine:
    """Slot-based continuous-batching engine (see module docstring for the
    KV layouts and the bucketed/chunked prefill scheme).

    ``submit`` then ``step`` drive it incrementally; ``run`` drains a whole
    request list. Arrivals are measured in engine steps (one ``step`` = one
    prefill chunk (if a job is active) + one admission pass + one decode
    chunk).

    Caveat: with ``moe_impl="ep"`` on a mesh, expert capacity buckets depend
    on the batch's token count, so (as with any capacity-routed MoE under
    rebatching) a request's tokens can depend on what shares its decode
    batch; admission groups are never padded for ep configs and ep stays on
    the legacy exact-length admission path.
    """

    def __init__(self, cfg: ModelConfig, params,
                 serve_cfg: ServeConfig | None = None, **kwargs):
        if serve_cfg is None:
            # one-release deprecation shim: the historical ~18-kwarg surface
            # funnels into ServeConfig (same validation, one warning); the
            # legacy on_complete contract was (uid, tokens), so wrap it
            warnings.warn(
                "ServeEngine(cfg, params, **kwargs) is deprecated; pass a "
                "ServeConfig: ServeEngine(cfg, params, ServeConfig(...)). "
                "The loose-kwargs surface will be removed next release.",
                DeprecationWarning, stacklevel=2)
            cb = kwargs.pop("on_complete", None)
            if cb is not None:
                kwargs["on_complete"] = lambda c: cb(c.uid, c.tokens)
            serve_cfg = ServeConfig(**kwargs)
        elif kwargs:
            raise TypeError(
                f"ServeEngine got both a ServeConfig and loose kwargs "
                f"{sorted(kwargs)}; fold everything into the ServeConfig")
        scfg = serve_cfg
        self.serve_cfg = scfg
        self.cfg, self.params = cfg, params
        self.model = registry.get(cfg)
        self.max_len, self.num_slots = scfg.max_len, scfg.num_slots
        self.eos_id = scfg.eos_id
        self.pad_id = int(scfg.pad_id)
        self.decode_chunk = int(scfg.decode_chunk)
        self.temperature = float(scfg.temperature)
        self.mesh, self.batch_axes = scfg.mesh, scfg.batch_axes

        # bucketed prefill needs per-row logit gather over a padded batch
        # (lm.prefill lengths=); only length-indexed-KV families support it,
        # and EP-MoE must never see pad rows (routing is batch-coupled)
        self._bucketed = (cfg.family in ("dense", "moe")
                          and cfg.moe_impl != "ep")
        self.prefill_buckets = (_make_buckets(self.max_len, scfg.min_bucket)
                                if self._bucketed else ())
        # bucketed admission prefills fixed [prefill_rows, bucket] batches
        # (larger groups split across calls): one compile key per bucket,
        # and small/stale groups don't pay num_slots rows of pad FLOPs
        self.prefill_rows = min(int(scfg.prefill_rows), self.num_slots)

        self.kv_layout = scfg.kv_layout
        self.page_size = int(scfg.page_size)
        self._alloc: PageAllocator | None = None
        if scfg.kv_layout == "paged":
            if cfg.family == "ssm":
                # no length-indexed KV to page — identical to dense layout
                self.cache = self.model.init_cache(cfg, self.num_slots,
                                                   self.max_len)
            else:
                if cfg.moe_impl == "ep":
                    raise ValueError(
                        "kv_layout='paged' is not supported for "
                        "moe_impl='ep': EP decode dispatch is mesh-coupled "
                        "and stays on the dense cache path. Use "
                        "kv_layout='dense' for ep configs.")
                pps = pages_for(self.max_len, self.page_size)
                self.num_pages = (int(scfg.num_pages)
                                  if scfg.num_pages is not None
                                  else self.num_slots * pps)
                # raises with the supported-family matrix if cfg can't page
                self.cache = self.model.init_paged_cache(
                    cfg, self.num_slots, self.max_len, self.page_size,
                    self.num_pages)
                self._alloc = PageAllocator(self.num_pages, self.num_slots,
                                            pps)
        else:
            self.cache = self.model.init_cache(cfg, self.num_slots,
                                               self.max_len)

        self.preempt = bool(scfg.preempt)
        if self.preempt and self._alloc is None:
            raise ValueError(
                "preempt=True requires kv_layout='paged' with a page pool "
                "(preemption frees and re-acquires pages; this config has "
                "no pool to reclaim — ssm pages are a no-op)")
        self._prefix: PrefixCache | None = None
        self._store = scfg.prefix_store
        self._store_key = None
        if scfg.prefix_cache:
            if self._alloc is None or not self._bucketed or cfg.use_mla:
                raise ValueError(
                    f"prefix_cache=True requires kv_layout='paged' on a "
                    f"bucketed GQA/MHA dense/moe stack (family="
                    f"{cfg.family!r}, use_mla={cfg.use_mla}, moe_impl="
                    f"{cfg.moe_impl!r}): suffix prefill reuses the chunked-"
                    f"prefill machinery and page aliasing needs the pool")
            cap = (int(scfg.prefix_cache_pages)
                   if scfg.prefix_cache_pages is not None
                   else self.num_pages // 2)
            self._prefix = PrefixCache(self.page_size, cap,
                                       self._alloc.incref, self._alloc.decref)
            if self._store is not None:
                # adopt warm state from a previous engine over the same
                # params + pool geometry: the stored k/v pools replace the
                # freshly-initialized ones, the stored allocator (carrying
                # the tree's page references) is re-shaped to this engine's
                # slot geometry, and the stored tree replaces the cold one
                self._store_key = self._store.key_for(
                    cfg, params, page_size=self.page_size,
                    num_pages=self.num_pages)
                state = self._store.take(self._store_key)
                if state is not None:
                    self.cache = {**self.cache, "k": state["k"],
                                  "v": state["v"]}
                    self._alloc = state["alloc"].resize_slots(self.num_slots,
                                                              pps)
                    tree = state["tree"]
                    # the tree's incref/decref are bound to the adopted
                    # allocator — the same object we just resized
                    tree.capacity = cap
                    if len(tree) > cap:
                        tree.evict(len(tree) - cap)
                    self._prefix = tree
                    self._mirror_pages()

        self.admission_policy = None
        if scfg.admission == "prefix_aware":
            fp = (int(scfg.admission_frontier_pages)
                  if scfg.admission_frontier_pages is not None
                  else 2 * pages_for(self.max_len, self.page_size))
            self.admission_policy = PrefixAwareAdmission(
                lambda r: set(self._prefix.match(self._eff_tokens(r),
                                                 touch=False)),
                lambda: self._prefix.lru_pages(fp),
                max_skips=scfg.admission_max_skips)
        self.scheduler = FCFSScheduler(self.admission_policy)

        self._on_complete = scfg.on_complete
        self._stream: StreamOut | None = (
            StreamOut(scfg.on_complete)
            if scfg.on_complete is not None and scfg.stream_out else None)

        self.prefill_chunk = int(scfg.prefill_chunk)
        if self.prefill_chunk:
            if not self._bucketed or cfg.use_mla:
                raise ValueError(
                    f"prefill_chunk is only supported for bucketed GQA/MHA "
                    f"dense/moe serving (family={cfg.family!r}, "
                    f"use_mla={cfg.use_mla}, moe_impl={cfg.moe_impl!r}); "
                    f"use prefill_chunk=0 for this architecture")

        self.finished = jnp.ones((self.num_slots,), bool)  # idle slots are inert
        self.last_tok = jnp.full((self.num_slots,), self.pad_id, jnp.int32)
        base = scfg.rng if scfg.rng is not None else jax.random.PRNGKey(0)
        self._base_rng = base
        self.keys = jax.random.split(base, self.num_slots)

        self._slot_req: list[Request | None] = [None] * self.num_slots
        self._out: dict[int, list[int]] = {}      # uid -> emitted tokens
        self._left: dict[int, int] = {}           # uid -> remaining budget
        self._resume: dict[int, dict] = {}        # uid -> preempted state
        self._meta: dict[int, dict] = {}          # uid -> Completion fields
        self._no_preempt: set[int] = set()        # slots admitted this step
        self._job: dict | None = None             # in-flight chunked prefill
        self._closed = False
        self.clock = 0                            # admission step counter
        # decode_steps counts ACTUAL emitted decode positions (tokens the
        # host consumed, including a terminal EOS) — not chunk * decode_chunk
        # — so goodput math downstream reads real work, not dispatch grain
        self.stats = {"decode_chunks": 0, "decode_steps": 0, "prefills": 0,
                      "prefill_chunks": 0, "admitted": 0, "completed": 0,
                      "backpressure": 0, "preempted": 0, "prefix_hits": 0,
                      "prefix_pages_shared": 0, "prefill_tokens": 0}

        # per-request wall-clock latency (always-on: perf_counter stamps +
        # bounded histograms, no device syncs). Keyed by uid; stamps survive
        # preemption so TTFT/e2e span the request's real lifetime.
        self._req_ns: dict[int, dict] = {}
        # per-ENGINE histograms (stats_snapshot() reports this instance, not
        # every engine the process ever ran), registered last-engine-wins
        # into the global registry — the SwapStats idiom
        self._h_queue_wait = obs.Histogram()
        self._h_ttft = obs.Histogram()
        self._h_tpot = obs.Histogram()
        self._h_e2e = obs.Histogram()
        for nm, h in (("queue_wait_us", self._h_queue_wait),
                      ("ttft_us", self._h_ttft), ("tpot_us", self._h_tpot),
                      ("e2e_us", self._h_e2e)):
            obs.metrics.register(nm, h, subsystem="serve")
        self._g_pages = obs.metrics.gauge("page_pool_live", subsystem="serve")
        self._g_fn_cache = obs.metrics.gauge("fn_cache_size",
                                             subsystem="serve")
        obs.metrics.register("engine", lambda: dict(self.stats),
                             subsystem="serve")

    # ---------------------------------------------------- compiled closures

    def _static_key(self) -> tuple:
        return (self.cfg, self.max_len, self.num_slots, self.eos_id,
                self.pad_id, self.temperature, self.mesh, self.batch_axes,
                self.kv_layout, self.page_size,
                getattr(self, "num_pages", None),
                getattr(self, "prefill_rows", 1))

    def _chunk_fn(self):
        # the build closure must capture only statics (no `self`): the jitted
        # fn lives in the process-wide cache and would otherwise pin the
        # first engine instance's params + KV cache for the process lifetime
        key = ("chunk", self.decode_chunk) + self._static_key()
        model, cfg = self.model, self.cfg
        mesh, axes = self.mesh, self.batch_axes
        eos, pad, steps = self.eos_id, self.pad_id, self.decode_chunk
        temperature = self.temperature

        def build():
            @jax.jit
            def chunk_fn(params, cache, last_tok, finished, keys):
                def body(carry, _):
                    cache, tok, fin, keys = carry
                    logits, cache = model.decode_step(
                        params, cfg, tok[:, None], cache, mesh=mesh,
                        batch_axes=axes)
                    ks = jax.vmap(jax.random.split)(keys)
                    nxt = _sample(logits, temperature, ks[:, 1])
                    keys = ks[:, 0] if temperature > 0 else keys
                    nxt = jnp.where(fin, pad, nxt)
                    if eos is not None:
                        fin = fin | (nxt == eos)
                    return (cache, nxt, fin, keys), nxt

                carry = (cache, last_tok, finished, keys)
                (cache, tok, fin, keys), toks = jax.lax.scan(
                    body, carry, None, length=steps)
                return cache, tok, fin, keys, toks.T  # toks: [B, steps]

            return chunk_fn

        return _cached_fn(key, build)

    @staticmethod
    def _tok0_bookkeeping(eos, temperature):
        """Shared tail of every admission closure: sample the first token
        and scatter per-slot state (pad rows carry the OOB slot index and
        drop)."""
        def finish(cache, slots, logits, last_tok, finished, keys, req_keys):
            ks = jax.vmap(jax.random.split)(req_keys)
            tok0 = _sample(logits, temperature, ks[:, 1])
            fin0 = ((tok0 == eos) if eos is not None
                    else jnp.zeros(tok0.shape, bool))
            last_tok = last_tok.at[slots].set(tok0)
            finished = finished.at[slots].set(fin0)
            keys = keys.at[slots].set(ks[:, 0])
            return cache, last_tok, finished, keys, tok0
        return finish

    def _admit_fn(self, group_size: int, sig: tuple):
        """Legacy exact-length admission (signature-grouped families)."""
        key = ("admit", group_size, sig) + self._static_key()
        model, cfg, max_len = self.model, self.cfg, self.max_len
        mesh, axes, eos = self.mesh, self.batch_axes, self.eos_id
        temperature = self.temperature
        finish = self._tok0_bookkeeping(eos, temperature)

        def build():
            @jax.jit
            def admit_fn(params, cache, batch, slots, last_tok, finished,
                         keys, req_keys):
                logits, new_cache = model.prefill(params, cfg, batch, max_len,
                                                  mesh=mesh, batch_axes=axes)
                cache = model.insert_slots(cache, new_cache, slots)
                return finish(cache, slots, logits, last_tok, finished, keys,
                              req_keys)

            return admit_fn

        return _cached_fn(key, build)

    def _admit_bucket_fn(self, bucket: int):
        """Bucketed single-shot admission: one compile key per bucket (the
        group is split/padded to fixed [prefill_rows, bucket] batches)."""
        key = ("admitb", bucket) + self._static_key()
        model, cfg, max_len = self.model, self.cfg, self.max_len
        mesh, axes, eos = self.mesh, self.batch_axes, self.eos_id
        temperature = self.temperature
        paged = self._alloc is not None
        # paged prefill builds its scratch at bucket length (the pool insert
        # handles any source length); dense must match the cache row length
        prefill_len = bucket if paged else max_len
        finish = self._tok0_bookkeeping(eos, temperature)

        def build():
            @jax.jit
            def admit_fn(params, cache, batch, slots, lengths, last_tok,
                         finished, keys, req_keys):
                logits, new_cache = model.prefill(
                    params, cfg, batch, prefill_len, mesh=mesh,
                    batch_axes=axes, lengths=lengths)
                if paged:
                    cache = model.insert_slots_paged(cache, new_cache, slots,
                                                     lengths)
                else:
                    cache = model.insert_slots(cache, new_cache, slots)
                return finish(cache, slots, logits, last_tok, finished, keys,
                              req_keys)

            return admit_fn

        return _cached_fn(key, build)

    def _admit_prefix_fn(self, scratch_len: int, chunk: int, rows: int):
        """Prefix-cache admission for a same-start group of ``rows``
        requests in ONE call: per row, COW-copy the boundary page
        (``cow_dst == num_pages`` drops the copy), gather the aliased
        prefix [0, start) from the page pools into a dense scratch, prefill
        only the uncached suffix chunk (traced ``start``, shared by the
        whole group — one compile per (scratch_len, chunk, rows) SHAPE, all
        static, not per offset), scatter positions [start, length) back
        through each slot's table (shared pages below ``start`` are never
        written), and sample token 0. Pad rows carry slot=num_slots and
        cow indices=num_pages, so every one of their scatters drops. A
        prefix MISS is the same closure with start=0 over a zero scratch."""
        key = ("padmit", scratch_len, chunk, rows) + self._static_key()
        model, cfg = self.model, self.cfg
        mesh, axes, eos = self.mesh, self.batch_axes, self.eos_id
        temperature = self.temperature
        num_slots, num_pages = self.num_slots, self.num_pages
        ps, nv = self.page_size, self.cfg.padded_vocab_size
        finish = self._tok0_bookkeeping(eos, temperature)

        def build():
            @jax.jit
            def admit_fn(params, cache, tokens, slots, start, lengths,
                         cow_src, cow_dst, last_tok, finished, keys,
                         req_keys):
                # copy-on-write BEFORE the gather and the suffix scatter:
                # the duplicated page carries the shared page's filled
                # positions, then receives the recomputed final token's KV
                src = jnp.minimum(cow_src, num_pages - 1)
                k_pool = cache["k"].at[:, cow_dst].set(cache["k"][:, src])
                v_pool = cache["v"].at[:, cow_dst].set(cache["v"][:, src])
                cache = {**cache, "k": k_pool, "v": v_pool}
                maxp = cache["pages"].shape[1]
                tbl = cache["pages"][jnp.minimum(slots, num_slots - 1)]
                t = jnp.arange(scratch_len)
                page = jnp.clip(tbl[:, jnp.minimum(t // ps, maxp - 1)],
                                0, num_pages - 1)                # [1, SL]
                off = jnp.broadcast_to(t % ps, page.shape)
                m = (t < start)[None, None, :, None, None]
                scratch = {"k": jnp.where(m, k_pool[:, page, off], 0),
                           "v": jnp.where(m, v_pool[:, page, off], 0)}
                last0 = jnp.zeros((tokens.shape[0], nv), jnp.float32)
                logits, scratch = model.prefill_chunk(
                    params, cfg, tokens, scratch, start, lengths, last0,
                    mesh=mesh, batch_axes=axes)
                cache = model.insert_slots_paged(cache, scratch, slots,
                                                 lengths, starts=start)
                return finish(cache, slots, logits, last_tok, finished,
                              keys, req_keys)

            return admit_fn

        return _cached_fn(key, build)

    def _prefill_chunk_fn(self, bucket: int, chunk: int):
        key = ("pchunk", bucket, chunk) + self._static_key()
        model, cfg = self.model, self.cfg
        mesh, axes = self.mesh, self.batch_axes

        def build():
            @jax.jit
            def chunk_prefill(params, tokens, scratch, start, lengths, last):
                return model.prefill_chunk(params, cfg, tokens, scratch,
                                           start, lengths, last, mesh=mesh,
                                           batch_axes=axes)

            return chunk_prefill

        return _cached_fn(key, build)

    def _prefill_final_fn(self, bucket: int):
        """Insert a finished chunked-prefill scratch cache into the engine
        cache and sample the first token."""
        key = ("pfinal", bucket) + self._static_key()
        model, cfg, max_len = self.model, self.cfg, self.max_len
        eos, temperature = self.eos_id, self.temperature
        paged = self._alloc is not None
        finish = self._tok0_bookkeeping(eos, temperature)

        def build():
            @jax.jit
            def final_fn(params, cache, scratch, slots, lengths, last_logits,
                         last_tok, finished, keys, req_keys):
                scratch2 = {**scratch, "pos": lengths}
                if paged:
                    cache = model.insert_slots_paged(cache, scratch2, slots,
                                                     lengths)
                else:
                    if bucket < max_len:
                        pad = [(0, 0), (0, 0), (0, max_len - bucket),
                               (0, 0), (0, 0)]
                        scratch2 = {**scratch2,
                                    "k": jnp.pad(scratch2["k"], pad),
                                    "v": jnp.pad(scratch2["v"], pad)}
                    cache = model.insert_slots(cache, scratch2, slots)
                return finish(cache, slots, last_logits, last_tok, finished,
                              keys, req_keys)

            return final_fn

        return _cached_fn(key, build)

    # ----------------------------------------------------------- lifecycle

    def submit(self, req: Request) -> None:
        if self._closed:
            raise RuntimeError("ServeEngine is closed")
        if req.prompt_len == 0:
            raise ValueError(
                f"request {req.uid}: empty prompt — the engine needs at "
                f"least one prompt token to prefill. Prepend a BOS token "
                f"for unconditional generation.")
        prefix = 0
        if self.cfg.family == "vlm" and "patch_embeds" in req.extras:
            prefix = int(np.asarray(req.extras["patch_embeds"]).shape[0])
        need = prefix + req.prompt_len + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {req.uid} needs {need} cache positions "
                f"(prefix {prefix} + prompt {req.prompt_len} + "
                f"{req.max_new_tokens} new) but max_len={self.max_len}")
        if self._alloc is not None:
            np_need = pages_for(need, self.page_size)
            if np_need > self._alloc.num_pages:
                raise PoolExhausted(
                    f"request {req.uid} needs {np_need} pages "
                    f"({need} positions / page_size {self.page_size}) but "
                    f"the pool has {self._alloc.num_pages}; grow num_pages "
                    f"— waiting cannot free enough")
        # first submit stamps the latency clock; a preempted request
        # re-entering through push_front keeps its original stamps
        self._req_ns.setdefault(req.uid, {"submit": time.perf_counter_ns()})
        self.scheduler.submit(req)

    def _free_slots(self) -> list[int]:
        job = set(self._job["slot_ids"]) if self._job else ()
        return [i for i, r in enumerate(self._slot_req)
                if r is None and i not in job]

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    def _bucket_for(self, n: int) -> int:
        for b in self.prefill_buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds the largest bucket "
                         f"{self.prefill_buckets[-1]} (max_len)")

    # Preempted requests requeue carrying their already-generated tokens:
    # the EFFECTIVE prompt at re-admission is prompt + emitted-so-far, and
    # the remaining budget is what was left at preemption. Every admission
    # site (grouping, page reservation, batching, prefill) goes through
    # these helpers so fresh and resumed requests share one code path.

    def _eff_tokens(self, req: Request) -> np.ndarray:
        res = self._resume.get(req.uid)
        return res["tokens"] if res is not None else req.tokens

    def _eff_len(self, req: Request) -> int:
        return int(self._eff_tokens(req).shape[0])

    def _budget_left(self, req: Request) -> int:
        res = self._resume.get(req.uid)
        return res["left"] if res is not None else req.max_new_tokens

    def _group_key(self, req: Request) -> tuple:
        ex = tuple(sorted((k, np.asarray(v).shape)
                          for k, v in req.extras.items()))
        return (self._bucket_for(self._eff_len(req)), ex)

    def _mirror_pages(self) -> None:
        self.cache = {**self.cache,
                      "pages": jnp.asarray(self._alloc.table)}

    def kv_cache_bytes(self) -> int:
        """Device bytes of the persistent serve cache (all leaves)."""
        return int(sum(x.nbytes for x in jax.tree.leaves(self.cache)))

    def page_pool_stats(self) -> dict | None:
        """Allocator stats for the paged layout (None for dense/no-op)."""
        return self._alloc.stats() if self._alloc is not None else None

    def stats_snapshot(self) -> dict:
        """One nested dict consolidating every serving stat surface (the
        launcher/examples print this instead of separate stat blocks; keys
        documented in the module docstring):

        - ``engine``: the per-engine counter dict (``self.stats``)
        - ``latency_us``: queue-wait / TTFT / time-per-output-token / e2e
          histogram summaries (count, mean, p50/p95/p99)
        - ``pages``: ``PageAllocator.stats()`` (None for dense layout)
        - ``scheduler``: queue depth + admission-policy counters
        - ``prefix_cache``: radix-tree occupancy (None when disabled)
        - ``stream_out``: background detokenize queue depth (None when off)
        - ``fn_cache``: the process-wide compiled-fn cache counters
        """
        return {
            "engine": dict(self.stats),
            "latency_us": {"queue_wait": self._h_queue_wait.summary(),
                           "ttft": self._h_ttft.summary(),
                           "tpot": self._h_tpot.summary(),
                           "e2e": self._h_e2e.summary()},
            "pages": self.page_pool_stats(),
            "scheduler": {
                "pending": int(self.scheduler.pending),
                "admission": (dict(self.admission_policy.stats)
                              if self.admission_policy is not None else None),
            },
            "prefix_cache": ({"pages": len(self._prefix),
                              "capacity_pages": self._prefix.capacity}
                             if self._prefix is not None else None),
            "stream_out": ({"pending": self._stream.pending}
                           if self._stream is not None else None),
            "fn_cache": fn_cache_info(),
        }

    def _insert_prefix_pages(self, slot: int, tokens, covered: int) -> None:
        """Insert ``slot``'s pages for the fully-written full-page prefix of
        ``tokens`` (``covered`` positions hold valid KV) into the radix
        tree. Called BEFORE the slot's free so the pages are still live —
        the tree's incref keeps them across the decref."""
        nfull = min(int(covered), len(tokens)) // self.page_size
        if nfull:
            pages = [int(p) for p in self._alloc.table[slot, :nfull]]
            self._prefix.insert(tokens[:nfull * self.page_size], pages)

    def _complete(self, slot: int, completed: list) -> None:
        req = self._slot_req[slot]
        self._slot_req[slot] = None
        self.stats["completed"] += 1
        toks = np.asarray(self._out.pop(req.uid), np.int32)
        self._left.pop(req.uid, None)
        meta = self._meta.pop(req.uid, {})
        eos_hit = (self.eos_id is not None and toks.size
                   and int(toks[-1]) == self.eos_id)
        comp = Completion(
            uid=req.uid, tokens=toks,
            finish_reason="eos" if eos_hit else "length",
            arrival=float(req.arrival),
            first_token_step=int(meta.get("first_step", self.clock)),
            done_step=int(self.clock),
            prefix_pages=int(meta.get("prefix_pages", 0)))
        completed.append(comp)
        rt = self._req_ns.pop(req.uid, None)
        if rt is not None:
            now_ns = time.perf_counter_ns()
            self._h_e2e.record((now_ns - rt["submit"]) / 1e3)
            first = rt.get("first", now_ns)
            self._h_tpot.record((now_ns - first) / 1e3
                                / max(1, len(toks) - 1))
            tr = obs.tracer()
            if tr is not None:
                # retroactive per-request spans, one timeline lane per uid:
                # e2e (submit -> done) with the ttft head (submit -> first)
                track = f"request {req.uid}"
                tr.complete("e2e", rt["submit"], now_ns, track=track,
                            args={"uid": req.uid, "tokens": len(toks),
                                  "finish": comp.finish_reason})
                tr.complete("ttft", rt["submit"], first, track=track,
                            args={"uid": req.uid})
        if self._alloc is not None:
            if self._prefix is not None:
                self._insert_prefix_pages(slot, req.tokens, req.prompt_len)
            self._alloc.free(slot)
            self._mirror_pages()
        if self._on_complete is not None:
            if self._stream is not None:
                self._stream.put(comp)     # worker detokenizes
            else:
                self._on_complete(comp)    # stream_out=False: inline

    # ----------------------------------------------------------- admission

    def _post_admit(self, group, slot_ids, tok0, completed) -> None:
        tok0 = np.asarray(tok0)[:len(group)]
        self.stats["admitted"] += len(group)
        now_ns = time.perf_counter_ns()
        for req, slot, t in zip(group, slot_ids, tok0):
            self._slot_req[slot] = req
            self._no_preempt.add(slot)  # just admitted: no KV written yet
            # first admission stamps first_token_step; a preempted request
            # keeps its original (its first token really was sampled then)
            self._meta.setdefault(req.uid, {"first_step": self.clock,
                                            "prefix_pages": 0})
            # first admission also samples the first token, so it stamps
            # both queue-wait and TTFT (re-admission keeps the originals)
            rt = self._req_ns.setdefault(req.uid, {"submit": now_ns})
            if "first" not in rt:
                rt["first"] = now_ns
                admit = rt.setdefault("admit", now_ns)
                self._h_queue_wait.record((admit - rt["submit"]) / 1e3)
                self._h_ttft.record((now_ns - rt["submit"]) / 1e3)
            res = self._resume.pop(req.uid, None)
            if res is not None:
                self._out[req.uid] = res["emitted"] + [int(t)]
                self._left[req.uid] = res["left"] - 1
            else:
                self._out[req.uid] = [int(t)]
                self._left[req.uid] = req.max_new_tokens - 1
            if ((self.eos_id is not None and int(t) == self.eos_id)
                    or self._left[req.uid] == 0):
                self._complete(slot, completed)

    def _admit(self, group: list[Request], completed: list) -> None:
        """Legacy exact-length admission (signature-grouped families): pad
        the group to a power of two — duplicate rows, scattered to the
        out-of-range slot index so insert_slots drops them — one prefill
        compile per (pow2 size, prompt signature). EP MoE is exempt: its
        capacity buckets depend on the batch's token count, so pad rows
        would perturb the real rows' routing."""
        free = self._free_slots()
        g = len(group)
        assert g <= len(free)
        slot_ids = free[:g]
        gp = _admit_pad_size(g, self.cfg.moe_impl)
        tokens = np.stack([r.tokens for r in group]).astype(np.int32)
        extras = {k: np.stack([np.asarray(r.extras[k]) for r in group])
                  for k in group[0].extras}
        if gp > g:
            rep = [(0, gp - g)] + [(0, 0)] * (tokens.ndim - 1)
            tokens = np.pad(tokens, rep, mode="edge")
            extras = {k: np.pad(v, [(0, gp - g)] + [(0, 0)] * (v.ndim - 1),
                                mode="edge") for k, v in extras.items()}
        slots = np.asarray(slot_ids + [self.num_slots] * (gp - g), np.int32)
        batch = {"tokens": tokens, **extras}
        req_keys = self._req_keys(group, gp)

        fn = self._admit_fn(gp, group[0].signature())
        self.cache, self.last_tok, self.finished, self.keys, tok0 = fn(
            self.params, self.cache, batch, slots, self.last_tok,
            self.finished, self.keys, req_keys)
        self.stats["prefills"] += 1
        self.stats["prefill_tokens"] += sum(r.prompt_len for r in group)
        self._post_admit(group, slot_ids, tok0, completed)

    def _req_keys(self, group, gp):
        """Per-request sampling keys. A resumed request continues from the
        key saved at preemption: tok0 bookkeeping and the decode-chunk body
        split identically (sample from split[1], carry split[0]), so the
        sampled stream is bit-exact vs the never-preempted run."""
        if self.temperature > 0:
            ks = []
            for r in group:
                res = self._resume.get(r.uid)
                ks.append(jnp.asarray(res["key"]) if res is not None
                          else jax.random.fold_in(self._base_rng, r.uid))
            return jnp.stack(ks + [self._base_rng] * (gp - len(group)))
        return jnp.zeros((gp,) + self.keys.shape[1:], self.keys.dtype)

    def _bucket_batch(self, group, slot_ids, rows):
        """Pad a bucketed admission group to ``rows`` rows: [rows, bucket]
        tokens, [rows] lengths/slots (pad rows -> OOB slot, dropped)."""
        ns = self.num_slots
        bucket = self._bucket_for(max(self._eff_len(r) for r in group))
        g = len(group)
        tokens = np.full((rows, bucket), self.pad_id, np.int32)
        lengths = np.zeros((rows,), np.int32)
        for i, r in enumerate(group):
            toks = self._eff_tokens(r)
            tokens[i, :len(toks)] = toks
            lengths[i] = len(toks)
        slots = np.asarray(list(slot_ids) + [ns] * (rows - g), np.int32)
        return bucket, tokens, lengths, slots

    # ------------------------------------------------- preempt-and-requeue

    def _preempt_one(self, head_left: int | None = None) -> bool:
        """Preempt the resident with the most remaining budget: free its
        private pages (shared prefix pages just decref), save its resume
        state, and requeue it at the scheduler head with its original
        arrival. Slots admitted this step are exempt — their token-0 KV is
        not written until the next decode chunk, so their pages hold an
        incomplete prefix (and preempting a request to admit another would
        thrash anyway).

        Damping: when ``head_left`` (the remaining budget of the request
        being admitted) is given, only residents with STRICTLY more budget
        left are preemptible. Preemption then only ever moves pages from
        longer-tailed work to shorter work, so a requeued victim can never
        preempt its way straight back in (the ping-pong livelock of an
        unconditional policy) — remaining work strictly decreases along any
        preemption chain."""
        best = None
        for slot, req in enumerate(self._slot_req):
            if req is None or slot in self._no_preempt:
                continue
            left = self._left[req.uid]
            if head_left is not None and left <= head_left:
                continue
            if best is None or left > best[0]:
                best = (left, slot)
        if best is None:
            return False
        _, slot = best
        req = self._slot_req[slot]
        emitted = self._out.pop(req.uid)
        left = self._left.pop(req.uid)
        ctx = np.concatenate([req.tokens,
                              np.asarray(emitted, np.int32)])
        self._resume[req.uid] = {
            "tokens": ctx, "emitted": emitted, "left": left,
            # sampled decoding: the key stream continues from here
            "key": (np.asarray(self.keys[slot])
                    if self.temperature > 0 else None)}
        if self._prefix is not None:
            # positions [0, len(ctx)-1) hold valid KV (the newest emitted
            # token was sampled but not yet fed back/written) — its full
            # pages make the re-admission prefix-accelerated
            self._insert_prefix_pages(slot, ctx, len(ctx) - 1)
        self._slot_req[slot] = None
        self._alloc.free(slot)
        self._mirror_pages()
        # inert on device: no more samples; sentinel table row drops writes
        self.finished = self.finished.at[slot].set(True)
        self.scheduler.push_front([req])
        self.stats["preempted"] += 1
        return True

    def _reclaim(self, need: int, head_left: int | None = None) -> bool:
        """Make room for an admission that needs ``need`` fresh pages:
        first evict LRU prefix-cache pages (cheapest — cached KV is
        recomputable), then preempt one resident with more remaining work
        than the admittee (see ``_preempt_one``). Returns True if anything
        was reclaimed (the caller loops until the request fits or this
        gives up)."""
        freed = False
        if self._prefix is not None:
            short = need - self._alloc.free_pages
            if short > 0 and self._prefix.evict(short):
                freed = True
        if not self._alloc.can_allocate(need) and self.preempt:
            freed = self._preempt_one(head_left) or freed
        return freed

    def _reserve_pages(self, group, free) -> list[Request]:
        """Admission backpressure: allocate pages FCFS, reclaiming (prefix
        eviction, then preemption) when a request doesn't fit; the first
        request that still doesn't fit (and everything behind it) goes back
        to the queue head. Returns the admissible prefix."""
        if self._alloc is None:
            return group
        fit = 0
        for r, slot in zip(group, free):
            need = pages_for(self._eff_len(r) + self._budget_left(r),
                             self.page_size)
            while not self._alloc.can_allocate(need):
                if not self._reclaim(need, self._budget_left(r)):
                    break
            if not self._alloc.can_allocate(need):
                break
            self._alloc.allocate(slot, need)
            fit += 1
        if fit < len(group):
            self.scheduler.push_front(group[fit:])
            self.stats["backpressure"] += len(group) - fit
        if fit:
            self._mirror_pages()
        return group[:fit]

    # ------------------------------------------------ prefix-hit admission

    def _prefix_match_start(self, req: Request, touch: bool = True):
        """The request's radix match and its page-aligned suffix start.
        COW boundary: a match is page-granular, so the start is
        page-aligned UNLESS the entire prompt is cached — then the final
        token's logits must be recomputed (start = len-1, mid-page) and
        the last matched page is duplicated first so the shared copy is
        never written."""
        eff = self._eff_tokens(req)
        length = len(eff)
        matched = self._prefix.match(eff, touch=touch)
        if matched and len(matched) * self.page_size >= length:
            return eff, length, matched, matched[:-1], int(matched[-1]), \
                length - 1
        return eff, length, matched, matched, None, \
            len(matched) * self.page_size

    def _prefix_group_key(self, req: Request) -> tuple:
        """Admission group key for the prefix path: requests sharing a
        suffix ``start`` and a prompt-length bucket prefill as ONE
        [rows, chunk] call. A pure probe — grouping must not touch the LRU
        stamps the prefix-aware policy schedules around."""
        *_, start = self._prefix_match_start(req, touch=False)
        ex = tuple(sorted((k, np.asarray(v).shape)
                          for k, v in req.extras.items()))
        return (start, self._bucket_for(self._eff_len(req)), ex)

    def _admit_prefix_group(self, group, free, completed) -> bool:
        """Admit a same-start group through the radix prefix cache in one
        prefill call: alias each request's cached prefix into its slot's
        table and prefill only the uncached suffixes as a [rows, chunk]
        batch (``rows`` = prefill_rows, pad rows drop on device). The
        matches are re-taken (touched) here; nothing mutates the tree
        between the scheduler's group-key probe and this point, so the
        group's shared ``start`` still holds. Returns False if any member
        hit backpressure (it and everything behind it are back at the
        queue head — the caller stops admitting)."""
        ps = self.page_size
        infos = [self._prefix_match_start(r) for r in group]
        start = infos[0][-1]
        # pin every match before reclaim can evict it out from under us
        # (eviction of a tree-only page would free it for reuse); pinned
        # pages survive prefix eviction with their KV intact, so aliasing
        # them below stays valid even if reclaim drops them from the tree
        pinned = [p for (_, _, matched, *_) in infos for p in matched]
        for p in pinned:
            self._alloc.incref(p)
        admitted, slots = [], []
        try:
            for (eff, length, matched, aliased, cow_src, st), req, slot in \
                    zip(infos, group, free):
                assert st == start, "scheduler grouped mixed starts"
                budget = self._budget_left(req)
                need = pages_for(length + budget, ps)
                n_fresh = need - len(aliased)
                while not self._alloc.can_allocate(n_fresh):
                    if not self._reclaim(n_fresh, budget):
                        break
                if not self._alloc.can_allocate(n_fresh):
                    back = group[len(admitted):]
                    self.scheduler.push_front(back)
                    self.stats["backpressure"] += len(back)
                    break
                self._alloc.alias(slot, aliased, n_fresh)
                admitted.append((req, eff, length, matched, aliased,
                                 cow_src))
                slots.append(slot)
        finally:
            for p in pinned:
                self._alloc.decref(p)
        if not admitted:
            return False
        self._mirror_pages()

        rows = self.prefill_rows
        chunk = _next_pow2(max(length - start
                               for _, _, length, *_ in admitted))
        scratch_len = _next_pow2(max(max(length for _, _, length, *_
                                         in admitted), start + chunk))
        tokens = np.full((rows, chunk), self.pad_id, np.int32)
        lengths = np.zeros((rows,), np.int32)
        slot_arr = np.full((rows,), self.num_slots, np.int32)
        cow_src_arr = np.full((rows,), self.num_pages, np.int32)
        cow_dst_arr = np.full((rows,), self.num_pages, np.int32)
        suffix_total = 0
        for i, ((req, eff, length, matched, aliased, cow_src),
                slot) in enumerate(zip(admitted, slots)):
            suffix = length - start
            tokens[i, :suffix] = eff[start:]
            lengths[i] = length
            slot_arr[i] = slot
            suffix_total += suffix
            if cow_src is not None:
                cow_src_arr[i] = cow_src
                cow_dst_arr[i] = int(self._alloc.table[slot, len(aliased)])
            if matched:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_pages_shared"] += len(aliased)
                meta = self._meta.setdefault(
                    req.uid, {"first_step": self.clock, "prefix_pages": 0})
                meta["prefix_pages"] += len(aliased)

        reqs = [a[0] for a in admitted]
        fn = self._admit_prefix_fn(scratch_len, chunk, rows)
        self.cache, self.last_tok, self.finished, self.keys, tok0 = fn(
            self.params, self.cache, tokens, slot_arr, np.int32(start),
            lengths, cow_src_arr, cow_dst_arr, self.last_tok, self.finished,
            self.keys, self._req_keys(reqs, rows))
        # suffix-only accounting: the aliased prefixes cost zero prefill
        # tokens, and the whole group is ONE prefill call
        self.stats["prefills"] += 1
        self.stats["prefill_tokens"] += suffix_total
        self._post_admit(reqs, slots, tok0, completed)
        return len(admitted) == len(group)

    def _admit_bucketed(self, group, slot_ids, completed) -> None:
        """Prefill the group in fixed [prefill_rows, bucket] batches: the
        row count is static per bucket, so every group size reuses the one
        compiled closure, and a lone late arrival doesn't pay num_slots
        rows of pad-row FLOPs."""
        rows = self.prefill_rows
        # the whole group shares one bucket (the scheduler groups by it)
        bucket = self._bucket_for(max(r.prompt_len for r in group))
        fn = self._admit_bucket_fn(bucket)
        for i in range(0, len(group), rows):
            sub, sids = group[i:i + rows], slot_ids[i:i + rows]
            _, tokens, lengths, slots = self._bucket_batch(sub, sids, rows)
            req_keys = self._req_keys(sub, rows)
            self.cache, self.last_tok, self.finished, self.keys, tok0 = fn(
                self.params, self.cache, {"tokens": tokens}, slots, lengths,
                self.last_tok, self.finished, self.keys, req_keys)
            self.stats["prefills"] += 1
            self.stats["prefill_tokens"] += int(lengths.sum())
            self._post_admit(sub, sids, tok0, completed)

    def _start_job(self, group, slot_ids) -> None:
        # chunked prefill: admission starts now, the first token lands when
        # the job finalizes steps later — stamp queue-wait's endpoint here
        now_ns = time.perf_counter_ns()
        for r in group:
            self._req_ns.setdefault(r.uid,
                                    {"submit": now_ns}).setdefault("admit",
                                                                   now_ns)
        bucket, tokens, lengths, slots = self._bucket_batch(
            group, slot_ids, self.num_slots)
        scratch = self.model.init_cache(self.cfg, self.num_slots, bucket)
        scratch = {"k": scratch["k"], "v": scratch["v"]}
        self._job = {
            "group": group, "slot_ids": slot_ids, "slots": slots,
            "lengths": lengths, "tokens": tokens, "bucket": bucket,
            "scratch": scratch, "start": 0,
            "last": jnp.zeros((self.num_slots, self.cfg.padded_vocab_size),
                              jnp.float32),
        }
        self.stats["prefills"] += 1
        self.stats["prefill_tokens"] += int(lengths.sum())

    def _job_step(self, completed) -> None:
        """Advance the in-flight chunked prefill by one chunk; finalize
        (insert + first-token sample) when the bucket is fully prefilled."""
        j = self._job
        c = min(self.prefill_chunk, j["bucket"] - j["start"])
        fn = self._prefill_chunk_fn(j["bucket"], c)
        chunk = j["tokens"][:, j["start"]:j["start"] + c]
        j["last"], j["scratch"] = fn(
            self.params, chunk, j["scratch"], np.int32(j["start"]),
            j["lengths"], j["last"])
        j["start"] += c
        self.stats["prefill_chunks"] += 1
        if j["start"] < j["bucket"]:
            return
        self._job = None
        req_keys = self._req_keys(j["group"], self.num_slots)
        fn = self._prefill_final_fn(j["bucket"])
        self.cache, self.last_tok, self.finished, self.keys, tok0 = fn(
            self.params, self.cache, j["scratch"], j["slots"], j["lengths"],
            j["last"], self.last_tok, self.finished, self.keys, req_keys)
        self._post_admit(j["group"], j["slot_ids"], tok0, completed)

    def _admission(self, completed) -> None:
        """Admit runnable groups into free slots until slots/pages/queue run
        out. At most one chunked-prefill job is in flight; while one is
        active its slots are reserved and admission pauses. With the prefix
        cache enabled, groups are keyed by (suffix start, bucket) so a
        same-start group prefills as ONE [prefill_rows, chunk] call through
        the suffix-prefill path."""
        while self._job is None:
            free = self._free_slots()
            if not free:
                return
            if self._prefix is not None:
                key = self._prefix_group_key
                want = min(len(free), self.prefill_rows)
            else:
                key = self._group_key if self._bucketed else None
                want = len(free)
            group = self.scheduler.next_group(want, now=self.clock, key=key)
            if not group:
                return
            if not self._bucketed:
                self._admit(group, completed)
                continue
            if self._prefix is not None:
                if not self._admit_prefix_group(group, free, completed):
                    return  # pool pressure even after reclaim
                continue
            admitted = self._reserve_pages(group, free)
            if not admitted:
                return  # pool pressure: wait for residents to free pages
            slot_ids = free[:len(admitted)]
            bucket = self._bucket_for(max(self._eff_len(r)
                                          for r in admitted))
            if self.prefill_chunk and bucket > self.prefill_chunk:
                self._start_job(admitted, slot_ids)
            else:
                self._admit_bucketed(admitted, slot_ids, completed)
            if len(admitted) < len(group):
                return  # backpressured tail is back at the queue head

    # ---------------------------------------------------------------- step

    def step(self) -> list[Completion]:
        """One engine step: advance the chunked-prefill job (if any) by one
        chunk, admit every runnable group into free slots, then run one
        jitted decode chunk (a single host sync). Returns a ``Completion``
        per request finished this step."""
        if self._closed:
            raise RuntimeError("ServeEngine is closed")
        completed: list[Completion] = []
        self._no_preempt.clear()  # last step's admits have their KV by now
        if self._job is not None:
            with obs.span("prefill_chunk"):
                self._job_step(completed)
        with obs.span("admission"):
            self._admission(completed)

        if self.num_active:
            with obs.span("decode_chunk"):
                fn = self._chunk_fn()
                self.cache, self.last_tok, self.finished, self.keys, toks = \
                    fn(self.params, self.cache, self.last_tok, self.finished,
                       self.keys)
                self.stats["decode_chunks"] += 1
                toks = np.asarray(toks)  # [num_slots, chunk] — the host sync
            emitted = 0
            for slot in range(self.num_slots):
                req = self._slot_req[slot]
                if req is None:
                    continue
                for t in toks[slot]:
                    self._out[req.uid].append(int(t))
                    self._left[req.uid] -= 1
                    emitted += 1
                    if ((self.eos_id is not None and int(t) == self.eos_id)
                            or self._left[req.uid] == 0):
                        self._complete(slot, completed)
                        break
            # actual emitted positions, not chunk-granular dispatch width:
            # slots that finish mid-chunk (or decode pad into idle slots)
            # don't inflate the count
            self.stats["decode_steps"] += emitted
        if self._alloc is not None:
            self._g_pages.set(self._alloc.stats()["live_pages"])
        self._g_fn_cache.set(len(_FN_CACHE))
        self.clock += 1
        return completed

    def run(self, requests=()) -> RunResult:
        """Submit ``requests`` and drive steps until queue and slots drain.
        Returns a ``RunResult``: a {uid: generated tokens (ends at EOS if
        hit)} mapping whose ``.completions`` carries the full per-request
        ``Completion`` records."""
        for r in requests:
            self.submit(r)
        comps: dict[int, Completion] = {}
        while self.scheduler.pending or self.num_active or self._job:
            for c in self.step():
                comps[c.uid] = c
        if self._stream is not None:
            self._stream.drain()  # surface stream-out callback errors here
        return RunResult(comps)

    def generate(self, batch: dict, *, max_new_tokens: int) -> np.ndarray:
        """Static-batch convenience: decode ``batch`` (all prompts the same
        length, batch size <= num_slots) and return [B, max_new_tokens] with
        ``pad_id`` after EOS — the legacy ``generate`` output contract. The
        returned array's ``.completions`` holds the ``Completion`` records
        (uid == row index)."""
        b = batch["tokens"].shape[0]
        if b > self.num_slots:
            raise ValueError(f"batch {b} > num_slots {self.num_slots}")
        reqs = [Request(uid=i, tokens=np.asarray(batch["tokens"][i]),
                        max_new_tokens=max_new_tokens,
                        extras={k: np.asarray(batch[k][i]) for k in batch
                                if k != "tokens"})
                for i in range(b)]
        res = self.run(reqs)
        out = np.full((b, max_new_tokens), self.pad_id, np.int32)
        for i in range(b):
            toks = res[i][:max_new_tokens]
            out[i, :len(toks)] = toks
        return TokenBatch.wrap(out, res.completions)

    def close(self) -> None:
        """Tear down the engine. Idempotent; the engine must be drained
        (no residents, no queue, no in-flight prefill job). With a
        ``PrefixStore`` configured, the radix tree, its page references,
        and the k/v page pools are handed to the store under the existing
        refcount contract — every slot is free at this point, so the
        tree's one-ref-per-node references are exactly the pool's live
        pages — and the next engine over the same params + geometry adopts
        them warm. ``step``/``submit`` raise afterwards."""
        if self._closed:
            return
        if self.num_active or self.scheduler.pending or self._job:
            raise RuntimeError(
                f"close() on a busy engine: {self.num_active} residents, "
                f"{self.scheduler.pending} queued, job={'yes' if self._job else 'no'} "
                f"— drain with run()/step() first")
        if self._stream is not None:
            self._stream.close()
            self._stream = None
        if self._store is not None and self._prefix is not None:
            self._store.put(self._store_key, self.params, {
                "k": self.cache["k"], "v": self.cache["v"],
                "alloc": self._alloc, "tree": self._prefix})
        self._closed = True


# ------------------------------------------------------------- public API


def generate(params, cfg: ModelConfig, batch: dict, *, max_new_tokens: int,
             max_len: int | None = None, temperature: float = 0.0,
             rng: jax.Array | None = None, mesh=None, batch_axes=("data",),
             eos_id: int | None = None, num_slots: int | None = None,
             decode_chunk: int = 8):
    """Greedy (temperature=0, the paper's eval protocol) or sampled decoding.
    batch["tokens"]: [B, S_prompt]. Returns np.ndarray [B, max_new_tokens].

    Compat wrapper over ``ServeEngine`` — token-for-token identical to the
    pre-engine loop (``serve/_oracle.py``'s ``generate_legacy``). Sampled
    decoding keeps the legacy path so the historical rng stream (one
    batch-wide categorical per step) is preserved exactly."""
    if temperature > 0:
        from repro.serve._oracle import generate_legacy  # lazy: avoids cycle
        return generate_legacy(params, cfg, batch,
                               max_new_tokens=max_new_tokens, max_len=max_len,
                               temperature=temperature, rng=rng, mesh=mesh,
                               batch_axes=batch_axes, eos_id=eos_id)
    b, s = batch["tokens"].shape
    max_len = max_len or (s + _prompt_prefix(cfg, batch) + max_new_tokens)
    engine = ServeEngine(cfg, params, ServeConfig(
        max_len=max_len, num_slots=num_slots or b, eos_id=eos_id,
        decode_chunk=decode_chunk, mesh=mesh, batch_axes=batch_axes))
    return engine.generate(batch, max_new_tokens=max_new_tokens)
