"""Shared KV page pool: host-side page-table allocator for the serve engine.

The paged KV layout replaces each slot's dense ``max_len`` cache row with a
pool of fixed-size pages (``[num_pages, page_size, heads, dim]`` K/V arrays
per layer, see ``models/lm.init_paged_cache``) plus a per-slot page table
mapping virtual position ``s`` to pool page ``table[slot, s // page_size]``.
Serve cache memory then scales with *live tokens* (pages actually backing
admitted requests) instead of ``num_slots * max_len``.

This module is the host side: ``PageAllocator`` owns the free list and the
``[num_slots, pages_per_slot]`` table (numpy; mirrored to the device cache
by the engine after every allocate/free). Unallocated table entries hold the
``num_pages`` sentinel — device code drops writes through them (OOB scatter)
and clamps reads (the gathered rows are masked by ``valid_len`` anyway), so
a freed slot that keeps decoding (finished slots ride along in the decode
chunk) can never corrupt a page that was handed to a new request.

Pages are REFCOUNTED so the radix prefix cache (serve/prefix_cache.py) can
alias one filled page into many slots: ``allocate``/``alias`` set fresh
pages to refcount 1, ``alias``/``incref`` bump shared ones, and ``free``/
``decref`` release — a page returns to the free list only when its refcount
reaches 0. Aliased pages are read-only by contract: the engine never
scatters through a table entry below a slot's private ``start`` offset
(lm.insert_slots_paged ``starts=``), and the first partially-filled page is
copied-on-write before any suffix write.

Exhaustion is not an error at admission time: the engine admits as many
requests as the pool can back and leaves the rest queued (admission
backpressure) — pages free as residents finish. A single request that could
never fit (needs more pages than the whole pool) raises ``PoolExhausted``
with the sizing math spelled out. Double frees are hard errors: freeing a
slot that holds no pages or decref'ing a page below zero would silently
corrupt the free list (the same page handed out twice), so both raise with
the offending slot/page id.
"""
from __future__ import annotations

import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages backing ``tokens`` cache positions."""
    return -(-int(tokens) // int(page_size))


def default_num_pages(num_slots: int, max_len: int, page_size: int) -> int:
    """Full-capacity pool: every slot can hold ``max_len`` tokens (the dense
    footprint). Real deployments size below this and lean on backpressure."""
    return num_slots * pages_for(max_len, page_size)


class PoolExhausted(RuntimeError):
    """A single request can never fit in the pool (vs transient pressure,
    which the engine handles by queueing)."""


class PageAllocator:
    """Free-list allocator over ``num_pages`` refcounted pages with per-slot
    tables.

    ``table``: [num_slots, pages_per_slot] i32, entry == ``num_pages`` means
    unallocated (the device-side OOB sentinel). ``refcount``: [num_pages]
    i32, 0 for pages on the free list. All methods are host-side and
    O(pages touched); the engine mirrors ``table`` into the device cache
    after every change.
    """

    def __init__(self, num_pages: int, num_slots: int, pages_per_slot: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = int(num_pages)
        self.num_slots = int(num_slots)
        self.pages_per_slot = int(pages_per_slot)
        self.table = np.full((num_slots, pages_per_slot), num_pages,
                             np.int32)
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> page 0 first
        self._used = np.zeros((num_slots,), np.int32)
        self.refcount = np.zeros((num_pages,), np.int32)
        self.peak_live = 0

    # ------------------------------------------------------------- queries

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.live_pages / self.num_pages

    def can_allocate(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    # ----------------------------------------------------------- lifecycle

    def _check_fit(self, slot: int, total: int, n_fresh: int) -> None:
        if self._used[slot]:
            raise RuntimeError(f"slot {slot} already holds "
                               f"{self._used[slot]} pages (free it first)")
        if total > self.pages_per_slot:
            raise PoolExhausted(
                f"request needs {total} pages but a slot maps at most "
                f"{self.pages_per_slot} (pages_per_slot = ceil(max_len / "
                f"page_size)); shrink the request or raise max_len")
        if total > self.num_pages:
            raise PoolExhausted(
                f"request needs {total} pages but the whole pool has "
                f"{self.num_pages}; grow num_pages (or page_size) — "
                f"backpressure cannot help, no amount of waiting frees "
                f"enough")
        if n_fresh > len(self._free):
            raise RuntimeError(
                f"pool pressure: need {n_fresh} fresh pages, "
                f"{len(self._free)} free — the engine should have deferred "
                f"this admission (can_allocate was false)")

    def allocate(self, slot: int, n_pages: int) -> None:
        """Back ``slot`` with ``n_pages`` fresh pages (refcount 1 each). The
        caller checks ``can_allocate`` first (transient pressure =
        backpressure, not an error); an impossible request raises
        ``PoolExhausted``."""
        self.alias(slot, (), n_pages)

    def alias(self, slot: int, shared_pages, n_fresh: int) -> None:
        """Back ``slot`` with ``shared_pages`` (already-filled prefix pages,
        incref'd — read-only by contract) followed by ``n_fresh`` fresh
        pages. The prefix cache's longest-match pages land at the head of
        the table row, so virtual positions [0, len(shared)*page_size) read
        the cached KV without a copy."""
        shared = [int(p) for p in shared_pages]
        self._check_fit(slot, len(shared) + n_fresh, n_fresh)
        for i, p in enumerate(shared):
            self.incref(p)
            self.table[slot, i] = p
        for i in range(n_fresh):
            self.table[slot, len(shared) + i] = self._free.pop()
        fresh = self.table[slot, len(shared):len(shared) + n_fresh]
        self.refcount[fresh] = 1
        self._used[slot] = len(shared) + n_fresh
        self.peak_live = max(self.peak_live, self.live_pages)

    def incref(self, page: int) -> None:
        """Add a reference to a live page (an aliasing slot or the prefix
        tree). Incref'ing a free page would resurrect a page the allocator
        may hand out again — raise instead."""
        page = int(page)
        if self.refcount[page] < 1:
            raise RuntimeError(
                f"page {page}: incref on a free page (refcount 0) — it may "
                f"already back another slot; alias only live pages")
        self.refcount[page] += 1

    def decref(self, page: int) -> None:
        """Drop a reference; the page returns to the free list only at
        refcount 0. Decref below zero means a double free — raise with the
        page id instead of silently corrupting the free list."""
        page = int(page)
        if self.refcount[page] < 1:
            raise RuntimeError(
                f"page {page}: decref below zero (double free) — the page "
                f"is already on the free list")
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            self._free.append(page)

    def free(self, slot: int) -> None:
        """Decref ``slot``'s pages (shared prefix pages stay live for their
        other holders) and sentinel its table row (freed-slot decode writes
        must drop, see module docstring). Freeing a slot that holds no
        pages is a double free — raise with the slot id."""
        n = int(self._used[slot])
        if n == 0:
            raise RuntimeError(
                f"slot {slot}: double free (slot holds no pages)")
        for i in range(n):
            self.decref(int(self.table[slot, i]))
        self.table[slot, :] = self.num_pages
        self._used[slot] = 0

    def resize_slots(self, num_slots: int,
                     pages_per_slot: int) -> "PageAllocator":
        """Rebuild the slot tables for a new engine geometry, preserving
        page refcounts and the free list. Used when a ``PrefixStore``
        hands this allocator to a new engine: the radix tree's references
        (pages with no slot holder) carry over untouched, while the slot
        side — necessarily empty at handoff — is rebuilt at the adopting
        engine's ``num_slots``/``pages_per_slot``. Refuses to resize while
        any slot still holds pages."""
        if self._used.any():
            held = [int(s) for s in np.nonzero(self._used)[0]]
            raise RuntimeError(
                f"resize_slots with live slots {held}: free every slot "
                f"before handing the allocator to a new engine")
        self.num_slots = int(num_slots)
        self.pages_per_slot = int(pages_per_slot)
        self.table = np.full((self.num_slots, self.pages_per_slot),
                             self.num_pages, np.int32)
        self._used = np.zeros((self.num_slots,), np.int32)
        return self

    def stats(self) -> dict:
        return {"num_pages": self.num_pages,
                "live_pages": self.live_pages,
                "free_pages": self.free_pages,
                "peak_live_pages": self.peak_live,
                "high_water_pages": self.peak_live,
                "utilization": self.utilization()}
