"""Shared KV page pool: host-side page-table allocator for the serve engine.

The paged KV layout replaces each slot's dense ``max_len`` cache row with a
pool of fixed-size pages (``[num_pages, page_size, heads, dim]`` K/V arrays
per layer, see ``models/lm.init_paged_cache``) plus a per-slot page table
mapping virtual position ``s`` to pool page ``table[slot, s // page_size]``.
Serve cache memory then scales with *live tokens* (pages actually backing
admitted requests) instead of ``num_slots * max_len``.

This module is the host side: ``PageAllocator`` owns the free list and the
``[num_slots, pages_per_slot]`` table (numpy; mirrored to the device cache
by the engine after every allocate/free). Unallocated table entries hold the
``num_pages`` sentinel — device code drops writes through them (OOB scatter)
and clamps reads (the gathered rows are masked by ``valid_len`` anyway), so
a freed slot that keeps decoding (finished slots ride along in the decode
chunk) can never corrupt a page that was handed to a new request.

Exhaustion is not an error at admission time: the engine admits as many
requests as the pool can back and leaves the rest queued (admission
backpressure) — pages free as residents finish. A single request that could
never fit (needs more pages than the whole pool) raises ``PoolExhausted``
with the sizing math spelled out.
"""
from __future__ import annotations

import numpy as np


def pages_for(tokens: int, page_size: int) -> int:
    """Number of pages backing ``tokens`` cache positions."""
    return -(-int(tokens) // int(page_size))


def default_num_pages(num_slots: int, max_len: int, page_size: int) -> int:
    """Full-capacity pool: every slot can hold ``max_len`` tokens (the dense
    footprint). Real deployments size below this and lean on backpressure."""
    return num_slots * pages_for(max_len, page_size)


class PoolExhausted(RuntimeError):
    """A single request can never fit in the pool (vs transient pressure,
    which the engine handles by queueing)."""


class PageAllocator:
    """Free-list allocator over ``num_pages`` pages with per-slot tables.

    ``table``: [num_slots, pages_per_slot] i32, entry == ``num_pages`` means
    unallocated (the device-side OOB sentinel). All methods are host-side and
    O(pages touched); the engine mirrors ``table`` into the device cache
    after every change.
    """

    def __init__(self, num_pages: int, num_slots: int, pages_per_slot: int):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = int(num_pages)
        self.num_slots = int(num_slots)
        self.pages_per_slot = int(pages_per_slot)
        self.table = np.full((num_slots, pages_per_slot), num_pages,
                             np.int32)
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> page 0 first
        self._used = np.zeros((num_slots,), np.int32)
        self.peak_live = 0

    # ------------------------------------------------------------- queries

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self.num_pages - len(self._free)

    def utilization(self) -> float:
        return self.live_pages / self.num_pages

    def can_allocate(self, n_pages: int) -> bool:
        return n_pages <= len(self._free)

    # ----------------------------------------------------------- lifecycle

    def allocate(self, slot: int, n_pages: int) -> None:
        """Back ``slot`` with ``n_pages`` fresh pages. The caller checks
        ``can_allocate`` first (transient pressure = backpressure, not an
        error); an impossible request raises ``PoolExhausted``."""
        if self._used[slot]:
            raise RuntimeError(f"slot {slot} already holds "
                               f"{self._used[slot]} pages (free it first)")
        if n_pages > self.pages_per_slot:
            raise PoolExhausted(
                f"request needs {n_pages} pages but a slot maps at most "
                f"{self.pages_per_slot} (pages_per_slot = ceil(max_len / "
                f"page_size)); shrink the request or raise max_len")
        if n_pages > self.num_pages:
            raise PoolExhausted(
                f"request needs {n_pages} pages but the whole pool has "
                f"{self.num_pages}; grow num_pages (or page_size) — "
                f"backpressure cannot help, no amount of waiting frees "
                f"enough")
        if n_pages > len(self._free):
            raise RuntimeError(
                f"pool pressure: need {n_pages} pages, {len(self._free)} "
                f"free — the engine should have deferred this admission "
                f"(can_allocate was false)")
        for i in range(n_pages):
            self.table[slot, i] = self._free.pop()
        self._used[slot] = n_pages
        self.peak_live = max(self.peak_live, self.live_pages)

    def free(self, slot: int) -> None:
        """Return ``slot``'s pages to the free list and sentinel its table
        row (freed-slot decode writes must drop, see module docstring)."""
        n = int(self._used[slot])
        for i in range(n):
            self._free.append(int(self.table[slot, i]))
        self.table[slot, :] = self.num_pages
        self._used[slot] = 0

    def stats(self) -> dict:
        return {"num_pages": self.num_pages,
                "live_pages": self.live_pages,
                "free_pages": self.free_pages,
                "peak_live_pages": self.peak_live,
                "utilization": self.utilization()}
