"""Radix prefix cache: host-side tree over page-granular token prefixes.

Maps ``tokens[:n*page_size]`` -> the pool pages that already hold those
positions' KV, so admission can alias the longest cached prefix read-only
into a new slot's page table and prefill only the uncached suffix. Nodes
are page-granular — one node per full page of tokens, keyed by that page's
token tuple — because KV pages are the unit of sharing: a partial-page
match cannot be aliased (the page would be written through by the suffix
scatter), so matches are always page-aligned by construction.

Ownership: the tree holds exactly one allocator reference per node (taken
via ``incref`` at insert, released via ``decref`` at eviction), so a cached
page survives its inserting slot's ``free`` and returns to the free list
only when no slot aliases it AND the tree has evicted it. Eviction is
LRU over leaf nodes only (evicting an interior node would dangle the
deeper cached prefixes), triggered by the ``capacity_pages`` cap at insert
time and by the engine under pool pressure (reclaim before preempting).

Insertion dedups: an existing node keeps its page (first writer wins) and
the duplicate page is simply not referenced — it returns to the pool with
its slot. All methods are host-side, O(pages touched) for match/insert and
O(nodes) for an eviction scan (fine at serve-engine scale).
"""
from __future__ import annotations

import numpy as np


class _Node:
    __slots__ = ("key", "page", "children", "parent", "stamp")

    def __init__(self, key, page, parent, stamp):
        self.key = key          # tuple of page_size tokens
        self.page = int(page)   # pool page id holding this page's KV
        self.children = {}      # token tuple -> _Node
        self.parent = parent    # _Node | None (root child)
        self.stamp = stamp      # LRU clock at last touch


class PrefixCache:
    """Page-granular radix tree with an LRU page cap (see module docstring).

    ``incref``/``decref`` are the allocator's refcount hooks; the tree never
    touches the free list directly.
    """

    def __init__(self, page_size: int, capacity_pages: int, incref, decref):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if capacity_pages < 0:
            raise ValueError(
                f"capacity_pages must be >= 0, got {capacity_pages}")
        self.page_size = int(page_size)
        self.capacity = int(capacity_pages)
        self._incref, self._decref = incref, decref
        self._children: dict = {}   # root's children
        self._clock = 0
        self._pages = 0

    def __len__(self) -> int:
        return self._pages

    @property
    def cached_pages(self) -> int:
        return self._pages

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _page_key(self, tokens, i: int) -> tuple:
        ps = self.page_size
        return tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])

    # -------------------------------------------------------------- lookup

    def match(self, tokens, touch: bool = True) -> list[int]:
        """Longest cached prefix of ``tokens``: page ids backing
        ``tokens[:n*page_size]`` with ``n`` maximal. Touches the matched
        chain's LRU stamps unless ``touch=False`` (a pure probe — the
        prefix-aware admission policy scans the queue without distorting
        the LRU order it is scheduling around). The caller must pin the
        returned pages (incref or alias) before anything that can evict."""
        tokens = np.asarray(tokens)
        stamp = self._tick() if touch else None
        out: list[int] = []
        children = self._children
        for i in range(len(tokens) // self.page_size):
            node = children.get(self._page_key(tokens, i))
            if node is None:
                break
            if touch:
                node.stamp = stamp
            out.append(node.page)
            children = node.children
        return out

    def lru_pages(self, n: int) -> set[int]:
        """Page ids of the ``n`` least-recently-used LEAF nodes — the
        eviction frontier: the next ``n`` calls to ``evict(1)`` would take
        exactly these (ties broken arbitrarily). Read-only; O(nodes)."""
        leaves: list[_Node] = []
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            else:
                leaves.append(node)
        leaves.sort(key=lambda nd: nd.stamp)
        return {leaf.page for leaf in leaves[:n]}

    # -------------------------------------------------------------- insert

    def insert(self, tokens, pages) -> int:
        """Insert the full-page prefixes of ``tokens``: ``pages[i]`` holds
        the KV for ``tokens[i*page_size:(i+1)*page_size]`` and must be live
        (refcount >= 1 — typically still held by the completing slot).
        Existing nodes keep their page (dedup); each NEW node increfs its
        page. Returns the number of new nodes; may evict LRU leaves to stay
        under the capacity cap."""
        tokens = np.asarray(tokens)
        n = min(len(tokens) // self.page_size, len(pages))
        stamp = self._tick()
        children, parent = self._children, None
        new = 0
        for i in range(n):
            key = self._page_key(tokens, i)
            node = children.get(key)
            if node is None:
                node = _Node(key, pages[i], parent, stamp)
                self._incref(node.page)
                children[key] = node
                self._pages += 1
                new += 1
            node.stamp = stamp
            parent, children = node, node.children
        if self._pages > self.capacity:
            self.evict(self._pages - self.capacity)
        return new

    # ------------------------------------------------------------ eviction

    def _lru_leaf(self) -> _Node | None:
        best = None
        stack = list(self._children.values())
        while stack:
            node = stack.pop()
            if node.children:
                stack.extend(node.children.values())
            elif best is None or node.stamp < best.stamp:
                best = node
        return best

    def evict(self, n_pages: int) -> list[int]:
        """Evict up to ``n_pages`` least-recently-used LEAF nodes, decref'ing
        each page — a page whose only reference was the tree returns to the
        free list; one still aliased by a resident stays live until that
        slot frees. Returns the evicted page ids."""
        evicted: list[int] = []
        while len(evicted) < n_pages:
            leaf = self._lru_leaf()
            if leaf is None:
                break
            siblings = (leaf.parent.children if leaf.parent is not None
                        else self._children)
            del siblings[leaf.key]
            self._pages -= 1
            self._decref(leaf.page)
            evicted.append(leaf.page)
        return evicted

    # ------------------------------------------------------------- testing

    def snapshot(self) -> dict[tuple, int]:
        """{full token prefix tuple -> page id} for every node (tests and
        debugging; O(total cached tokens))."""
        out: dict[tuple, int] = {}
        stack = [((), node) for node in self._children.values()]
        while stack:
            prefix, node = stack.pop()
            prefix = prefix + node.key
            out[prefix] = node.page
            stack.extend((prefix, c) for c in node.children.values())
        return out
