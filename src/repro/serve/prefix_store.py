"""``PrefixStore`` — server-level persistence for the radix prefix cache.

A ``ServeEngine`` owns its KV page pool, page allocator, and radix tree;
without a store they die with the engine, so repeated engine instances
over the SAME params (eval sweeps building one engine per call, a
relaunched server, per-wave engines in a benchmark) re-prefill prefixes
they already computed. The store keeps ``{k/v pools, PageAllocator,
PrefixCache}`` alive between engines: ``ServeEngine.close()`` hands its
live tree pages over (the tree's one-ref-per-node refcount contract moves
wholesale — no page is freed or copied), and the next engine constructed
with the same store, params, and pool geometry adopts them instead of
initializing cold, so its first admissions alias warm pages
(``stats["prefix_hits"] > 0`` from request one). This is the
cross-engine analogue of SGLang's RadixAttention keeping its tree across
batches.

Keying: entries are keyed by the model config, a cheap content
fingerprint of the params (tree structure + leaf shapes/dtypes + CRC32 of
small samples of the leading leaves), and the pool geometry
(``page_size``/``num_pages`` — pools of a different shape cannot be
adopted). Each entry additionally holds a weakref to one of the original
params' leaves: cached KV is only valid for the exact arrays it was
computed from, and the fingerprint samples rather than hashes every byte,
so if the original params have been freed the entry is dropped instead of
trusting a partial digest. ``take`` pops (single ownership — two live
engines over the same params never share one mutable allocator);
``put`` overwrites (last close wins).
"""
from __future__ import annotations

import weakref
import zlib

import numpy as np

import jax


def params_fingerprint(params) -> int:
    """Cheap content fingerprint of a params pytree: CRC32 over the tree
    structure, every leaf's shape/dtype, and a small value sample of the
    leading leaves (enough to tell checkpoints apart without hashing
    gigabytes; the store's weakref covers in-place reuse of the arrays)."""
    leaves, treedef = jax.tree.flatten(params)
    h = zlib.crc32(str(treedef).encode())
    for leaf in leaves:
        h = zlib.crc32(
            f"{getattr(leaf, 'shape', ())}:{getattr(leaf, 'dtype', '')}"
            .encode(), h)
    for leaf in leaves[:2]:
        sample = np.asarray(leaf.reshape(-1)[:64])
        h = zlib.crc32(sample.tobytes(), h)
    return h


def _anchor(params):
    """A weakref-able leaf of ``params`` (None if none supports weakrefs —
    the store then keys on the fingerprint alone)."""
    for leaf in jax.tree.leaves(params):
        try:
            return weakref.ref(leaf)
        except TypeError:
            continue
    return None


class PrefixStore:
    """Cross-engine radix-tree store (see module docstring). One instance
    per server process (or per eval sweep); share it by passing the same
    object as ``ServeConfig.prefix_store`` to every engine."""

    def __init__(self):
        self._entries: dict[tuple, tuple] = {}
        self.stats = {"puts": 0, "adoptions": 0, "misses": 0, "expired": 0}

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def key_for(cfg, params, *, page_size: int, num_pages: int) -> tuple:
        return (cfg, params_fingerprint(params), int(page_size),
                int(num_pages))

    def put(self, key: tuple, params, state: dict) -> None:
        """Deposit an engine's live prefix state: ``state`` carries the
        ``k``/``v`` device pools, the ``PageAllocator`` (all slot rows
        free — only tree references remain), and the ``PrefixCache``."""
        self._entries[key] = (_anchor(params), state)
        self.stats["puts"] += 1

    def take(self, key: tuple) -> dict | None:
        """Pop the entry for ``key`` (single ownership). Returns None on a
        miss or when the original params have been garbage-collected (the
        cached KV can no longer be tied to live arrays)."""
        item = self._entries.pop(key, None)
        if item is None:
            self.stats["misses"] += 1
            return None
        anchor, state = item
        if anchor is not None and anchor() is None:
            self.stats["expired"] += 1
            return None
        self.stats["adoptions"] += 1
        return state

    def cached_pages(self) -> int:
        """Total radix-tree pages currently parked in the store."""
        return sum(len(state["tree"]) for _, state in self._entries.values())
