"""Unified serve-engine result types.

Every delivery surface of the engine hands back the same ``Completion``
record: ``step()`` returns a list of them, ``run()`` returns a
``RunResult`` (a ``{uid: tokens}`` dict view carrying the full records on
``.completions``), ``engine.generate`` returns a token array whose
``.completions`` attribute holds them, and ``on_complete`` callbacks
receive one per finished request. Before this, the three surfaces used
three conventions ((uid, tokens) tuples, a plain dict, a bare array) and
per-request metadata (finish reason, queueing delay, prefix reuse) was
unobservable without scraping engine internals.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class Completion:
    """One finished request.

    ``tokens``: the emitted tokens ([n] i32, ends at EOS if hit).
    ``finish_reason``: ``"eos"`` (terminated on the EOS token) or
    ``"length"`` (exhausted ``max_new_tokens``).
    ``arrival``: the request's arrival step; ``first_token_step`` the
    engine step at which it was admitted (its first token sampled) — the
    difference is the queueing delay; ``done_step`` the step it finished.
    ``prefix_pages``: radix-cache pages aliased instead of prefilled
    across this request's admission(s) (0 with the prefix cache off).
    """

    uid: int
    tokens: np.ndarray
    finish_reason: str
    arrival: float
    first_token_step: int
    done_step: int
    prefix_pages: int = 0


class RunResult(dict):
    """``ServeEngine.run``'s return value: a ``{uid: tokens}`` mapping
    (the historical contract — existing callers index/iterate it
    unchanged) with the full per-request records on ``.completions``."""

    def __init__(self, completions: dict[int, Completion]):
        super().__init__({uid: c.tokens for uid, c in completions.items()})
        self.completions = completions


class TokenBatch(np.ndarray):
    """``engine.generate``'s return value: the historical
    ``[B, max_new_tokens]`` token array, with the per-request
    ``Completion`` records attached as ``.completions`` (uid == row)."""

    completions: dict[int, Completion] | None = None

    @classmethod
    def wrap(cls, tokens: np.ndarray,
             completions: dict[int, Completion]) -> "TokenBatch":
        out = np.asarray(tokens).view(cls)
        out.completions = completions
        return out

    def __array_finalize__(self, obj):
        if obj is not None:
            self.completions = getattr(obj, "completions", None)
