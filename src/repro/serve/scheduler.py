"""Request queue + slot admission policy for the continuous-batching engine.

FCFS with same-shape grouping: ``next_group`` hands the engine the longest
run of *consecutive* head-of-queue requests that share a prompt signature
(prompt length + extra-input shapes) and have arrived by ``now``, capped by
the number of free slots. Grouping consecutive same-shape requests keeps
admission FCFS while letting the engine prefill them as one batch (one
prefill compile key per signature instead of per request).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    """One generation request.

    ``tokens``: the prompt, [S] i32 (no batch dim). ``extras`` carries
    per-request model inputs without a batch dim (e.g. vlm ``patch_embeds``
    [Np, D] or encdec ``src_embeds`` [Ss, D]). ``arrival`` is the engine
    step at which the request becomes admissible (0 = immediately); the
    benchmark's staggered workload replays a trace through it.
    """

    uid: int
    tokens: np.ndarray
    max_new_tokens: int
    arrival: float = 0.0
    extras: dict = field(default_factory=dict)

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32)
        if self.tokens.ndim != 1 or self.tokens.shape[0] < 1:
            raise ValueError(f"request {self.uid}: tokens must be non-empty "
                             f"[S], got {self.tokens.shape}")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.uid}: max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    def signature(self) -> tuple:
        """Requests with equal signatures can share one prefill call."""
        ex = tuple(sorted((k, np.asarray(v).shape) for k, v in self.extras.items()))
        return (self.prompt_len, ex)


class FCFSScheduler:
    """First-come-first-served queue with consecutive same-shape grouping."""

    def __init__(self):
        self._q: deque[Request] = deque()

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> int:
        return len(self._q)

    def submit(self, req: Request) -> None:
        self._q.append(req)

    def next_arrival(self) -> float | None:
        """Arrival step of the head request (None if the queue is empty)."""
        return self._q[0].arrival if self._q else None

    def push_front(self, reqs) -> None:
        """Return ``reqs`` (in order) to the HEAD of the queue — admission
        backpressure puts un-admittable requests back without losing their
        FCFS position."""
        for r in reversed(list(reqs)):
            self._q.appendleft(r)

    def next_group(self, free_slots: int, now: float = float("inf"),
                   key=None) -> list[Request]:
        """Pop up to ``free_slots`` consecutive head-of-queue requests that
        share the head's group key and have ``arrival <= now``. ``key``
        (Request -> hashable) defaults to ``Request.signature`` (exact
        prompt shape); the bucketed engine passes a coarser
        bucket-of-prompt-length key so mixed-length prompts batch into one
        prefill."""
        keyf = key if key is not None else (lambda r: r.signature())
        if free_slots <= 0 or not self._q or self._q[0].arrival > now:
            return []
        sig = keyf(self._q[0])
        group: list[Request] = []
        while self._q and len(group) < free_slots:
            r = self._q[0]
            if r.arrival > now or keyf(r) != sig:
                break
            group.append(self._q.popleft())
        return group
