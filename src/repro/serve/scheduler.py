"""Request queue + slot admission policy for the continuous-batching engine.

FCFS with same-shape grouping: ``next_group`` hands the engine the longest
run of *consecutive* head-of-queue requests that share a prompt signature
(prompt length + extra-input shapes) and have arrived by ``now``, capped by
the number of free slots. Grouping consecutive same-shape requests keeps
admission FCFS while letting the engine prefill them as one batch (one
prefill compile key per signature instead of per request).

``AdmissionPolicy`` is the seam for admitting OUT of arrival order: the
policy picks which admissible request pivots the next group (default: the
head, i.e. strict FCFS). ``PrefixAwareAdmission`` uses it to rescue
requests whose cached prefix pages sit at the radix tree's LRU eviction
frontier — admitting them before their pages are evicted converts a
would-be full prefill into page aliasing, the way vLLM schedules around
cached blocks. Reordering is bounded: each waiting request can be bypassed
at most ``max_skips`` times, after which the policy is forced back to
FCFS until that request drains — no starvation (property-tested in
tests/test_scheduler_prop.py). Because the engine derives each slot's rng
key from the request uid (not the slot or admission step), admission order
never changes token outputs.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Request:
    """One generation request.

    ``tokens``: the prompt, [S] i32 (no batch dim). ``extras`` carries
    per-request model inputs without a batch dim (e.g. vlm ``patch_embeds``
    [Np, D] or encdec ``src_embeds`` [Ss, D]). ``arrival`` is the engine
    step at which the request becomes admissible (0 = immediately); the
    benchmark's staggered workload replays a trace through it.
    """

    uid: int
    tokens: np.ndarray
    max_new_tokens: int
    arrival: float = 0.0
    extras: dict = field(default_factory=dict)

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32)
        if self.tokens.ndim != 1 or self.tokens.shape[0] < 1:
            raise ValueError(f"request {self.uid}: tokens must be non-empty "
                             f"[S], got {self.tokens.shape}")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.uid}: max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    def signature(self) -> tuple:
        """Requests with equal signatures can share one prefill call."""
        ex = tuple(sorted((k, np.asarray(v).shape) for k, v in self.extras.items()))
        return (self.prompt_len, ex)


class AdmissionPolicy:
    """Chooses which admissible request pivots the next admission group.

    ``pick`` receives the window of queued requests whose ``arrival <=
    now`` (in queue order) and returns the index of the request the next
    group should form around; the scheduler pops that request plus the
    consecutive same-key run behind it. The base policy returns 0 —
    strict FCFS, bit-identical to a policy-less scheduler. ``on_admit``
    observes every admission (admitted group + the requests it jumped
    over) so stateful policies can enforce fairness bounds.
    """

    def pick(self, window: list[Request], now: float) -> int:
        return 0

    def on_admit(self, admitted: list[Request],
                 bypassed: list[Request]) -> None:
        pass


class PrefixAwareAdmission(AdmissionPolicy):
    """Admit a queued request early when its cached prefix is about to die.

    ``matched_pages(req)`` -> set of radix-cache page ids the request's
    prompt currently matches (a read-only lookup — no LRU touch);
    ``frontier_pages()`` -> the page ids at the tree's LRU eviction
    frontier (the next candidates to be evicted). A request whose match
    intersects the frontier is admitted ahead of FCFS order so its pages
    are re-pinned (aliased, refcounted) before eviction reclaims them.

    Fairness: every bypassed request's skip count is bumped; once any
    waiting request reaches ``max_skips`` the policy returns to strict
    FCFS until that request has been admitted. A bypassed request never
    moves backward in the queue and reordering only happens within the
    first ``max_window`` admissible requests, so each request is bypassed
    at most ``max_skips`` times before it drains — the starvation bound.
    """

    def __init__(self, matched_pages, frontier_pages, *,
                 max_skips: int = 4, max_window: int = 16):
        if max_skips < 1:
            raise ValueError(f"max_skips must be >= 1, got {max_skips}")
        self.matched_pages = matched_pages
        self.frontier_pages = frontier_pages
        self.max_skips = int(max_skips)
        self.max_window = int(max_window)
        self._skips: dict[int, int] = {}
        self.stats = {"bypass_admissions": 0, "bypassed": 0,
                      "aging_forced": 0}

    def pick(self, window: list[Request], now: float) -> int:
        if len(window) <= 1:
            return 0
        window = window[:self.max_window]
        # aging cap: once anyone has been skipped to the limit, fall back
        # to strict FCFS until the queue drains past them
        if any(self._skips.get(r.uid, 0) >= self.max_skips for r in window):
            self.stats["aging_forced"] += 1
            return 0
        frontier = self.frontier_pages()
        if not frontier:
            return 0
        for i, r in enumerate(window):
            if self.matched_pages(r) & frontier:
                return i
        return 0

    def on_admit(self, admitted: list[Request],
                 bypassed: list[Request]) -> None:
        if bypassed:
            self.stats["bypass_admissions"] += 1
            self.stats["bypassed"] += len(bypassed)
            for r in bypassed:
                self._skips[r.uid] = self._skips.get(r.uid, 0) + 1
        for r in admitted:
            self._skips.pop(r.uid, None)


class FCFSScheduler:
    """First-come-first-served queue with consecutive same-shape grouping.

    An optional ``AdmissionPolicy`` may pivot admission away from the
    head (see module docstring); with ``policy=None`` the scheduler is
    strict FCFS.
    """

    def __init__(self, policy: AdmissionPolicy | None = None):
        self._q: deque[Request] = deque()
        self.policy = policy

    def __len__(self) -> int:
        return len(self._q)

    @property
    def pending(self) -> int:
        return len(self._q)

    def submit(self, req: Request) -> None:
        self._q.append(req)

    def next_arrival(self) -> float | None:
        """Arrival step of the head request (None if the queue is empty)."""
        return self._q[0].arrival if self._q else None

    def push_front(self, reqs) -> None:
        """Return ``reqs`` (in order) to the HEAD of the queue — admission
        backpressure puts un-admittable requests back without losing their
        FCFS position."""
        for r in reversed(list(reqs)):
            self._q.appendleft(r)

    def next_group(self, free_slots: int, now: float = float("inf"),
                   key=None) -> list[Request]:
        """Pop up to ``free_slots`` consecutive requests sharing one group
        key, pivoted at the request the admission policy picks (the head
        under strict FCFS), all with ``arrival <= now``. ``key`` (Request
        -> hashable) defaults to ``Request.signature`` (exact prompt
        shape); the bucketed engine passes a coarser
        bucket-of-prompt-length key so mixed-length prompts batch into one
        prefill."""
        keyf = key if key is not None else (lambda r: r.signature())
        if free_slots <= 0 or not self._q or self._q[0].arrival > now:
            return []
        start = 0
        if self.policy is not None:
            window = []
            for r in self._q:
                if r.arrival > now:
                    break
                window.append(r)
            start = self.policy.pick(window, now)
            if not 0 <= start < len(window):
                start = 0
        sig = keyf(self._q[start])
        bypassed = list(self._q)[:start]
        group: list[Request] = [self._q[start]]
        del self._q[start]
        while len(self._q) > start and len(group) < free_slots:
            r = self._q[start]
            if r.arrival > now or keyf(r) != sig:
                break
            group.append(r)
            del self._q[start]
        if self.policy is not None:
            self.policy.on_admit(group, bypassed)
        return group
