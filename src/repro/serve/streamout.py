"""Background detokenize/stream-out queue for the serve engine.

Finished sequences are handed off to a daemon worker thread (the pattern
MaxText's ``offline_inference.py`` uses for its emit thread) so
``ServeEngine.step()`` never blocks on host-side decode: the engine's hot
loop only enqueues ``Completion`` records and moves on to the next decode
chunk, while the worker runs the user callback — detokenization, HTTP
writes, logging — off the critical path.

Error contract: a callback exception does not kill the engine loop; the
first one is captured and re-raised from ``drain()`` (which ``run()`` calls
before returning), so failures surface at the end of the batch instead of
being swallowed. ``drain`` blocks until every enqueued completion has been
processed — results are complete when it returns.
"""
from __future__ import annotations

import queue
import threading

import numpy as np

from repro import obs

_STOP = object()


class StreamOut:
    """Single worker thread draining a completion queue (see module doc).

    ``callback(completion)`` runs on the worker thread with the finished
    request's ``Completion`` record (uid, tokens, finish reason, timing,
    prefix-reuse count — see serve/results.py).
    """

    def __init__(self, callback=None):
        self._callback = callback
        self._q: queue.Queue = queue.Queue()
        self._results: dict[int, np.ndarray] = {}
        self._error: BaseException | None = None
        # incremented from the worker thread — thread-safe by contract
        self._c_streamed = obs.metrics.counter("streamed_completions",
                                               subsystem="serve")
        self._thread = threading.Thread(
            target=self._worker, name="serve-streamout", daemon=True)
        self._thread.start()

    @property
    def pending(self) -> int:
        return self._q.unfinished_tasks

    def put(self, completion) -> None:
        """Enqueue a finished request's ``Completion`` (non-blocking;
        called from step())."""
        self._q.put(completion)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _STOP:
                    return
                with obs.span("streamout_callback"):
                    self._results[item.uid] = item.tokens
                    if self._callback is not None:
                        self._callback(item)
                self._c_streamed.inc()
            except BaseException as e:  # noqa: BLE001 — surfaced via drain()
                if self._error is None:
                    self._error = e
            finally:
                self._q.task_done()

    def drain(self) -> dict[int, np.ndarray]:
        """Block until the queue is empty; re-raise the first callback
        error; return {uid: tokens} for everything streamed so far."""
        self._q.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        return dict(self._results)

    def close(self) -> None:
        """Drain, then stop the worker thread."""
        self._q.join()
        self._q.put(_STOP)
        self._thread.join()
