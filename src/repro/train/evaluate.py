"""Task evaluation — the GSM8K-protocol proxy: zero-shot, greedy decoding,
exact match on the generated answer (paper §4.2)."""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.data import synthetic
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


def math_accuracy(params, cfg: ModelConfig, task: synthetic.MathTaskConfig,
                  *, num_problems: int = 64, batch_size: int = 16, mesh=None,
                  batch_axes=("data",), serve_cfg: ServeConfig | None = None
                  ) -> float:
    """Greedy-decode the CoT + answer for held-out problems; exact match.

    Problems stream through a ``ServeEngine`` in chunks of ``batch_size``
    slots, so memory scales with ``batch_size`` instead of ``num_problems``,
    and the engine's process-wide compiled-fn cache means repeated calls
    (train-loop eval) compile prefill/decode exactly once.

    ``serve_cfg`` overrides the default serving configuration — pass one
    with ``prefix_cache=True`` and a shared ``prefix_store`` so repeated
    sweeps (methods x checkpoints over the same prompt set) re-alias cached
    prefix pages across engine instances instead of re-prefilling
    (``mesh``/``batch_axes``/``eos_id`` and the capacity fields are still
    forced to the eval protocol's values)."""
    p_len = synthetic.prompt_len(task)
    toks = [synthetic.sample_problem(task, task.eval_offset + i)[0][:p_len]
            for i in range(num_problems)]
    answers = [synthetic.answer_of(task, i) for i in range(num_problems)]
    prompts = np.stack(toks).astype(np.int32)

    slots = min(batch_size, num_problems)
    if serve_cfg is None:
        scfg = ServeConfig(max_len=task.seq_len, num_slots=slots,
                           eos_id=synthetic.EOS, mesh=mesh,
                           batch_axes=batch_axes)
    else:
        from dataclasses import replace
        scfg = replace(serve_cfg, max_len=task.seq_len, num_slots=slots,
                       eos_id=synthetic.EOS, mesh=mesh,
                       batch_axes=batch_axes)
    engine = ServeEngine(cfg, params, scfg)
    correct = 0
    # full-slot chunks drained one at a time (not one continuous submit):
    # every admission then has the same [slots, p_len] prefill shape, so
    # repeated eval calls compile prefill/decode exactly once. The idle-slot
    # bubble at each chunk tail is the price; eval throughput is dominated
    # by the compile-once property, not tail latency.
    for start in range(0, num_problems, slots):
        chunk = prompts[start:start + slots]
        reqs = [Request(uid=start + i, tokens=chunk[i],
                        max_new_tokens=task.seq_len - p_len)
                for i in range(len(chunk))]
        res = engine.run(reqs)
        for i in range(len(chunk)):
            pred = synthetic.decode_answer(res[start + i])
            correct += int(pred == answers[start + i])
    # hands the radix tree to serve_cfg.prefix_store (when set) so the
    # next sweep's engine adopts it warm
    engine.close()
    return correct / num_problems
