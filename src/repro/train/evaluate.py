"""Task evaluation — the GSM8K-protocol proxy: zero-shot, greedy decoding,
exact match on the generated answer (paper §4.2)."""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig
from repro.data import synthetic
from repro.serve.engine import generate


def math_accuracy(params, cfg: ModelConfig, task: synthetic.MathTaskConfig,
                  *, num_problems: int = 64, mesh=None,
                  batch_axes=("data",)) -> float:
    """Greedy-decode the CoT + answer for held-out problems; exact match."""
    p_len = synthetic.prompt_len(task)
    toks = []
    answers = []
    for i in range(num_problems):
        t, _ = synthetic.sample_problem(
            task.__class__(**{**task.__dict__}), task.eval_offset + i)
        toks.append(t[:p_len])
        answers.append(synthetic.answer_of(task, i))
    prompts = np.stack(toks).astype(np.int32)
    gen = generate(params, cfg, {"tokens": prompts},
                   max_new_tokens=task.seq_len - p_len, mesh=mesh,
                   batch_axes=batch_axes, eos_id=synthetic.EOS)
    correct = 0
    for row, ans in zip(gen, answers):
        pred = synthetic.decode_answer(row)
        correct += int(pred == ans)
    return correct / num_problems
