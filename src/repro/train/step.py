"""Compiled train steps.

``make_train_step``  — AdaGradSelect / topk_grad / random / full-FT (Alg. 2
    integrated: grads -> per-block norms -> in-jit selection -> masked AdamW).
``make_lora_train_step`` — LoRA baseline (merge-on-forward, standard AdamW on
    adapters only).

One compiled program serves every selection outcome (masks are runtime
inputs). Microbatch gradient accumulation (optimizer.microbatch > 1) scans
over batch slices inside the step.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig, SelectConfig
from repro.core import adagradselect, masked_adamw, partition as part_mod
from repro.models import registry
from repro.optim import adamw as plain_adamw
from repro.optim import lora as lora_mod
from repro.optim.schedules import learning_rate


# ----------------------------------------------------------------- loss


def next_token_loss(logits, tokens, loss_mask, shift: int = 1):
    """Masked CE: position t predicts token t+shift. Computed as
    gathered-logit minus logsumexp so no [B,S,V] f32 tensor is ever
    materialized (the f32 reduction fuses)."""
    if shift:
        logits = logits[:, :-shift]
        targets = tokens[:, shift:]
        mask = loss_mask[:, shift:]
    else:
        targets, mask = tokens, loss_mask
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ll = picked.astype(jnp.float32) - lse
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / denom


def model_loss(model, cfg: ModelConfig, params, batch, *, mesh=None,
               batch_axes=("data",), masks=None):
    logits, aux, extra = model.apply_train(params, cfg, batch, mesh=mesh,
                                           batch_axes=batch_axes, masks=masks)
    loss = next_token_loss(logits, batch["tokens"], batch["loss_mask"])
    total = loss + aux
    if "mtp_logits" in extra:
        mtp = next_token_loss(extra["mtp_logits"], batch["tokens"],
                              batch["loss_mask"], shift=2)
        total = total + cfg.mtp_loss_weight * mtp
    return total, {"ce_loss": loss, "aux_loss": aux}


def _accumulate_grads(loss_fn, params, batch, n_micro: int,
                      accum_dtype=jnp.float32):
    """Mean grads over microbatches via lax.scan (gradient accumulation)."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def resh(x):
        return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

    micro = jax.tree.map(resh, batch)

    def body(carry, mb):
        acc, loss_acc, m_acc = carry
        (loss, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(jnp.add, acc, g)
        m_acc = jax.tree.map(jnp.add, m_acc, met)
        return (acc, loss_acc + loss, m_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    met0 = {"ce_loss": jnp.zeros((), jnp.float32),
            "aux_loss": jnp.zeros((), jnp.float32)}
    (gacc, loss, macc), _ = jax.lax.scan(
        body, (zeros, jnp.zeros((), jnp.float32), met0), micro)
    scale = 1.0 / n_micro
    grads = jax.tree.map(lambda g, p: (g * scale).astype(p.dtype), gacc, params)
    met = jax.tree.map(lambda m: m * scale, macc)
    return (loss * scale, met), grads


# ----------------------------------------------------------------- steps


def make_train_step(model_cfg: ModelConfig, sel_cfg: SelectConfig,
                    opt_cfg: OptimizerConfig, *, mesh=None,
                    batch_axes=("data",), use_pallas: bool = False,
                    donate: bool = True):
    """-> jitted (state, batch) -> (state, metrics).

    state = {"params", "opt" {m,v,counts}, "sel" (adagradselect state),
             "step" i32}.
    """
    model = registry.get(model_cfg)
    partition = part_mod.build_partition(model_cfg)
    gate = model_cfg.gate_weight_grads

    def step_fn(state, batch):
        sel_state = state["sel"]

        # gate mode decides the mask BEFORE backward (from cumulative signal)
        pre_mask = None
        if gate:
            pre_mask, sel_state = adagradselect.select(
                sel_cfg, sel_state, jnp.zeros((partition.num_blocks,), jnp.float32),
                partition.num_blocks)

        def loss_fn(params, mb):
            masks = (part_mod.layer_masks_dict(partition, pre_mask)
                     if gate else None)
            return model_loss(model, model_cfg, params, mb, mesh=mesh,
                              batch_axes=batch_axes, masks=masks)

        (loss, metrics), grads = _accumulate_grads(
            loss_fn, state["params"], batch, opt_cfg.microbatch,
            jnp.dtype(opt_cfg.accum_dtype))

        grads, gnorm = masked_adamw.clip_by_global_norm(grads, opt_cfg.grad_clip)
        block_norms = part_mod.block_grad_norms(partition, grads,
                                                use_pallas=use_pallas)
        if gate:
            mask = pre_mask
            # observe norms post-hoc (only computed blocks contribute)
            sel_state = {**sel_state,
                         "cum_norms": sel_state["cum_norms"] + block_norms}
        else:
            mask, sel_state = adagradselect.select(
                sel_cfg, state["sel"], block_norms, partition.num_blocks)

        lr = learning_rate(opt_cfg, state["step"])
        params, opt = masked_adamw.update(
            opt_cfg, partition, state["params"], grads, state["opt"], mask,
            lr, use_pallas=use_pallas)
        new_state = {"params": params, "opt": opt, "sel": sel_state,
                     "step": state["step"] + 1}
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm, "lr": lr,
                   "epsilon": adagradselect.epsilon(sel_cfg, state["step"]),
                   "num_selected": jnp.sum(mask.astype(jnp.int32)),
                   "mask": mask, "block_norms": block_norms}
        return new_state, metrics

    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())


def init_train_state(model_cfg: ModelConfig, seed: int = 0,
                     moment_dtype=jnp.float32) -> dict:
    model = registry.get(model_cfg)
    partition = part_mod.build_partition(model_cfg)
    params = model.init(jax.random.PRNGKey(seed), model_cfg)
    return {
        "params": params,
        "opt": masked_adamw.init_opt_state(partition, params, moment_dtype),
        "sel": adagradselect.init_state(partition.num_blocks, seed),
        "step": jnp.zeros((), jnp.int32),
    }


def train_state_shapes(model_cfg: ModelConfig, seed: int = 0):
    return jax.eval_shape(partial(init_train_state, model_cfg), seed)


# ----------------------------------------------------------------- LoRA


def make_lora_train_step(model_cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                         mesh=None, batch_axes=("data",), donate: bool = True):
    """Baseline: adapters trained with standard AdamW; base weights frozen.
    state = {"base", "lora", "opt", "step"}."""
    model = registry.get(model_cfg)
    rank, alpha = opt_cfg.lora_rank, opt_cfg.lora_alpha

    def step_fn(state, batch):
        def loss_fn(lp, mb):
            merged = lora_mod.merge(state["base"], lp, model_cfg, rank, alpha)
            return model_loss(model, model_cfg, merged, mb, mesh=mesh,
                              batch_axes=batch_axes)

        (loss, metrics), grads = _accumulate_grads(
            loss_fn, state["lora"], batch, opt_cfg.microbatch)
        grads, gnorm = masked_adamw.clip_by_global_norm(grads, opt_cfg.grad_clip)
        lr = learning_rate(opt_cfg, state["step"])
        lora_p, opt = plain_adamw.update(opt_cfg, state["lora"], grads,
                                         state["opt"], lr)
        new_state = {"base": state["base"], "lora": lora_p, "opt": opt,
                     "step": state["step"] + 1}
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())


def init_lora_state(model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    seed: int = 0) -> dict:
    model = registry.get(model_cfg)
    base = model.init(jax.random.PRNGKey(seed), model_cfg)
    lora_p = lora_mod.init_lora(jax.random.PRNGKey(seed + 1), base, model_cfg,
                                opt_cfg.lora_rank)
    return {"base": base, "lora": lora_p,
            "opt": plain_adamw.init_opt_state(lora_p),
            "step": jnp.zeros((), jnp.int32)}
