"""Method-agnostic training-step building blocks.

This module owns the pieces every fine-tuning method shares: the masked
next-token loss, microbatch gradient accumulation (``accumulate_grads``
scans over batch slices inside the step), and TrainState initialization /
shape inference for the masked-selection family. The per-method step
factories themselves live in ``repro.methods`` — ``methods/selection.py``
for the block-masked family (full / adagradselect / topk_grad / random /
lisa / grass) and ``methods/lora.py`` for LoRA; they are resolved through
the string-keyed registry in ``methods/registry.py``.

``make_train_step`` / ``make_lora_train_step`` / ``init_lora_state`` remain
as thin compatibility shims over the registry so existing callers and
checkpointed workflows keep working.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, OptimizerConfig, SelectConfig
from repro.core import adagradselect, masked_adamw, partition as part_mod
from repro.models import registry


# ----------------------------------------------------------------- loss


def next_token_loss(logits, tokens, loss_mask, shift: int = 1):
    """Masked CE: position t predicts token t+shift. Computed as
    gathered-logit minus logsumexp so no [B,S,V] f32 tensor is ever
    materialized (the f32 reduction fuses)."""
    if shift:
        logits = logits[:, :-shift]
        targets = tokens[:, shift:]
        mask = loss_mask[:, shift:]
    else:
        targets, mask = tokens, loss_mask
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ll = picked.astype(jnp.float32) - lse
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.sum(ll * mask) / denom


def model_loss(model, cfg: ModelConfig, params, batch, *, mesh=None,
               batch_axes=("data",), masks=None):
    logits, aux, extra = model.apply_train(params, cfg, batch, mesh=mesh,
                                           batch_axes=batch_axes, masks=masks)
    loss = next_token_loss(logits, batch["tokens"], batch["loss_mask"])
    total = loss + aux
    if "mtp_logits" in extra:
        mtp = next_token_loss(extra["mtp_logits"], batch["tokens"],
                              batch["loss_mask"], shift=2)
        total = total + cfg.mtp_loss_weight * mtp
    return total, {"ce_loss": loss, "aux_loss": aux}


def accumulate_grads(loss_fn, params, batch, n_micro: int,
                     accum_dtype=jnp.float32):
    """Mean grads over microbatches via lax.scan (gradient accumulation)."""
    if n_micro <= 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def resh(x):
        return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

    micro = jax.tree.map(resh, batch)

    def body(carry, mb):
        acc, loss_acc, m_acc = carry
        (loss, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(jnp.add, acc, g)
        m_acc = jax.tree.map(jnp.add, m_acc, met)
        return (acc, loss_acc + loss, m_acc), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    met0 = {"ce_loss": jnp.zeros((), jnp.float32),
            "aux_loss": jnp.zeros((), jnp.float32)}
    (gacc, loss, macc), _ = jax.lax.scan(
        body, (zeros, jnp.zeros((), jnp.float32), met0), micro)
    scale = 1.0 / n_micro
    grads = jax.tree.map(lambda g, p: (g * scale).astype(p.dtype), gacc, params)
    met = jax.tree.map(lambda m: m * scale, macc)
    return (loss * scale, met), grads


# ----------------------------------------------------------------- state


def init_train_state(model_cfg: ModelConfig, seed: int = 0,
                     moment_dtype=jnp.float32,
                     policy: str = "adagradselect",
                     select_k: int | None = None,
                     moment_residency: str = "device",
                     store_policy: str = "host",
                     mesh=None) -> dict:
    """TrainState for the masked-selection family: params + masked-AdamW
    moments + the policy's selection-state pytree.

    ``moment_residency == "device"`` (default): ``state["opt"]`` is the
    dense layout ``{"m", "v", "counts"}`` with full-shape moments.
    ``moment_residency == "banked"``: ``state["opt"]`` is the compact
    layout ``{"banks", "slot_map", "counts", "store"}`` — [k]-slot device
    moment banks over a full store placed per ``store_policy`` ("host" ->
    host RAM; "zero1" -> device, sharded 1/dp over ``mesh``'s data axis;
    see masked_adamw.init_banked_opt_state). ``select_k`` caps
    the slot count (and the selection state's static ``indices`` length);
    default: ``num_blocks``."""
    model = registry.get(model_cfg)
    partition = part_mod.build_partition(model_cfg)
    params = model.init(jax.random.PRNGKey(seed), model_cfg)
    if moment_residency == "banked":
        if store_policy == "zero1" and mesh is None:
            # an UNSHARDED device store on top of the banks would be
            # strictly worse than dense zero1 — the sharded layout needs a
            # mesh to place its 1/dp shards, so reject instead of degrading
            raise ValueError(
                "moment_residency='banked' with offload='zero1' requires a "
                "mesh (the full store is sharded 1/dp over the data axis); "
                "pass Trainer(..., mesh=...) / launch.train --mesh, use "
                "offload='host' for the paper's host-resident store, or "
                "moment_residency='device' to keep dense ZeRO-1 moments")
        store = {"host": "host", "zero1": "zero1"}.get(store_policy, "device")
        k = select_k if select_k is not None else partition.num_blocks
        opt = masked_adamw.init_banked_opt_state(
            partition, params, k, moment_dtype, store_policy=store,
            mesh=mesh)
    elif moment_residency == "device":
        opt = masked_adamw.init_opt_state(partition, params, moment_dtype)
    else:
        raise ValueError(f"unknown moment_residency {moment_residency!r}; "
                         f"expected 'device' or 'banked'")
    return {
        "params": params,
        "opt": opt,
        "sel": adagradselect.init_state(partition.num_blocks, seed,
                                        policy=policy, k=select_k),
        "step": jnp.zeros((), jnp.int32),
    }


def train_state_shapes(model_cfg: ModelConfig, seed: int = 0,
                       policy: str = "adagradselect"):
    return jax.eval_shape(partial(init_train_state, model_cfg, policy=policy),
                          seed)


# ----------------------------------------------- compatibility shims


def make_train_step(model_cfg: ModelConfig, sel_cfg: SelectConfig,
                    opt_cfg: OptimizerConfig, *, mesh=None,
                    batch_axes=("data",), use_pallas: bool = False,
                    donate: bool = True):
    """Shim -> methods/selection.py (kept for existing callers)."""
    from repro.methods.selection import SelectionMethod
    method = SelectionMethod(name=sel_cfg.policy, sel_cfg=sel_cfg)
    return method.make_step(model_cfg, opt_cfg, mesh=mesh,
                            batch_axes=batch_axes, use_pallas=use_pallas,
                            donate=donate)


def make_lora_train_step(model_cfg: ModelConfig, opt_cfg: OptimizerConfig, *,
                         mesh=None, batch_axes=("data",), donate: bool = True):
    """Shim -> methods/lora.py (kept for existing callers)."""
    from repro.methods.lora import LoRAMethod
    return LoRAMethod().make_step(model_cfg, opt_cfg, mesh=mesh,
                                  batch_axes=batch_axes, donate=donate)


def init_lora_state(model_cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    seed: int = 0) -> dict:
    """Shim -> methods/lora.py (kept for existing callers)."""
    from repro.methods.lora import LoRAMethod
    return LoRAMethod().init_state(model_cfg, opt_cfg, seed)
