"""Training loop: logging, checkpointing, straggler watchdog, eval, restore.

Runs the same code path single-device (tests/examples) and distributed
(launch/train.py passes a mesh + sharded state). Fault-tolerance contract:
  * `checkpoint_every` saves are async + atomic, include the full TrainState
    (bandit statistics included) and the data cursor IS the step counter;
  * on start, `maybe_restore()` resumes from the latest checkpoint;
  * a step-time EWMA watchdog flags stragglers (> tau * EWMA) and calls the
    configurable `on_straggler` hook (default: log; production: abort to the
    last checkpoint so the scheduler can replace the slow host).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data import loader as data_loader
from repro.train import step as step_mod


@dataclass
class TrainLog:
    steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    metrics: list = field(default_factory=list)


class Trainer:
    def __init__(self, tcfg: TrainConfig, *, mesh=None, batch_axes=("data",),
                 method: str = "adagradselect", data_source=None,
                 batch_shardings=None, on_straggler=None, use_pallas=False):
        self.tcfg = tcfg
        self.mesh = mesh
        self.method = method
        self.batch_shardings = batch_shardings
        self.on_straggler = on_straggler or (lambda step, dt, ewma: None)
        mcfg = tcfg.model
        if method == "lora":
            self.state = step_mod.init_lora_state(mcfg, tcfg.optimizer, tcfg.seed)
            self.step_fn = step_mod.make_lora_train_step(
                mcfg, tcfg.optimizer, mesh=mesh, batch_axes=batch_axes)
        else:
            sel = tcfg.select if method == "adagradselect" else \
                tcfg.select.__class__(**{**tcfg.select.__dict__, "policy": method})
            self.sel_cfg = sel
            self.state = step_mod.init_train_state(mcfg, tcfg.seed)
            self.step_fn = step_mod.make_train_step(
                mcfg, sel, tcfg.optimizer, mesh=mesh, batch_axes=batch_axes,
                use_pallas=use_pallas)
        self.data = data_source or data_loader.make_source(
            "synthetic_math", seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed)
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir, tcfg.checkpoint_keep)
                     if tcfg.checkpoint_dir else None)
        self.log = TrainLog()
        self._ewma = None

    # ------------------------------------------------------------- resume
    def maybe_restore(self) -> int:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return 0
        self.state, step = self.ckpt.restore(self.state)
        return step

    # ------------------------------------------------------------- loop
    def _device_batch(self, batch: dict):
        if self.batch_shardings is not None:
            return jax.tree.map(jax.device_put, batch, self.batch_shardings)
        return batch

    def train(self, steps: int | None = None, start_step: int | None = None):
        tcfg = self.tcfg
        steps = steps if steps is not None else tcfg.steps
        step0 = start_step if start_step is not None else int(self.state["step"])
        for step in range(step0, step0 + steps):
            batch = self._device_batch(self.data.batch_at(step))
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            loss = float(metrics["loss"])  # blocks; keeps timing honest
            dt = time.perf_counter() - t0

            # straggler watchdog (EWMA of step time, warmup-excluded)
            if step > step0 + 2:
                self._ewma = dt if self._ewma is None else \
                    0.9 * self._ewma + 0.1 * dt
                if self._ewma and dt > tcfg.straggler_tau * self._ewma:
                    self.on_straggler(step, dt, self._ewma)

            self.log.steps.append(step)
            self.log.losses.append(loss)
            self.log.step_times.append(dt)
            if tcfg.log_every and step % tcfg.log_every == 0:
                small = {k: np.asarray(v).tolist() for k, v in metrics.items()
                         if np.ndim(v) == 0}
                self.log.metrics.append({"step": step, **small})
            if (self.ckpt is not None and tcfg.checkpoint_every
                    and (step + 1) % tcfg.checkpoint_every == 0):
                self.ckpt.save(step + 1, self.state)
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.log
