"""Training loop: logging, checkpointing, straggler watchdog, eval, restore.

Method-agnostic: the fine-tuning method (full FT, AdaGradSelect and the
other selection policies, LoRA, ...) is resolved through the
``repro.methods`` registry, which supplies the TrainState, the compiled step
function, and eval/accounting hooks — the trainer never inspects the method
name. Runs the same code path single-device (tests/examples) and distributed:
with ``mesh=...`` the trainer shards the batch over the mesh's batch axes
(global_batch must divide the dp degree), places the TrainState per the
method's ``state_shardings()`` tree (params/moments sharded or replicated,
HOST_RESIDENT leaves left in host RAM), and hands the sharding tree to
``make_step`` so compiled steps pin their outputs to the same layout
(compile-once under data parallelism). Fault-tolerance contract:
  * `checkpoint_every` saves are async + atomic and include the full
    TrainState (method state included) plus the data cursor: for legacy
    pure-f(step) sources the cursor IS the step counter; streaming pipelines
    (repro.data.pipeline) serialize their record cursor into the checkpoint
    meta and resume the packed stream exactly;
  * on start, `maybe_restore()` resumes from the latest checkpoint;

Data enters through an iterator seam: ``_batch_stream`` yields
``(host_batch, cursor_after)`` pairs (legacy ``batch_at`` sources ride a
StepIndexedAdapter), and with ``prefetch_depth > 0`` a background
``Prefetcher`` builds and device_puts up to that many batches ahead
(respecting the mesh batch sharding) so host batch construction overlaps
device compute. Prefetch on/off changes timing only — trajectories are
bit-identical.
  * a step-time EWMA watchdog flags stragglers (> tau * EWMA) and calls the
    configurable `on_straggler` hook (default: log; production: abort to the
    last checkpoint so the scheduler can replace the slow host).

Scalar materialization is deferred to `log_every` boundaries: between
boundaries the loop only enqueues compiled steps (losses are kept as device
scalars), and at a boundary a single `block_until_ready` drains the pipeline
so the per-step timing EWMA stays honest (boundary timings are the window
average). `log_every=0` — or passing a custom `on_straggler` hook, which
needs true per-step times so a single slow step is never averaged away —
syncs every step (the exact-timing mode benchmarks rely on).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro import methods, obs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data import loader as data_loader


@dataclass
class TrainLog:
    steps: list = field(default_factory=list)
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    metrics: list = field(default_factory=list)


def _place_state(state, shardings):
    """device_put every leaf onto its sharding; HOST_RESIDENT markers (the
    banked slot_map / "host"-policy store) stay numpy in host RAM."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s)
        if isinstance(s, jax.sharding.Sharding) else x,
        state, shardings)


class Trainer:
    def __init__(self, tcfg: TrainConfig, *, mesh=None, batch_axes=("data",),
                 method: str | None = None, data_source=None,
                 batch_shardings=None, on_straggler=None, use_pallas=False,
                 prefetch_depth: int = 0):
        self.tcfg = tcfg
        self.mesh = mesh
        self.method_name = method or tcfg.method
        self.method = methods.build(self.method_name, tcfg)
        self.sel_cfg = getattr(self.method, "sel_cfg", tcfg.select)
        self.batch_shardings = batch_shardings
        self._watchdog_active = on_straggler is not None
        self.on_straggler = on_straggler or (lambda step, dt, ewma: None)
        init_kw = {"mesh": mesh} if mesh is not None else {}
        self.state = self.method.init_state(tcfg.model, tcfg.optimizer,
                                            tcfg.seed, **init_kw)

        # -- data-parallel placement: shard/replicate the TrainState per the
        # method's sharding tree and shard the batch over the mesh's batch
        # axes. The same code path runs single-device when mesh is None.
        self.state_shardings = None
        step_kw = {}
        if mesh is not None and hasattr(self.method, "state_shardings"):
            self.state_shardings = self.method.state_shardings(
                tcfg.model, tcfg.optimizer, self.state, mesh)
            self.state = _place_state(self.state, self.state_shardings)
            step_kw["state_shardings"] = self.state_shardings
        if mesh is not None and batch_shardings is None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            baxes = tuple(a for a in batch_axes if a in mesh.axis_names)
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            dp = 1
            for a in baxes:
                dp *= sizes[a]
            if tcfg.global_batch % max(1, dp):
                raise ValueError(
                    f"global_batch={tcfg.global_batch} must be divisible by "
                    f"the data-parallel degree {dp} (mesh axes {baxes})")
            self._batch_sharding = NamedSharding(mesh, P(baxes))
        else:
            self._batch_sharding = None

        self.step_fn = self.method.make_step(
            tcfg.model, tcfg.optimizer, mesh=mesh, batch_axes=batch_axes,
            use_pallas=use_pallas, **step_kw)
        self.data = data_source or data_loader.make_source(
            "synthetic_math", seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed)
        self.prefetch_depth = prefetch_depth
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir, tcfg.checkpoint_keep)
                     if tcfg.checkpoint_dir else None)
        self.log = TrainLog()
        self._ewma = None
        self._data_cursor = None  # cursor AFTER the last consumed batch

        # always-on registry instruments (host-side, sub-µs; the tracing/
        # selection-telemetry syncs below are gated on obs.enabled())
        self._m_steps = obs.metrics.counter("steps", subsystem="train")
        self._m_step_time = obs.metrics.histogram("step_time_us",
                                                  subsystem="train")
        self._m_stragglers = obs.metrics.counter("stragglers",
                                                 subsystem="train")
        self._m_loss = obs.metrics.gauge("last_loss", subsystem="train")

    # ------------------------------------------------------------- resume
    def maybe_restore(self) -> int:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return 0
        # shardings re-place restored (numpy) leaves onto the current mesh —
        # the sharded-store round-trip and elastic resharding both land here
        self.state, step = self.ckpt.restore(
            self.state, shardings=self.state_shardings)
        # streaming sources (data/pipeline) resume their record stream from
        # the cursor saved next to the TrainState; pure-f(step) sources need
        # only the step counter (their "cursor" is implicit)
        cursor = self.ckpt.load_meta(step).get("data_cursor")
        if cursor is not None and hasattr(self.data, "restore_cursor"):
            self.data.restore_cursor(cursor)
        return step

    # ------------------------------------------------------------- loop
    def _device_batch(self, batch: dict):
        if self.batch_shardings is not None:
            return jax.tree.map(jax.device_put, batch, self.batch_shardings)
        if self._batch_sharding is not None:
            return jax.tree.map(
                lambda x: jax.device_put(x, self._batch_sharding), batch)
        return batch

    def _batch_stream(self, step0: int, steps: int):
        """(host_batch, cursor_after) pairs for the next ``steps`` steps.

        Streaming pipelines (anything with ``.batches``) iterate from their
        committed cursor; legacy pure-``f(step)`` sources go through the
        StepIndexedAdapter. Either way the generator never mutates source
        state — the loop commits consumption via ``restore_cursor`` — so a
        prefetcher may run it arbitrarily far ahead."""
        if hasattr(self.data, "batches"):
            return self.data.batches(steps)
        from repro.data.pipeline import StepIndexedAdapter
        return StepIndexedAdapter(self.data, step0).batches(steps)

    def train(self, steps: int | None = None, start_step: int | None = None):
        tcfg = self.tcfg
        steps = steps if steps is not None else tcfg.steps
        step0 = start_step if start_step is not None else int(self.state["step"])
        last = step0 + steps - 1
        pending = []  # (step, device-scalar loss) since the last boundary
        t0 = time.perf_counter()
        from repro.data.pipeline import Prefetcher
        fetch = Prefetcher(self._batch_stream(step0, steps),
                           self._device_batch, depth=self.prefetch_depth)
        try:
            self._train_loop(tcfg, fetch, step0, steps, last, pending, t0)
        finally:
            fetch.close()
            # async banked streaming: join any in-flight boundary dispatch
            # before the caller can read/checkpoint/donate the state it
            # references (the job mutates the host store in place)
            planner = getattr(self.step_fn, "swap_planner", None)
            if planner is not None:
                planner.quiesce()
            # commit consumption: read-ahead must not advance the stream
            # past what the loop actually trained on
            if (self._data_cursor is not None
                    and hasattr(self.data, "restore_cursor")):
                self.data.restore_cursor(self._data_cursor)
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.log

    def _train_loop(self, tcfg, fetch, step0, steps, last, pending, t0):
        sel_trace = obs.selection_trace()
        for step in range(step0, step0 + steps):
            batch, self._data_cursor = next(fetch)
            if not pending:
                t0 = time.perf_counter()
            with obs.span("train_step", {"step": step} if obs.enabled()
                          else None):
                self.state, metrics = self.step_fn(self.state, batch)
            pending.append((step, metrics["loss"]))

            # selection telemetry (obs-enabled only: pulling the mask off
            # the device is a host sync the disabled contract forbids). The
            # recorded mask is the one this step's update applied, so the
            # accumulated counts reproduce state["opt"]["counts"] exactly.
            if sel_trace is not None and metrics.get("mask") is not None:
                sel_trace.record(step, np.asarray(metrics["mask"]),
                                 np.asarray(metrics["block_norms"])
                                 if metrics.get("block_norms") is not None
                                 else None)

            at_log = tcfg.log_every and step % tcfg.log_every == 0
            if (at_log or step == last or not tcfg.log_every
                    or self._watchdog_active):
                with obs.span("log_sync"):
                    jax.block_until_ready(metrics["loss"])
                dt = (time.perf_counter() - t0) / len(pending)
                # steps/losses/step_times extend together at the boundary so
                # the lists never misalign if the loop exits mid-window
                self.log.steps.extend(s for s, _ in pending)
                self.log.losses.extend(float(np.asarray(x))
                                       for _, x in pending)
                self.log.step_times.extend([dt] * len(pending))
                self._m_steps.inc(len(pending))
                self._m_step_time.record(dt * 1e6)
                self._m_loss.set(self.log.losses[-1])
                pending = []

                # straggler watchdog (EWMA of step time, warmup-excluded)
                if step > step0 + 2:
                    self._ewma = dt if self._ewma is None else \
                        0.9 * self._ewma + 0.1 * dt
                    if self._ewma and dt > tcfg.straggler_tau * self._ewma:
                        self._m_stragglers.inc()
                        obs.instant("straggler",
                                    {"step": step, "dt_s": dt,
                                     "ewma_s": self._ewma})
                        self.on_straggler(step, dt, self._ewma)

            if at_log:
                small = {k: np.asarray(v).tolist() for k, v in metrics.items()
                         if np.ndim(v) == 0}
                self.log.metrics.append({"step": step, **small})
            if (self.ckpt is not None and tcfg.checkpoint_every
                    and (step + 1) % tcfg.checkpoint_every == 0):
                # an in-flight boundary dispatch holds references into the
                # banks/store about to be snapshotted (and writes the host
                # store in place) — barrier it out before saving
                planner = getattr(self.step_fn, "swap_planner", None)
                if planner is not None:
                    planner.quiesce()
                # the data cursor rides along in meta.json: restoring this
                # checkpoint resumes the record stream exactly after the
                # batch consumed at `step` (no skips, no repeats)
                self.ckpt.save(step + 1, self.state,
                               extra_meta={"data_cursor": self._data_cursor})
