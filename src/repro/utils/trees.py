"""Pytree path utilities shared across the framework.

Every subsystem that needs per-parameter behaviour (block partitioning,
sharding rules, LoRA targeting, checkpoint naming) keys off the same
canonical "/"-joined path strings produced here, so the conventions live
in exactly one place.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def path_str(path: tuple) -> str:
    """Canonical string for a jax.tree_util key path: 'layers/attn/wq'."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:  # FlattenedIndexKey or raw
            parts.append(str(getattr(k, "key", k)))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any, *rest: Any) -> Any:
    """tree_map where fn receives the canonical path string first."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x, *r: fn(path_str(p), x, *r), tree, *rest
    )


def tree_leaves_with_path(tree: Any) -> list[tuple[str, Any]]:
    return [
        (path_str(p), leaf)
        for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def first_prefix(path: str) -> str:
    return path.split("/", 1)[0]
