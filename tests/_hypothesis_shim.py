"""Property-test shim: re-exports `hypothesis` when installed, otherwise a
tiny deterministic stand-in so the suite still collects and runs.

The fallback implements only what this repo's tests use — ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``sampled_from`` / ``text`` strategies. Each decorated test
runs ``max_examples`` times with samples drawn from a fixed-seed PRNG, so
failures reproduce. Install the real dependency (requirements-dev.txt) for
shrinking, edge-case generation, and the full strategy library.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10
    _SEED = 0xADA6

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rnd: random.Random):
            return self._draw(rnd)

    class st:  # noqa: N801 — mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=2**30):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def text(min_size=0, max_size=40):
            """Unicode strings mixing ASCII, multi-byte BMP, and astral
            codepoints (surrogates excluded — not encodable to UTF-8)."""
            pools = ((0x20, 0x7E), (0xA0, 0x2FF), (0x400, 0x4FF),
                     (0x4E00, 0x4FFF), (0x1F300, 0x1F5FF))

            def draw(r):
                n = r.randint(min_size, max_size)
                return "".join(chr(r.randint(*r.choice(pools)))
                               for _ in range(n))
            return _Strategy(draw)

    def settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples or _DEFAULT_MAX_EXAMPLES
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
                rnd = random.Random(_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # no functools.wraps: pytest must NOT see the original signature,
            # or it would treat the strategy kwargs as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
