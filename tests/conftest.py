import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def run_multidevice(code: str, num_devices: int = 8, timeout: int = 300):
    """Run ``code`` in a subprocess with a forced host device count (tests
    must not set XLA_FLAGS in-process — jax locks devices on first init)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={num_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice
