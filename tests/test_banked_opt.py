"""Banked optimizer state ([k]-slot device moment banks + host-resident
full store) against the dense masked-AdamW oracle: trajectory exactness,
swap semantics, static shapes, residency accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.core import adagradselect, masked_adamw, offload
from repro.core import partition as pmod
from repro.models import registry
from repro.train.trainer import Trainer

TINY = ModelConfig(name="banked-tiny", family="dense", num_layers=4,
                   d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
                   d_ff=32, vocab_size=17, dtype="float32", remat="none",
                   tie_embeddings=False)

ALL_POLICIES = adagradselect.available_policies()


def _grads_like(params, step: int):
    """Deterministic synthetic grads that vary per step."""
    def one(path_seed, p):
        base = jnp.cos(1.0 * step + path_seed
                       + jnp.arange(p.size, dtype=jnp.float32))
        return (0.01 * base.reshape(p.shape)).astype(p.dtype)
    leaves, treedef = jax.tree.flatten(params)
    return jax.tree.unflatten(
        treedef, [one(float(i), p) for i, p in enumerate(leaves)])


def _sel_cfg(policy: str) -> SelectConfig:
    return SelectConfig(policy=policy, k_percent=40, steps_per_epoch=4,
                        epsilon_decay=0.1, lisa_interval=3,
                        always_include=(0,))


def _tcfg(residency: str, steps: int = 6, policy: str = "adagradselect",
          **opt_kw) -> TrainConfig:
    return TrainConfig(
        model=TINY,
        select=SelectConfig(policy=policy, k_percent=40, steps_per_epoch=10,
                            epsilon_decay=0.05),
        optimizer=OptimizerConfig(
            lr=1e-2, schedule="constant", warmup_steps=0,
            moment_residency=residency,
            offload="host" if residency == "banked" else "none", **opt_kw),
        seq_len=48, global_batch=4, steps=steps, seed=0, log_every=0)


# ----------------------------------------------------- oracle parity


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_banked_bit_exact_vs_dense_oracle(policy):
    """Multi-interval run: identical (grads, mask, lr) sequences through the
    banked layout and the dense ``masked_adamw.update`` oracle must give
    bit-exact params AND moments at every step — including across lisa
    interval boundaries and re-admission of previously evicted blocks."""
    part = pmod.build_partition(TINY)
    model = registry.get(TINY)
    params = model.init(jax.random.PRNGKey(0), TINY)
    sel_cfg = _sel_cfg(policy)
    nb = part.num_blocks
    cap = min(nb, sel_cfg.num_selected(nb) + len(sel_cfg.always_include))
    ocfg = OptimizerConfig(lr=1e-2, weight_decay=0.01)

    params_d, opt_d = params, masked_adamw.init_opt_state(part, params)
    params_b = params
    opt_b = masked_adamw.init_banked_opt_state(part, params, cap)
    sel_state = adagradselect.init_state(nb, seed=3, policy=policy, k=cap)

    for step in range(7):
        grads = _grads_like(params_b, step)
        norms = pmod.block_grad_norms(part, grads)
        mask, sel_state = adagradselect.select(sel_cfg, sel_state, norms, nb)
        assert sel_state["indices"].shape == (cap,)

        params_d, opt_d = masked_adamw.update(ocfg, part, params_d, grads,
                                              opt_d, mask, 1e-2)
        banks, slot_map, store = masked_adamw.swap_banked(
            part, opt_b["banks"], opt_b["store"], opt_b["slot_map"],
            np.asarray(mask))
        params_b, banks, counts = masked_adamw.banked_update(
            ocfg, part, params_b, grads, banks, opt_b["counts"], mask, 1e-2)
        opt_b = {"banks": banks, "slot_map": slot_map, "counts": counts,
                 "store": store}

        for a, b in zip(jax.tree.leaves(params_d), jax.tree.leaves(params_b)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        m_full, v_full = masked_adamw.materialize_moments(part, opt_b)
        for a, b in zip(jax.tree.leaves(opt_d["m"]), jax.tree.leaves(m_full)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt_d["v"]), jax.tree.leaves(v_full)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(opt_d["counts"]),
                                      np.asarray(opt_b["counts"]))


def test_banked_trainer_matches_dense_trainer():
    """End-to-end: the banked two-phase step reproduces the fused dense
    step's trajectory through the real Trainer."""
    t_dense = Trainer(_tcfg("device"), method="adagradselect")
    t_bank = Trainer(_tcfg("banked"), method="adagradselect")
    ld, lb = t_dense.train(), t_bank.train()
    np.testing.assert_allclose(ld.losses, lb.losses, rtol=0, atol=2e-6)
    for a, b in zip(jax.tree.leaves(t_dense.state["params"]),
                    jax.tree.leaves(t_bank.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    part = pmod.build_partition(TINY)
    m_full, _ = masked_adamw.materialize_moments(part, t_bank.state["opt"])
    for a, b in zip(jax.tree.leaves(t_dense.state["opt"]["m"]),
                    jax.tree.leaves(m_full)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_async_swap_trajectory_bit_exact(policy):
    """The overlapped boundary may never change the trajectory. For every
    registered policy: banked + async streaming == banked synchronous ==
    the dense trainer, bit for bit — a prediction hit commits exactly the
    rows the synchronous path would have staged, and a miss falls back to
    that path. Also pins the planner's accounting: async-on dispatches,
    async-off never does."""
    t_dense = Trainer(_tcfg("device", policy=policy), method=policy)
    t_sync = Trainer(_tcfg("banked", policy=policy, async_swap=False),
                     method=policy)
    t_async = Trainer(_tcfg("banked", policy=policy, async_swap=True),
                      method=policy)
    ld, ls, la = t_dense.train(), t_sync.train(), t_async.train()
    np.testing.assert_array_equal(ld.losses, la.losses)
    np.testing.assert_array_equal(ls.losses, la.losses)
    for a, b in zip(jax.tree.leaves(t_sync.state["params"]),
                    jax.tree.leaves(t_async.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(t_dense.state["params"]),
                    jax.tree.leaves(t_async.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    part = pmod.build_partition(TINY)
    m_sync, v_sync = masked_adamw.materialize_moments(part,
                                                      t_sync.state["opt"])
    m_async, v_async = masked_adamw.materialize_moments(part,
                                                        t_async.state["opt"])
    for a, b in zip(jax.tree.leaves((m_sync, v_sync)),
                    jax.tree.leaves((m_async, v_async))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    on, off = t_async.step_fn.swap_stats, t_sync.step_fn.swap_stats
    assert on.dispatches > 0
    assert off.dispatches == 0 and off.predicted_hits == 0
    # the overlapped driver still compiles each phase exactly once
    assert t_async.step_fn.forward_select._cache_size() == 1
    assert t_async.step_fn.apply._cache_size() == 1


def test_banked_pallas_path_matches_dense_pallas():
    """Fused Pallas kernel on bank rows == dense Pallas on full leaves."""
    part = pmod.build_partition(TINY)
    model = registry.get(TINY)
    params = model.init(jax.random.PRNGKey(1), TINY)
    ocfg = OptimizerConfig(lr=1e-2)
    nb, cap = part.num_blocks, 3
    mask = jnp.zeros((nb,), jnp.bool_).at[jnp.array([1, 2, 4])].set(True)

    params_d, opt_d = masked_adamw.update(
        ocfg, part, params, _grads_like(params, 0),
        masked_adamw.init_opt_state(part, params), mask, 1e-2,
        use_pallas=True)
    opt_b = masked_adamw.init_banked_opt_state(part, params, cap)
    banks, slot_map, store = masked_adamw.swap_banked(
        part, opt_b["banks"], opt_b["store"], opt_b["slot_map"],
        np.asarray(mask))
    params_b, banks, counts = masked_adamw.banked_update(
        ocfg, part, params, _grads_like(params, 0), banks, opt_b["counts"],
        mask, 1e-2, use_pallas=True)
    for a, b in zip(jax.tree.leaves(params_d), jax.tree.leaves(params_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


# ----------------------------------------------------- swap semantics


def test_swap_zero_init_and_eviction_writeback():
    part = pmod.build_partition(TINY)
    model = registry.get(TINY)
    params = model.init(jax.random.PRNGKey(0), TINY)
    ocfg = OptimizerConfig(lr=1e-2)
    nb = part.num_blocks
    opt = masked_adamw.init_banked_opt_state(part, params, 2)
    assert (opt["slot_map"] == -1).all()  # nothing resident initially

    # step 1: blocks 1 and 2 selected — admitted with zero moments
    mask1 = np.zeros((nb,), bool)
    mask1[[1, 2]] = True
    banks, slot_map, store = masked_adamw.swap_banked(
        part, opt["banks"], opt["store"], opt["slot_map"], mask1)
    g = part.group("layers")
    assert set(slot_map[[1, 2]]) == {0, 1} and (slot_map[[0, 3]] == -1).all()
    p2, banks, counts = masked_adamw.banked_update(
        ocfg, part, params, _grads_like(params, 0), banks, opt["counts"],
        jnp.asarray(mask1), 1e-2)
    leaf = jax.tree.leaves(banks["layers"]["m"])[0]
    assert np.abs(np.asarray(leaf)).sum() > 0  # moments were written

    # step 2: block 1 evicted (moments go back to the store bit-exact),
    # block 3 admitted (zero rows — first selection)
    mask2 = np.zeros((nb,), bool)
    mask2[[2, 3]] = True
    m_before, _ = masked_adamw.materialize_moments(
        part, {"banks": banks, "store": store, "slot_map": slot_map})
    banks2, slot_map2, store2 = masked_adamw.swap_banked(
        part, banks, store, slot_map, mask2)
    assert slot_map2[1] == -1 and slot_map2[3] >= 0
    b1 = 1 - g.start  # local index of block 1 in the layers group
    for st_leaf, m_leaf in zip(jax.tree.leaves(store2["layers"]["m"]),
                               jax.tree.leaves(m_before["layers"])):
        np.testing.assert_array_equal(np.asarray(st_leaf)[b1],
                                      np.asarray(m_leaf)[b1])
    slots2 = np.asarray(banks2["layers"]["slots"])
    s3 = int(np.nonzero(slots2 == (3 - g.start))[0][0])
    for bl in jax.tree.leaves(banks2["layers"]["m"]):
        assert (np.asarray(bl)[s3] == 0).all()  # zero-init on first selection

    # unchanged mask within an interval: swap is a no-op
    banks3, slot_map3, _ = masked_adamw.swap_banked(
        part, banks2, store2, slot_map2, mask2)
    np.testing.assert_array_equal(slot_map3, slot_map2)
    assert banks3["layers"] is banks2["layers"]


def test_swap_overflow_raises():
    part = pmod.build_partition(TINY)
    model = registry.get(TINY)
    params = model.init(jax.random.PRNGKey(0), TINY)
    opt = masked_adamw.init_banked_opt_state(part, params, 1)  # 1 slot
    mask = np.zeros((part.num_blocks,), bool)
    mask[[1, 2]] = True  # two layer blocks into one slot
    with pytest.raises(RuntimeError, match="bank overflow"):
        masked_adamw.swap_banked(part, opt["banks"], opt["store"],
                                 opt["slot_map"], mask)


# ----------------------------------------------------- static shapes


def test_banked_step_compiles_once_across_selection_changes():
    """Per-step selection (random policy redraws every step) must never
    recompile either banked phase: masks/slots are runtime vectors."""
    tr = Trainer(_tcfg("banked", steps=5, policy="random"), method="random")
    tr.train()
    fwd, apply = tr.step_fn.forward_select, tr.step_fn.apply
    if hasattr(fwd, "_cache_size"):
        assert fwd._cache_size() == 1
        assert apply._cache_size() == 1


def test_selected_indices_static_shape_and_padding():
    mask = jnp.array([True, False, True, False, False, True])
    idx = adagradselect.selected_indices(mask, 4)
    assert idx.shape == (4,)
    assert idx.tolist() == [0, 2, 5, 6]  # padded with num_blocks


# ----------------------------------------------------- residency accounting


def test_banked_resident_bytes_under_half_of_full():
    """Acceptance criterion: k~1/3 of blocks -> measured device-resident
    optimizer bytes <= 50% of the full-FT dense baseline."""
    deep = TINY.replace(num_layers=12, tie_embeddings=True)  # 14 blocks
    tcfg = TrainConfig(
        model=deep, select=SelectConfig(k_percent=33.0),
        optimizer=OptimizerConfig(moment_residency="banked", offload="host"),
        seq_len=32, global_batch=2, steps=1, log_every=0)
    from repro import methods
    banked_state = methods.build("adagradselect", tcfg).init_state(
        deep, tcfg.optimizer)
    dense_opt = masked_adamw.init_opt_state(
        pmod.build_partition(deep),
        banked_state["params"])
    banked = offload.resident_opt_bytes(banked_state["opt"])
    dense = offload.resident_opt_bytes(dense_opt)
    assert banked["device"] <= 0.5 * dense["device"], (banked, dense)
    assert banked["host"] > 0  # the full store lives in host RAM


def test_banked_rejects_zero1_store_without_mesh():
    """Without a mesh there is nothing to shard the store over — an
    unsharded device store on top of the banks would be strictly worse than
    dense ZeRO-1, so init still rejects (with the mesh hint). With a mesh
    the store shards 1/dp instead: tests/test_sharded_train.py."""
    from repro.train import step as step_mod
    with pytest.raises(ValueError, match="zero1.*mesh|mesh.*zero1"):
        step_mod.init_train_state(TINY, moment_residency="banked",
                                  store_policy="zero1")


def test_ensure_store_residency_after_restore_roundtrip():
    """Checkpoint restore materializes every leaf as numpy; the step must
    re-place a device-resident store back on device (and leave a host
    store alone)."""
    part = pmod.build_partition(TINY)
    model = registry.get(TINY)
    params = model.init(jax.random.PRNGKey(0), TINY)
    store_dev = offload.init_full_store(part, params, policy="device")
    as_restored = jax.tree.map(np.asarray, store_dev)  # all-numpy
    back = offload.ensure_store_residency(as_restored, "none")
    assert not isinstance(jax.tree.leaves(back)[0], np.ndarray)
    store_host = offload.init_full_store(part, params, policy="host")
    same = offload.ensure_store_residency(store_host, "host")
    assert jax.tree.leaves(same)[0] is jax.tree.leaves(store_host)[0]


def test_trainable_report_resident_column():
    t_dense = Trainer(_tcfg("device", steps=1), method="adagradselect")
    t_bank = Trainer(_tcfg("banked", steps=1), method="adagradselect")
    rd = t_dense.method.trainable_param_report(TINY, t_dense.state)
    rb = t_bank.method.trainable_param_report(TINY, t_bank.state)
    assert rb.opt_bytes_resident < rd.opt_bytes_resident
    assert rd.opt_bytes == rb.opt_bytes  # §3.3 model unchanged by residency


# ----------------------------------------------------- trainer log fix


def test_trainlog_lists_stay_aligned_on_midwindow_exit():
    """steps/losses/step_times extend atomically at sync boundaries, so an
    exception mid-window cannot leave the lists misaligned."""
    tr = Trainer(_tcfg("device", steps=10), method="random")
    tr.tcfg = tr.tcfg.__class__(**{**tr.tcfg.__dict__, "log_every": 4})
    real_step, calls = tr.step_fn, []

    def exploding(state, batch):
        calls.append(1)
        if len(calls) == 6:
            raise RuntimeError("boom")
        return real_step(state, batch)

    tr.step_fn = exploding
    with pytest.raises(RuntimeError, match="boom"):
        tr.train(steps=10)
    assert len(tr.log.steps) == len(tr.log.losses) == len(tr.log.step_times)
