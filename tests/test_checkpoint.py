"""Fault-tolerance: checkpoint roundtrip, GC, async, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_smoke_config
from repro.train import step as step_mod


@pytest.fixture
def state():
    cfg = get_smoke_config("llama3.2-1b")
    return step_mod.init_train_state(cfg, seed=0)


def test_roundtrip_bitexact(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(7, state)
    restored, step = mgr.restore(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray(s)})
    assert mgr.all_steps() == [3, 4]


def test_async_save_waits_and_surfaces(tmp_path, state):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, state)
    mgr.wait()
    assert mgr.all_steps() == [1]
    # atomicity: no tmp dirs left behind
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]


def test_restore_latest_of_many(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, {"w": jnp.full((3,), float(s))})
    restored, step = mgr.restore({"w": jnp.zeros(3)})
    assert step == 30 and float(restored["w"][0]) == 30.0


def test_training_resume_equivalence(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3 — identical
    final params (data is a pure function of the step; selection PRNG is
    folded with the step)."""
    from repro.configs.base import OptimizerConfig, SelectConfig, TrainConfig
    from repro.train.trainer import Trainer
    cfg = get_smoke_config("qwen2.5-0.5b").replace(remat="none")
    def mk(ckdir):
        return TrainConfig(
            model=cfg,
            select=SelectConfig(policy="adagradselect", k_percent=40),
            optimizer=OptimizerConfig(lr=1e-3, schedule="constant",
                                      warmup_steps=0),
            seq_len=48, global_batch=4, steps=6, log_every=0,
            checkpoint_dir=ckdir, checkpoint_every=3, checkpoint_keep=3)

    t1 = Trainer(mk(""), method="adagradselect")
    t1.train(steps=6)

    t2 = Trainer(mk(str(tmp_path)), method="adagradselect")
    t2.train(steps=3)
    t3 = Trainer(mk(str(tmp_path)), method="adagradselect")
    start = t3.maybe_restore()
    assert start == 3
    t3.train(steps=3, start_step=start)

    for a, b in zip(jax.tree.leaves(t1.state["params"]),
                    jax.tree.leaves(t3.state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def _banked_tcfg(ckdir, policy="lisa", steps=6):
    """Banked-residency config whose mask changes mid-run (lisa interval 4:
    a checkpoint at step 3 lands mid-selection-interval)."""
    from repro.configs.base import OptimizerConfig, SelectConfig, TrainConfig
    cfg = get_smoke_config("qwen2.5-0.5b").replace(remat="none")
    return TrainConfig(
        model=cfg,
        select=SelectConfig(policy=policy, k_percent=40, lisa_interval=4),
        optimizer=OptimizerConfig(lr=1e-3, schedule="constant",
                                  warmup_steps=0, moment_residency="banked",
                                  offload="host"),
        seq_len=48, global_batch=4, steps=steps, log_every=0,
        checkpoint_dir=ckdir, checkpoint_every=3, checkpoint_keep=3)


@pytest.mark.parametrize("policy", ["lisa", "adagradselect"])
def test_banked_training_resume_equivalence(tmp_path, policy):
    """Banked state (device banks + slot_map + host-resident full store)
    saved mid-selection-interval, restored, and continued must match an
    uninterrupted run — params AND materialized moments."""
    from repro.core import masked_adamw
    from repro.core import partition as pmod
    from repro.train.trainer import Trainer

    t1 = Trainer(_banked_tcfg("", policy), method=policy)
    t1.train(steps=6)

    ckdir = str(tmp_path / policy)
    t2 = Trainer(_banked_tcfg(ckdir, policy), method=policy)
    t2.train(steps=3)
    t3 = Trainer(_banked_tcfg(ckdir, policy), method=policy)
    start = t3.maybe_restore()
    assert start == 3
    # slot_map + store round-tripped through the checkpoint
    np.testing.assert_array_equal(np.asarray(t3.state["opt"]["slot_map"]),
                                  np.asarray(t2.state["opt"]["slot_map"]))
    t3.train(steps=3, start_step=start)

    for a, b in zip(jax.tree.leaves(t1.state["params"]),
                    jax.tree.leaves(t3.state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
    part = pmod.build_partition(t1.tcfg.model)
    m1, v1 = masked_adamw.materialize_moments(part, t1.state["opt"])
    m3, v3 = masked_adamw.materialize_moments(part, t3.state["opt"])
    for a, b in zip(jax.tree.leaves((m1, v1)), jax.tree.leaves((m3, v3))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7)


def test_banked_state_roundtrip_bitexact(tmp_path):
    """The banked opt layout (incl. numpy host store + slot_map) flattens
    and restores bit-exactly through the npz format."""
    cfg = get_smoke_config("llama3.2-1b")
    state = step_mod.init_train_state(cfg, seed=0, select_k=3,
                                      moment_residency="banked",
                                      store_policy="host")
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(4, state)
    restored, step = mgr.restore(state)
    assert step == 4
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_snapshots_host_store(tmp_path):
    """In-place mutation of the host store after save() must not leak into
    the serialized snapshot (the writer owns a copy)."""
    store_leaf = np.arange(8, dtype=np.float32)
    state = {"opt": {"store": {"x": store_leaf}}}
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(1, state)
    store_leaf[:] = -1.0  # simulates the next step's swap_banked write-back
    mgr.wait()
    restored, _ = mgr.restore({"opt": {"store": {"x": np.zeros(8,
                                                              np.float32)}}})
    np.testing.assert_array_equal(restored["opt"]["store"]["x"],
                                  np.arange(8, dtype=np.float32))


def test_elastic_restore_across_device_counts(multidevice):
    """Save on a 4-device (2,2) mesh, restore+reshard onto (4,2) and (1,1):
    the restart-based elasticity path."""
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.elastic import reshard_state, validate_rescale

d = tempfile.mkdtemp()
mesh1 = jax.make_mesh((2, 2), ("data", "model"), devices=jax.devices()[:4])
w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
state = {"w": jax.device_put(w, NamedSharding(mesh1, P("data", "model")))}
mgr = CheckpointManager(d, async_save=False)
mgr.save(5, state)

mesh2 = jax.make_mesh((4, 2), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("data", "model"))}
restored, step = mgr.restore({"w": jnp.zeros((8, 8))}, shardings=sh2)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding.num_devices == 8
validate_rescale((2, 2), (4, 2), global_batch=8)
try:
    validate_rescale((2, 2), (4, 2), global_batch=6)
    raise SystemExit("should have raised")
except ValueError:
    pass
print("OK", step)
""")
    assert "OK 5" in out
