"""Data pipeline: determinism, resume, masks, answer parsing, jsonl packing."""
import json

import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.data import loader, synthetic, tokenizer


def test_batch_determinism_and_disjoint_steps():
    cfg = synthetic.MathTaskConfig(digits=3, seq_len=64)
    b1 = synthetic.batch_at(cfg, 5, 8)
    b2 = synthetic.batch_at(cfg, 5, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic.batch_at(cfg, 6, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_loss_mask_covers_completion_only():
    cfg = synthetic.MathTaskConfig(digits=3, seq_len=64)
    toks, mask = synthetic.sample_problem(cfg, 123)
    p = synthetic.prompt_len(cfg)
    assert mask[:p].sum() == 0
    assert mask[p:].sum() > 0
    assert (toks[mask == 0][1 + p:] == synthetic.PAD).all() if False else True
    # masked-out tail is padding
    last = int(np.max(np.nonzero(mask)))
    assert (toks[last + 1:] == synthetic.PAD).all()


@settings(max_examples=25, deadline=None)
@given(idx=st.integers(0, 10_000))
def test_answer_roundtrip(idx):
    cfg = synthetic.MathTaskConfig(digits=3, seq_len=64)
    toks, _ = synthetic.sample_problem(cfg, cfg.eval_offset + idx)
    assert synthetic.decode_answer(toks) == synthetic.answer_of(cfg, idx)


def test_eval_and_train_streams_disjoint():
    cfg = synthetic.MathTaskConfig(digits=3, seq_len=64)
    tr = synthetic.batch_at(cfg, 0, 4)["tokens"]
    ev = synthetic.batch_at(cfg, 0, 4, eval_split=True)["tokens"]
    assert not np.array_equal(tr, ev)


def test_host_local_slice():
    batch = {"tokens": np.arange(32).reshape(8, 4)}
    s0 = loader.host_local_slice(batch, 0, 2)
    s1 = loader.host_local_slice(batch, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), batch["tokens"])


def test_host_local_slice_rejects_nondivisible_batch():
    """Silently dropping trailing rows would desync the global batch across
    process counts — must raise instead."""
    batch = {"tokens": np.arange(28).reshape(7, 4)}
    with pytest.raises(ValueError, match="divisible by process_count=2"):
        loader.host_local_slice(batch, 0, 2)


def test_jsonl_source_packs(tmp_path):
    p = tmp_path / "docs.jsonl"
    with open(p, "w") as f:
        for i in range(4):
            f.write(json.dumps({"text": f"hello world {i} " * 10}) + "\n")
    src = loader.JsonlSource(str(p), seq_len=32, global_batch=2)
    b = src.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    b2 = src.batch_at(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


def test_jsonl_source_pads_short_corpus(tmp_path):
    """A corpus shorter than one row must pad the tail, not crash in the
    ring reshape."""
    p = tmp_path / "tiny.jsonl"
    with open(p, "w") as f:
        f.write(json.dumps({"text": "hi"}) + "\n")
    src = loader.JsonlSource(str(p), seq_len=32, global_batch=2)
    b = src.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    # the real tokens survive, the tail is PAD and loss-masked out
    assert tokenizer.decode(b["tokens"][0]) == "hi"
    n_real = len(tokenizer.encode("hi"))
    assert (b["tokens"][0][n_real:] == tokenizer.PAD).all()
    assert (b["loss_mask"][0][n_real:] == 0).all()


def test_jsonl_source_empty_corpus_actionable(tmp_path):
    p = tmp_path / "empty.jsonl"
    p.write_text("")
    with pytest.raises(ValueError, match="empty"):
        loader.JsonlSource(str(p), seq_len=32, global_batch=2)


def test_byte_tokenizer_roundtrip():
    s = "AdaGradSelect: 3 + 4 = 7 ✓"
    ids = tokenizer.encode(s)
    assert tokenizer.decode(ids) == s


@settings(max_examples=50, deadline=None)
@given(s=st.text(max_size=64))
def test_byte_tokenizer_roundtrip_property(s):
    """encode/decode is the identity on arbitrary unicode text, with and
    without BOS/EOS framing."""
    assert tokenizer.decode(tokenizer.encode(s)) == s
    assert tokenizer.decode(
        tokenizer.encode(s, add_bos=False, add_eos=False)) == s


@settings(max_examples=25, deadline=None)
@given(s=st.text(max_size=32))
def test_byte_tokenizer_framing_and_stripping(s):
    """BOS/EOS land exactly where requested; decode strips every special id
    (PAD padding included) without touching content bytes."""
    ids = tokenizer.encode(s)
    assert ids[0] == tokenizer.BOS and ids[-1] == tokenizer.EOS
    assert len(ids) == len(s.encode("utf-8")) + 2
    bare = tokenizer.encode(s, add_bos=False, add_eos=False)
    assert (len(bare) == 0
            or (bare[0] != tokenizer.BOS and bare[-1] != tokenizer.EOS))
    padded = np.concatenate(
        [ids, np.full(7, tokenizer.PAD, np.int32)])
    assert tokenizer.decode(padded) == s
