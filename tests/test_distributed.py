"""Distribution: sharding rules, dry-run cells on a tiny mesh, gradient
compression, HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.distributed import sharding as sh
from repro.launch.hlo_cost import analyze_text


def test_hlo_cost_scan_trip_counts():
    def body(x, _):
        return x @ x, None

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = jax.jit(scanned).lower(x).compile().as_text()
    ct = analyze_text(txt, 1)
    assert abs(ct.flops - 10 * 2 * 128**3) / (10 * 2 * 128**3) < 1e-6
    assert ct.unknown_trip_whiles == 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_resolve(arch, multidevice=None):
    """Every leaf gets a spec whose sharded dims divide-or-pad legally."""
    cfg = get_smoke_config(arch)
    from repro.models import registry
    model = registry.get(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg), jax.random.PRNGKey(0))
    # a fake mesh-dims view is enough to exercise the rule table
    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((2, 4))
    specs = sh.param_specs(cfg, shapes, FakeMesh())
    n_sharded = sum(
        1 for s in jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
        if any(p is not None for p in s))
    assert n_sharded > 0


@pytest.mark.parametrize("m", [1, 2, 4])
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_spec_rules_every_family(arch, m):
    """The rules table, leaf by leaf, over every registered model family
    (dense / MoE / SSM / MLA / hybrid / encdec / vlm) x model-axis sizes
    {1, 2, 4}: every leaf must get a spec that (a) fits the leaf's rank,
    (b) names only mesh axes, (c) never repeats an axis, and (d) follows
    the kv-head rule — kv projections shard over "model" iff the kv-head
    count divides the axis, else they fall back to replicated."""
    from jax.sharding import PartitionSpec as P

    from repro.utils.trees import path_str, tree_leaves_with_path

    cfg = get_smoke_config(arch)
    from repro.models import registry
    model = registry.get(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((2, m))

    flat_shapes = dict(tree_leaves_with_path(shapes))
    specs = sh.param_specs(cfg, shapes, FakeMesh())
    flat_specs = {
        path_str(p): s for p, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]}
    assert set(flat_specs) == set(flat_shapes)
    for path, spec in flat_specs.items():
        leaf = flat_shapes[path]
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        used = []
        for part in spec:
            for ax in (part if isinstance(part, tuple) else (part,)):
                if ax is not None:
                    used.append(ax)
        assert all(ax in ("data", "model") for ax in used), (path, spec)
        assert len(used) == len(set(used)), f"axis repeated: {path} {spec}"
        base = path.split("/")[-1]
        stacked = path.split("/")[0].endswith("layers")
        if base in ("wk", "wv"):
            kvh = leaf.shape[-2]
            model_sharded = any(
                ax == "model"
                for part in spec
                for ax in (part if isinstance(part, tuple) else (part,)))
            assert model_sharded == (kvh % m == 0), \
                f"kv rule violated: {path} kvh={kvh} m={m} spec={spec}"
        if stacked and len(spec) > 0:
            # the stacked layer axis is never sharded by the param rules
            assert spec[0] is None, (path, spec)


def test_dryrun_cells_tiny_mesh(multidevice):
    """Lower+compile train/prefill/decode for representative archs on a
    (2,4) mesh in a subprocess — the structural core of deliverable (e)."""
    out = multidevice("""
import sys
sys.argv = ["dryrun"]
from repro.launch.dryrun import run_cell
from repro.configs import get_smoke_config
ok = 0
cells = [("llama3.2-1b", "train_4k"), ("qwen3-moe-30b-a3b", "train_4k"),
         ("mamba2-2.7b", "decode_32k"), ("zamba2-7b", "decode_32k"),
         ("seamless-m4t-medium", "prefill_32k"), ("paligemma-3b", "train_4k"),
         ("deepseek-v3-671b", "train_4k")]
for arch, shape in cells:
    cfg = get_smoke_config(arch).replace(ssm_chunk=32)
    r = run_cell(arch, shape, "tiny", cfg_override=cfg, verbose=False)
    assert r["status"] == "ok", (arch, shape, r.get("error"), r.get("traceback"))
    assert r["roofline"]["flops_per_chip"] > 0
    ok += 1
print("OK", ok)
""", num_devices=8, timeout=560)
    assert "OK 7" in out


def test_grad_compression_error_feedback(multidevice):
    out = multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed import sharding as shrd
from repro.distributed.compression import (compressed_grad_sync,
                                           init_error_state,
                                           quantize_with_feedback)
# error feedback: repeated quantization converges to within one bf16
# quantum / n (the EF residual bound)
g = jnp.full((64,), 1.0 + 2**-12, jnp.float32)  # not bf16-representable
err = jnp.zeros_like(g)
tot = jnp.zeros_like(g)
for _ in range(64):
    q, err = quantize_with_feedback(g, err)
    tot = tot + q.astype(jnp.float32)
np.testing.assert_allclose(np.asarray(tot / 64), np.asarray(g), atol=2**-8/32)

# shard_map psum path: values exact for bf16-representable grads; the
# payload enters the reduce through a bf16 quantization (XLA:CPU promotes
# the wire dtype to f32 — TPU keeps bf16 — so we assert the quantize
# convert exists, not the wire dtype)
mesh = jax.make_mesh((4,), ("data",))
def body(g, e):
    return compressed_grad_sync({"g": g}, {"g": e}, mesh, axes=("data",))
g_loc = jnp.arange(8.0)
f = jax.jit(shrd.shard_map(body, mesh=mesh,
                           in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data"))))
synced, e2 = f(jnp.tile(g_loc, 4).reshape(32), jnp.zeros(32))
np.testing.assert_allclose(np.asarray(synced["g"][:8]), np.asarray(g_loc))
hlo = f.lower(jnp.zeros(32), jnp.zeros(32)).compile().as_text()
assert "all-reduce" in hlo and "bf16[" in hlo
print("OK")
""")
    assert "OK" in out


def test_mesh_device_count_error_message():
    """make_mesh / make_production_mesh raise an actionable error (with the
    XLA_FLAGS hint) when the device count does not match, instead of jax's
    opaque failure."""
    from repro.configs.base import MeshConfig
    from repro.launch import mesh as mesh_mod

    n = len(jax.devices())
    bad = MeshConfig((n + 1, 1), ("data", "model"))
    with pytest.raises(ValueError) as ei:
        mesh_mod.make_mesh(bad)
    msg = str(ei.value)
    assert f"needs {n + 1} devices" in msg
    assert f"found {n}" in msg
    assert f"--xla_force_host_platform_device_count={n + 1}" in msg
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        mesh_mod.make_production_mesh()


def test_zero1_moment_sharding(multidevice):
    out = multidevice("""
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs import get_smoke_config
from repro.configs.base import MeshConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh
from repro.models import registry
cfg = get_smoke_config("llama3.2-1b")
mesh = make_mesh(MeshConfig((2, 4), ("data", "model")))
model = registry.get(cfg)
shapes = jax.eval_shape(lambda k: model.init(k, cfg), jax.random.PRNGKey(0))
specs = sh.param_specs(cfg, shapes, mesh)
z1 = sh.apply_zero1(specs, shapes, mesh)
import jax.tree_util as jtu
n_extra = 0
for s0, s1 in zip(jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index")),
                  jax.tree.leaves(z1, is_leaf=lambda x: hasattr(x, "index"))):
    if tuple(s0) != tuple(s1):
        n_extra += 1
        assert "data" in [p for p in s1 if p]
assert n_extra > 0, "zero1 sharded nothing"
print("OK", n_extra)
""")
    assert "OK" in out
