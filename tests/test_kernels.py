"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-5)


class TestBlockGradNorm:
    @pytest.mark.parametrize("shape", [(3, 100), (2, 64, 65), (5, 7, 9, 11),
                                       (1, 2048), (4, 4096)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, dtype):
        g = (jax.random.normal(jax.random.PRNGKey(0), shape) * 2).astype(dtype)
        out = ops.block_grad_sq_norms(g)
        expect = ref.block_grad_sq_norms(g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-3)

    def test_under_jit(self):
        g = jax.random.normal(jax.random.PRNGKey(1), (4, 333))
        out = jax.jit(ops.block_grad_sq_norms)(g)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(ref.block_grad_sq_norms(g)),
                                   rtol=1e-5)


class TestMaskedAdamW:
    @pytest.mark.parametrize("shape", [(4, 100), (2, 32, 9), (3, 2048)])
    @pytest.mark.parametrize("pdtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, pdtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        nl = shape[0]
        p = jax.random.normal(ks[0], shape).astype(pdtype)
        g = (0.1 * jax.random.normal(ks[1], shape)).astype(pdtype)
        m = 0.01 * jax.random.normal(ks[2], shape)
        v = 0.001 * jnp.abs(jax.random.normal(ks[3], shape))
        sel = jnp.asarray(np.arange(nl) % 2, jnp.float32)
        cnt = jnp.arange(1, nl + 1, dtype=jnp.float32)
        args = (1e-2, 0.9, 0.999, 1e-8, 0.01)
        po, mo, vo = ops.masked_adamw(p, g, m, v, sel, cnt, *args)
        l2 = shape[0]
        flat = lambda t: t.reshape(l2, -1)  # noqa: E731
        pr, mr, vr = ref.masked_adamw(flat(p), flat(g), flat(m), flat(v),
                                      sel, cnt, *args)
        np.testing.assert_allclose(np.asarray(po, np.float32).reshape(l2, -1),
                                   np.asarray(pr, np.float32), **_tol(pdtype))
        np.testing.assert_allclose(np.asarray(mo).reshape(l2, -1),
                                   np.asarray(mr), rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(np.asarray(vo).reshape(l2, -1),
                                   np.asarray(vr), rtol=1e-4, atol=1e-8)


class TestFlashAttention:
    @pytest.mark.parametrize("s", [128, 256, 384])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_fwd_sweep(self, s, dtype):
        b, h, d = 2, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = (0.5 * jax.random.normal(ks[0], (b, s, h, d))).astype(dtype)
        k = (0.5 * jax.random.normal(ks[1], (b, s, h, d))).astype(dtype)
        v = (0.5 * jax.random.normal(ks[2], (b, s, h, d))).astype(dtype)
        o = ops.flash_attention(q, k, v)
        fold = lambda t: t.transpose(0, 2, 1, 3)  # noqa: E731
        expect = ref.flash_attention(fold(q), fold(k), fold(v)).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(expect, np.float32), **_tol(dtype))

    def test_grads_match_ref(self):
        b, s, h, d = 1, 256, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q, k, v = (0.5 * jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        fold = lambda t: t.transpose(0, 2, 1, 3)  # noqa: E731

        def lk(q, k, v):
            return jnp.sum(ops.flash_attention(q, k, v) ** 2)

        def lr(q, k, v):
            return jnp.sum(ref.flash_attention(fold(q), fold(k), fold(v)) ** 2)

        gk = jax.grad(lk, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-4)

    def _packed_segments(self, b, s):
        """Variable-length packed layout incl. a pad (0) tail and segments
        crossing the 128-tile boundaries."""
        seg = np.zeros((b, s), np.int32)
        seg[0, :s // 3] = 1
        seg[0, s // 3:s - 40] = 2
        seg[0, s - 40:s - 16] = 3
        seg[1, :150] = 1
        seg[1, 150:] = 2
        return jnp.asarray(seg)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_segmented_fwd(self, dtype):
        b, s, h, d = 2, 256, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = (0.5 * jax.random.normal(ks[0], (b, s, h, d))).astype(dtype)
        k = (0.5 * jax.random.normal(ks[1], (b, s, h, d))).astype(dtype)
        v = (0.5 * jax.random.normal(ks[2], (b, s, h, d))).astype(dtype)
        seg = self._packed_segments(b, s)
        o = ops.flash_attention(q, k, v, segment_ids=seg)
        fold = lambda t: t.transpose(0, 2, 1, 3)  # noqa: E731
        expect = ref.flash_attention(fold(q), fold(k), fold(v),
                                     segment_ids=seg).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(expect, np.float32),
                                   **_tol(dtype))

    def test_segmented_grads_match_ref(self):
        b, s, h, d = 2, 256, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q, k, v = (0.5 * jax.random.normal(kk, (b, s, h, d)) for kk in ks)
        seg = self._packed_segments(b, s)
        fold = lambda t: t.transpose(0, 2, 1, 3)  # noqa: E731

        def lk(q, k, v):
            return jnp.sum(ops.flash_attention(q, k, v,
                                               segment_ids=seg) ** 2)

        def lr(q, k, v):
            return jnp.sum(ref.flash_attention(fold(q), fold(k), fold(v),
                                               segment_ids=seg) ** 2)

        gk = jax.grad(lk, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       rtol=1e-4, atol=1e-4)


class TestDecodeAttention:
    @pytest.mark.parametrize("s,valid", [(512, 100), (1024, 1024), (2048, 7)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, s, valid, dtype):
        b, h, d = 2, 4, 64
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = (jax.random.normal(ks[0], (b, 1, h, d))).astype(dtype)
        k = (0.5 * jax.random.normal(ks[1], (b, s, h, d))).astype(dtype)
        v = (0.5 * jax.random.normal(ks[2], (b, s, h, d))).astype(dtype)
        o = ops.decode_attention(q, k, v, valid)
        fold = lambda t: t.transpose(0, 2, 1, 3)  # noqa: E731
        expect = ref.decode_attention(q.reshape(b, h, d), fold(k), fold(v), valid)
        np.testing.assert_allclose(np.asarray(o.reshape(b, h, d), np.float32),
                                   np.asarray(expect, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("s,valids", [(512, (1, 100, 512)),
                                          (1024, (7, 1024, 333))])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vector_valid_len(self, s, valids, dtype):
        """Per-row valid_len (continuous-batching slots at mixed progress)
        must match the oracle row for row."""
        b, h, d = len(valids), 4, 64
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = (jax.random.normal(ks[0], (b, 1, h, d))).astype(dtype)
        k = (0.5 * jax.random.normal(ks[1], (b, s, h, d))).astype(dtype)
        v = (0.5 * jax.random.normal(ks[2], (b, s, h, d))).astype(dtype)
        vl = jnp.asarray(valids, jnp.int32)
        o = ops.decode_attention(q, k, v, vl)
        fold = lambda t: t.transpose(0, 2, 1, 3)  # noqa: E731
        expect = ref.decode_attention(q.reshape(b, h, d), fold(k), fold(v), vl)
        np.testing.assert_allclose(np.asarray(o.reshape(b, h, d), np.float32),
                                   np.asarray(expect, np.float32), **_tol(dtype))
        # each row must equal the scalar-valid_len result for its own length
        for i, v_i in enumerate(valids):
            solo = ops.decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                        v_i)
            np.testing.assert_allclose(np.asarray(o[i], np.float32),
                                       np.asarray(solo[0], np.float32),
                                       rtol=1e-6, atol=1e-6)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(8, 128), (2, 16, 256), (3, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, shape, dtype):
        x = jax.random.normal(jax.random.PRNGKey(3), shape).astype(dtype)
        sc = (1 + 0.1 * jax.random.normal(jax.random.PRNGKey(4),
                                          (shape[-1],))).astype(dtype)
        o = ops.rmsnorm(x, sc)
        expect = ref.rmsnorm(x.reshape(-1, shape[-1]), sc).reshape(shape)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(expect, np.float32), **_tol(dtype))


class TestPagedDecodeAttention:
    """Paged flash-decoding: pool + scalar-prefetched page tables must match
    both the pure-jnp oracle and the dense kernel on the gathered view."""

    def _pool(self, seed, num_pages, ps, kvh, d, dtype):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        k = (0.5 * jax.random.normal(ks[0], (num_pages, ps, kvh, d))).astype(dtype)
        v = (0.5 * jax.random.normal(ks[1], (num_pages, ps, kvh, d))).astype(dtype)
        return k, v

    @pytest.mark.parametrize("ps", [4, 8, 16])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_page_size_sweep_vs_ref(self, ps, dtype):
        """Scrambled (non-contiguous) page tables with sentinel entries and
        per-row valid_len, GQA hmap — pinned against the jnp oracle."""
        b, h, kvh, d = 3, 4, 2, 64
        num_pages, maxp = 20, 5
        hmap = jnp.asarray([0, 0, 1, 1], jnp.int32)
        k_pool, v_pool = self._pool(7, num_pages, ps, kvh, d, dtype)
        rng = np.random.default_rng(11)
        perm = rng.permutation(num_pages)
        tbl = np.full((b, maxp), num_pages, np.int32)  # sentinel-filled
        vl = np.asarray([1, 2 * ps + 1, maxp * ps], np.int32)
        used = 0
        for i in range(b):
            n = -(-int(vl[i]) // ps)
            tbl[i, :n] = perm[used:used + n]
            used += n
        q = jax.random.normal(jax.random.PRNGKey(1), (b, 1, h, d)).astype(dtype)
        o = ops.paged_decode_attention(q, k_pool, v_pool, jnp.asarray(tbl),
                                       jnp.asarray(vl), hmap)
        expect = ref.paged_decode_attention(q.reshape(b, h, d), k_pool,
                                            v_pool, jnp.asarray(tbl),
                                            jnp.asarray(vl), hmap)
        np.testing.assert_allclose(np.asarray(o.reshape(b, h, d), np.float32),
                                   np.asarray(expect, np.float32),
                                   **_tol(dtype))

    @pytest.mark.parametrize("ps", [8, 16])
    def test_matches_dense_kernel_on_gathered_view(self, ps):
        """The paged kernel on (pool, table) must agree with the dense
        kernel run over the gathered head-expanded dense cache."""
        b, h, kvh, d = 2, 4, 2, 64
        maxp = 4
        num_pages = b * maxp
        hmap = jnp.asarray([0, 0, 1, 1], jnp.int32)
        k_pool, v_pool = self._pool(3, num_pages, ps, kvh, d, jnp.float32)
        rng = np.random.default_rng(5)
        tbl = rng.permutation(num_pages).reshape(b, maxp).astype(np.int32)
        vl = np.asarray([ps + 3, maxp * ps], np.int32)
        q = jax.random.normal(jax.random.PRNGKey(2), (b, 1, h, d))
        o = ops.paged_decode_attention(q, k_pool, v_pool, jnp.asarray(tbl),
                                       jnp.asarray(vl), hmap)
        # dense view: gather pages row-major, expand kv heads via hmap
        kd = k_pool[tbl].reshape(b, maxp * ps, kvh, d)[:, :, hmap, :]
        vd = v_pool[tbl].reshape(b, maxp * ps, kvh, d)[:, :, hmap, :]
        od = ops.decode_attention(q, kd, vd, jnp.asarray(vl))
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(od, np.float32),
                                   rtol=2e-5, atol=2e-5)
