"""LoRA baseline: targeting, zero-init identity, adapter-only training."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig
from repro.models import registry
from repro.optim import lora
from repro.train import step as step_mod
from repro.utils.trees import tree_leaves_with_path


def test_targets_qkvo_and_gud():
    cfg = get_smoke_config("llama3.2-1b")
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    lp = lora.init_lora(jax.random.PRNGKey(1), params, cfg, rank=4)
    bases = {p.split("/")[-1] for p in lp}
    assert bases == {"wq", "wk", "wv", "wo", "wg", "wu", "wd"}
    # stacked adapters carry the layer axis
    a = lp["layers/attn/wq"]["a"]
    assert a.shape[0] == cfg.num_layers and a.shape[-1] == 4


def test_zero_init_is_identity():
    cfg = get_smoke_config("llama3.2-1b")
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    lp = lora.init_lora(jax.random.PRNGKey(1), params, cfg, rank=4)
    merged = lora.merge(params, lp, cfg, rank=4, alpha=16)
    for (pa, la), (pb, lb) in zip(tree_leaves_with_path(params),
                                  tree_leaves_with_path(merged)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_merge_changes_only_targets():
    cfg = get_smoke_config("llama3.2-1b")
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    lp = lora.init_lora(jax.random.PRNGKey(1), params, cfg, rank=4)
    # make b nonzero
    lp = jax.tree.map(lambda x: x + 0.1, lp)
    merged = lora.merge(params, lp, cfg, rank=4, alpha=16)
    for path, leaf in tree_leaves_with_path(params):
        new = dict(tree_leaves_with_path(merged))[path]
        changed = bool(jnp.any(new != leaf))
        assert changed == (path in lp), path


def test_lora_training_reduces_loss():
    cfg = get_smoke_config("qwen2.5-0.5b").replace(remat="none")
    ocfg = OptimizerConfig(lr=5e-3, lora_rank=8, warmup_steps=2,
                           schedule="constant")
    state = step_mod.init_lora_state(cfg, ocfg, seed=0)
    fn = step_mod.make_lora_train_step(cfg, ocfg, donate=False)
    from repro.data import synthetic
    task = synthetic.MathTaskConfig(digits=2, seq_len=48)
    losses = []
    for step in range(25):
        b = synthetic.batch_at(task, step, 8)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "loss_mask": jnp.asarray(b["loss_mask"])}
        state, metrics = fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses
    # base must be untouched
    base2 = state["base"]
    model = registry.get(cfg)
    base0 = model.init(jax.random.PRNGKey(0), cfg)
    for a, b in zip(jax.tree.leaves(base0), jax.tree.leaves(base2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
