"""Masked AdamW: the paper's custom optimizer (freeze semantics + bias
correction) against the plain AdamW oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.configs import get_smoke_config
from repro.configs.base import OptimizerConfig
from repro.core import masked_adamw as mad
from repro.core import partition as pmod
from repro.models import registry
from repro.optim import adamw as plain


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("llama3.2-1b")
    part = pmod.build_partition(cfg)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    grads = jax.tree.map(
        lambda p: 0.01 * jax.random.normal(jax.random.PRNGKey(1), p.shape,
                                           jnp.float32).astype(p.dtype), params)
    return cfg, part, params, grads


def test_all_ones_equals_plain_adamw(setup):
    """mask == all-ones must reduce exactly to standard AdamW."""
    cfg, part, params, grads = setup
    ocfg = OptimizerConfig(lr=1e-2, weight_decay=0.01)
    ones = jnp.ones(part.num_blocks, bool)
    ms, os_ = mad.init_opt_state(part, params), plain.init_opt_state(params)
    p1, o1 = mad.update(ocfg, part, params, grads, ms, ones, 1e-2)
    p2, o2 = plain.update(ocfg, params, grads, os_, 1e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
    # two steps (bias correction must track)
    p1, o1 = mad.update(ocfg, part, p1, grads, o1, ones, 1e-2)
    p2, o2 = plain.update(ocfg, p2, grads, o2, 1e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_frozen_blocks_bit_identical(setup):
    cfg, part, params, grads = setup
    ocfg = OptimizerConfig(lr=1e-2)
    opt = mad.init_opt_state(part, params)
    mask = jnp.zeros(part.num_blocks, bool).at[1].set(True)
    p1, o1 = mad.update(ocfg, part, params, grads, opt, mask, 1e-2)
    for g in part.groups:
        for pn, po, mn, mo in zip(jax.tree.leaves(p1[g.key]),
                                  jax.tree.leaves(params[g.key]),
                                  jax.tree.leaves(o1["m"][g.key]),
                                  jax.tree.leaves(opt["m"][g.key])):
            if g.stacked:
                sel = np.asarray(mask[g.start:g.start + g.length])
                pn2 = np.asarray(pn, np.float32).reshape(g.length, -1)
                po2 = np.asarray(po, np.float32).reshape(g.length, -1)
                frozen = ~sel
                assert (pn2[frozen] == po2[frozen]).all()
                assert (pn2[sel] != po2[sel]).any() or not sel.any()
            else:
                same = (np.asarray(pn, np.float32) ==
                        np.asarray(po, np.float32)).all()
                assert same == (not bool(mask[g.start]))


def test_per_block_bias_correction(setup):
    """A block updated for the first time at global step 10 must be bias-
    corrected as t=1, not t=10 (the per-block counts mechanism)."""
    cfg, part, params, grads = setup
    ocfg = OptimizerConfig(lr=1e-3, weight_decay=0.0)
    # path A: update block 1 once (its count becomes 1)
    mask_b1 = jnp.zeros(part.num_blocks, bool).at[1].set(True)
    opt = mad.init_opt_state(part, params)
    pa, oa = mad.update(ocfg, part, params, grads, opt, mask_b1, 1e-3)
    # path B: 5 steps updating only block 2, then block 1
    mask_b2 = jnp.zeros(part.num_blocks, bool).at[2].set(True)
    pb, ob = params, mad.init_opt_state(part, params)
    for _ in range(5):
        pb, ob = mad.update(ocfg, part, pb, grads, ob, mask_b2, 1e-3)
    pb, ob = mad.update(ocfg, part, pb, grads, ob, mask_b1, 1e-3)
    # block 1's params must be identical in both paths (same single update)
    g = part.group("layers")
    for la, lb in zip(jax.tree.leaves(pa["layers"]), jax.tree.leaves(pb["layers"])):
        np.testing.assert_allclose(
            np.asarray(la, np.float32)[0], np.asarray(lb, np.float32)[0],
            atol=1e-7)


def test_clip_by_global_norm(setup):
    _, _, params, grads = setup
    clipped, norm = mad.clip_by_global_norm(grads, 0.001)
    new_norm = mad.global_grad_norm(clipped)
    assert float(new_norm) <= 0.0011
    clipped2, _ = mad.clip_by_global_norm(grads, 1e9)
    for a, b in zip(jax.tree.leaves(clipped2), jax.tree.leaves(grads)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100))
def test_property_update_direction(seed):
    """For any mask, selected params move opposite to m-hat sign on step 1
    (wd=0)."""
    key = jax.random.PRNGKey(seed)
    from repro.configs.base import ModelConfig
    cfg = ModelConfig(num_layers=2, d_model=16, num_heads=2, num_kv_heads=2,
                      head_dim=8, d_ff=32, vocab_size=17, dtype="float32")
    part = pmod.build_partition(cfg)
    model = registry.get(cfg)
    params = model.init(key, cfg)
    grads = jax.tree.map(lambda p: jnp.ones_like(p), params)
    ocfg = OptimizerConfig(lr=1e-2, weight_decay=0.0)
    opt = mad.init_opt_state(part, params)
    mask = jax.random.bernoulli(key, 0.5, (part.num_blocks,))
    mask = mask.at[0].set(True)
    p2, _ = mad.update(ocfg, part, params, grads, opt, mask, 1e-2)
    emb_delta = np.asarray(p2["embed"]["tok"] - params["embed"]["tok"])
    assert (emb_delta <= 0).all()  # grad>0 -> param decreases
