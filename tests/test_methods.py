"""Fine-tuning method registry: resolution, smoke runs, golden-seed parity.

The GOLDEN table was captured from the pre-refactor ``Trainer`` /
``make_train_step`` code path (and, for LoRA, from the first deterministic
revision — adapter init previously depended on per-process string-hash
salting) on this exact tiny config. The parity test asserts the registry
refactor reproduces those trajectories bit-for-bit-close.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import methods
from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.train.trainer import Trainer

GOLDEN_MODEL = ModelConfig(
    name="golden-tiny", family="dense", num_layers=3, d_model=32, num_heads=2,
    num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=32, dtype="float32",
    remat="none", tie_embeddings=False)

ALL_METHODS = ("full", "adagradselect", "topk_grad", "random", "lora",
               "lisa", "grass")

# 5 steps, seed 0, on GOLDEN_MODEL (see module docstring)
GOLDEN = {
    "adagradselect": {
        "losses": [3.947706, 3.383842, 3.053774, 2.758202, 2.788784],
        "fp": 4618.3515625,
        "final_mask": [0, 1, 0, 0, 0, 1],
    },
    "topk_grad": {
        "losses": [3.947706, 3.383842, 3.053774, 2.758202, 2.70422],
        "fp": 4616.29443359375,
        "final_mask": [0, 1, 0, 0, 0, 1],
    },
    "random": {
        "losses": [3.947706, 3.437435, 3.253561, 3.049162, 2.837551],
        "fp": 4605.08447265625,
        "final_mask": [0, 1, 0, 0, 1, 0],
    },
    "full": {
        "losses": [3.947706, 3.291163, 2.890966, 2.702341, 2.628693],
        "fp": 4652.72705078125,
        "final_mask": [1, 1, 1, 1, 1, 1],
    },
    "lora": {
        "losses": [3.947706, 3.402235, 3.240843, 3.049545, 2.876902],
        "fp": 495.78143310546875,
        "final_mask": None,
    },
}


def _tcfg(steps=5):
    return TrainConfig(
        model=GOLDEN_MODEL,
        select=SelectConfig(policy="adagradselect", k_percent=40,
                            steps_per_epoch=10, epsilon_decay=0.05),
        optimizer=OptimizerConfig(lr=1e-2, schedule="constant", warmup_steps=0,
                                  lora_rank=4),
        seq_len=48, global_batch=4, steps=steps, seed=0, log_every=0)


def _fingerprint(tree):
    return float(sum(jnp.sum(jnp.abs(leaf.astype(jnp.float32)))
                     for leaf in jax.tree.leaves(tree)))


# ------------------------------------------------------------- resolution


def test_registry_resolves_all_methods():
    for name in ALL_METHODS:
        assert methods.get_method(name) is not None, name
    assert "all" in methods.available()  # full-FT alias


def test_registry_unknown_method_raises_with_alternatives():
    with pytest.raises(KeyError, match="available"):
        methods.get_method("does_not_exist")


def test_built_methods_satisfy_protocol():
    tcfg = _tcfg()
    for name in ALL_METHODS:
        m = methods.build(name, tcfg)
        assert isinstance(m, methods.FinetuneMethod), name


def test_trainer_is_method_agnostic():
    """The trainer must never branch on the method name."""
    import inspect
    src = inspect.getsource(Trainer)
    assert "lora" not in src and 'method ==' not in src


# ------------------------------------------------------------- smoke runs


@pytest.mark.parametrize("name", ALL_METHODS)
def test_every_method_runs_three_steps_finite(name):
    tr = Trainer(_tcfg(3), method=name)
    log = tr.train()
    assert len(log.losses) == 3
    assert np.isfinite(log.losses).all(), (name, log.losses)
    params = tr.method.eval_params(GOLDEN_MODEL, tr.tcfg.optimizer, tr.state)
    assert all(np.isfinite(np.asarray(leaf, np.float32)).all()
               for leaf in jax.tree.leaves(params)), name


@pytest.mark.parametrize("name", ALL_METHODS)
def test_trainable_param_report(name):
    tr = Trainer(_tcfg(1), method=name)
    rep = tr.method.trainable_param_report(GOLDEN_MODEL, tr.state)
    assert rep.num_params_total > 0
    assert 0 < rep.num_params_trainable <= rep.num_params_total
    assert rep.opt_bytes > 0
    full = 0.0 if name in ("lora",) else rep.trainable_fraction
    if name == "full":
        assert rep.num_params_trainable == rep.num_params_total, full


def test_method_from_train_config_field():
    tr = Trainer(_tcfg().__class__(**{**_tcfg().__dict__, "method": "random"}))
    assert tr.method_name == "random"
    assert tr.sel_cfg.policy == "random"


# ---------------------------------------------------------- golden parity


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_golden_seed_parity(name):
    """The registry refactor must reproduce the pre-refactor trajectories."""
    golden = GOLDEN[name]
    tr = Trainer(_tcfg(5), method=name)
    log = tr.train()
    np.testing.assert_allclose(log.losses, golden["losses"],
                               rtol=0, atol=2e-6, err_msg=name)
    params = tr.state["params"] if name != "lora" else tr.state["lora"]
    np.testing.assert_allclose(_fingerprint(params), golden["fp"],
                               rtol=1e-6, err_msg=name)
    if golden["final_mask"] is not None:
        mask = np.asarray(tr.state["sel"]["mask"]).astype(int).tolist()
        assert mask == golden["final_mask"], name


def test_all_ones_mask_reduces_to_plain_adamw():
    """Training with the 'full' method must equal a hand-rolled loop on the
    reference (unmasked) AdamW — i.e. mask == all-ones keeps the masked
    optimizer on the plain-AdamW path end to end."""
    from repro.core import masked_adamw
    from repro.data import loader as data_loader
    from repro.models import registry as model_registry
    from repro.optim import adamw as plain_adamw
    from repro.optim.schedules import learning_rate
    from repro.train import step as step_mod

    tcfg = _tcfg(3)
    ocfg = tcfg.optimizer
    tr = Trainer(tcfg, method="full")
    tr.train()

    model = model_registry.get(GOLDEN_MODEL)
    params = model.init(jax.random.PRNGKey(tcfg.seed), GOLDEN_MODEL)
    opt = plain_adamw.init_opt_state(params)
    data = data_loader.make_source("synthetic_math", seq_len=tcfg.seq_len,
                                   global_batch=tcfg.global_batch,
                                   seed=tcfg.seed)

    def loss_fn(p, b):
        return step_mod.model_loss(model, GOLDEN_MODEL, p, b)

    for step in range(3):
        batch = data.batch_at(step)
        (_, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        grads, _ = masked_adamw.clip_by_global_norm(grads, ocfg.grad_clip)
        lr = learning_rate(ocfg, jnp.asarray(step))
        params, opt = plain_adamw.update(ocfg, params, grads, opt, lr)

    # atol covers jit-vs-eager fusion drift; exact masked==plain equality at
    # the update level is asserted in test_masked_adamw.py
    for a, b in zip(jax.tree.leaves(tr.state["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-4)


# ------------------------------------------------------- zero1 moment wiring


def test_moment_shardings_zero1_uses_concrete_shapes():
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import offload

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shapes = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    specs = {"w": P(None, "model")}
    sh = offload.moment_shardings("zero1", specs, mesh, params_shapes=shapes)
    assert sh["w"].spec == P("data", "model")
    with pytest.raises(ValueError, match="params_shapes"):
        offload.moment_shardings("zero1", specs, mesh)
