"""Per-arch smoke tests (deliverable f): reduced config of the same family,
one forward + one train step on CPU, asserting shapes and no NaNs; plus
prefill/decode consistency against the train-time logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.configs.base import OptimizerConfig, SelectConfig
from repro.models import registry
from repro.train import step as step_mod

B, S = 2, 32


def _batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_frontend_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["src_embeds"] = 0.1 * jax.random.normal(
            key, (B, S // cfg.frontend_len_ratio, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch).replace(remat="none", ssm_chunk=16)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    logits, aux, extra = model.apply_train(params, cfg, _batch(cfg, jax.random.PRNGKey(1)))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))
    if cfg.mtp_depth:
        assert extra["mtp_logits"].shape == (B, S, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch).replace(remat="none", ssm_chunk=16)
    sel = SelectConfig(policy="adagradselect", k_percent=25)
    opt = OptimizerConfig(lr=1e-3)
    state = step_mod.init_train_state(cfg, seed=0)
    fn = step_mod.make_train_step(cfg, sel, opt, donate=False)
    state2, metrics = fn(state, _batch(cfg, jax.random.PRNGKey(2)))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    k = sel.num_selected(step_mod.part_mod.build_partition(cfg).num_blocks)
    assert int(metrics["num_selected"]) == k
    # selected params changed, step advanced
    assert int(state2["step"]) == 1
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(state2["params"]),
                        jax.tree.leaves(state["params"])))
    assert changed


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_train_logits(arch):
    cfg = get_smoke_config(arch).replace(remat="none", ssm_chunk=16)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(3))
    logits, _, _ = model.apply_train(params, cfg, batch)
    half = {k: (v[:, :S // 2] if k == "tokens" else v) for k, v in batch.items()}
    last, cache = model.prefill(params, cfg, half, max_len=S)
    errs = []
    for t in range(S // 2, S // 2 + 3):
        errs.append(float(jnp.max(jnp.abs(last - logits[:, t - 1]))))
        last, cache = model.decode_step(params, cfg,
                                        batch["tokens"][:, t:t + 1], cache)
    assert max(errs) < 5e-4, errs


def test_gated_weight_grads_equivalence():
    """gate_weight_grads: mask=1 -> grads equal ungated; mask=0 -> dW=0 but
    dx still flows (DESIGN 3.3)."""
    from repro.core.gated import gated_block_apply
    cfg = get_smoke_config("llama3.2-1b")
    from repro.models import blocks
    params = blocks.attn_block_init(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))

    def apply_fn(p, xx):
        return blocks.attn_block_apply(p, cfg, xx)

    def loss_gated(p, xx, m):
        y, _ = gated_block_apply(apply_fn, p, xx, m)
        return jnp.sum(y ** 2)

    def loss_plain(p, xx):
        y, _ = apply_fn(p, xx)
        return jnp.sum(y ** 2)

    g1 = jax.grad(loss_gated)(params, x, jnp.asarray(1.0))
    g0 = jax.grad(loss_gated)(params, x, jnp.asarray(0.0))
    gp = jax.grad(loss_plain)(params, x)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gp)):
        # separate param/activation vjp closures reassociate f32 sums
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
    assert all(not np.asarray(g).any() for g in jax.tree.leaves(g0))
    dx_gated = jax.grad(loss_gated, argnums=1)(params, x, jnp.asarray(0.0))
    dx_plain = jax.grad(loss_plain, argnums=1)(params, x)
    np.testing.assert_allclose(np.asarray(dx_gated), np.asarray(dx_plain),
                               atol=1e-5)
