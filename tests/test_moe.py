"""MoE: dense oracle semantics + EP (shard_map all-to-all) equivalence."""
import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import moe


def _cfg(**kw):
    base = dict(name="t", family="moe", d_model=32, num_experts=8,
                num_experts_per_tok=2, moe_d_ff=16, num_shared_experts=1,
                capacity_factor=8.0, dtype="float32", num_heads=4,
                num_kv_heads=4)
    base.update(kw)
    return ModelConfig(**base)


def test_dense_oracle_topk_semantics():
    """Dense path must equal an explicit per-token loop over its top-k."""
    cfg = _cfg(num_shared_experts=0)
    params = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 32))
    y, _ = moe.apply_dense(params, cfg, x)
    xt = x.reshape(-1, 32)
    gates, ids, _ = moe._route(cfg, params["router"], xt)
    manual = np.zeros((6, 32), np.float32)
    for t in range(6):
        for j in range(cfg.num_experts_per_tok):
            e = int(ids[t, j])
            h = jax.nn.silu(xt[t] @ params["wg"][e]) * (xt[t] @ params["wu"][e])
            manual[t] += float(gates[t, j]) * np.asarray(h @ params["wd"][e])
    np.testing.assert_allclose(np.asarray(y.reshape(6, 32)), manual, atol=1e-4)


def test_router_aux_loss_positive_and_finite():
    cfg = _cfg()
    params = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    _, aux = moe.apply_dense(params, cfg, x)
    assert np.isfinite(float(aux)) and float(aux) >= 1.0  # >= E * (1/E) bound


def test_ep_matches_dense_singledevice():
    """shard_map path on a (1,1) mesh is numerically the dense result."""
    cfg = _cfg(moe_impl="ep")
    params = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 32))
    from repro.configs.base import MeshConfig
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(MeshConfig((1, 1), ("data", "model")))
    y_ep, _ = jax.jit(lambda p, xx: moe.apply_ep(p, cfg, xx, mesh))(params, x)
    y_d, _ = moe.apply_dense(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_d), atol=1e-5)


def test_ep_multidevice_fwd_grad(multidevice):
    out = multidevice("""
import jax, jax.numpy as jnp
from repro.configs.base import MeshConfig, ModelConfig
from repro.launch.mesh import make_mesh
from repro.models.layers import moe
cfg = ModelConfig(name="t", family="moe", d_model=32, num_experts=16,
                  num_experts_per_tok=2, moe_d_ff=16, num_shared_experts=1,
                  capacity_factor=16.0, dtype="float32", num_heads=4,
                  num_kv_heads=4, moe_impl="ep", ep_axes=("model","data"))
params = moe.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
mesh = make_mesh(MeshConfig((2, 4), ("data", "model")))
y_d, _ = moe.apply_dense(params, cfg, x)
y_e, _ = jax.jit(lambda p, xx: moe.apply_ep(p, cfg, xx, mesh))(params, x)
err = float(jnp.max(jnp.abs(y_d - y_e)))
gd = jax.grad(lambda p: jnp.sum(moe.apply_dense(p, cfg, x)[0]**2))(params)
ge = jax.jit(jax.grad(lambda p: jnp.sum(moe.apply_ep(p, cfg, x, mesh)[0]**2)))(params)
gerr = max(float(jnp.max(jnp.abs(a-b))) for a, b in
           zip(jax.tree.leaves(gd), jax.tree.leaves(ge)))
yd_dec, _ = moe.apply_dense(params, cfg, x[:, :1])
ye_dec, _ = jax.jit(lambda p, xx: moe.apply_ep_decode(p, cfg, xx, mesh))(params, x[:, :1])
derr = float(jnp.max(jnp.abs(yd_dec - ye_dec)))
assert err < 1e-4, err
assert gerr < 1e-3, gerr
assert derr < 1e-4, derr
print("OK", err, gerr, derr)
""")
    assert "OK" in out


def test_capacity_drop_behavior():
    """With capacity_factor ~0 the send capacity clamps to 1 entry per
    bucket: all but <=1 token degrade gracefully to shared-expert-only
    output (drops, not corruption)."""
    cfg = _cfg(moe_impl="ep", capacity_factor=1e-9)
    params = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32))
    from repro.configs.base import MeshConfig
    from repro.launch.mesh import make_mesh
    mesh = make_mesh(MeshConfig((1, 1), ("data", "model")))
    y, _ = jax.jit(lambda p, xx: moe.apply_ep(p, cfg, xx, mesh))(params, x)
    shared_only = moe._shared_ffn(cfg, params["shared"], x.reshape(-1, 32))
    diff = np.abs(np.asarray(y.reshape(-1, 32)) - np.asarray(shared_only))
    mismatched_rows = int((diff.max(axis=1) > 2e-4).sum())
    assert mismatched_rows <= 1, mismatched_rows
    assert np.isfinite(np.asarray(y)).all()
