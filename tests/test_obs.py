"""Observability layer: histogram quantile accuracy + bounded memory,
counter/histogram thread-safety, trace-event well-formedness, the
obs-on/obs-off bit-identical-trajectory contract, selection telemetry
agreeing with optimizer counts, the exploration->exploitation report, the
banked/serve trace structure, and the serve engine's consolidated
``stats_snapshot()`` (including the decode_steps accounting)."""
import json
import threading

import jax
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro import obs
from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.obs import report
from repro.obs.registry import Counter, Histogram
from repro.obs.selection import SelectionTrace
from repro.obs.trace import Tracer, validate_trace, validate_trace_file
from repro.train.trainer import Trainer

# vocab >= 32 so the synthetic-math token space fits (finite losses)
TINY = ModelConfig(name="obs-tiny", family="dense", num_layers=4,
                   d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
                   d_ff=32, vocab_size=32, dtype="float32", remat="none",
                   tie_embeddings=False)


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with tracing off (obs.metrics is
    process-global by design; instruments are additive and harmless)."""
    obs.disable()
    yield
    obs.disable()


def _tcfg(method="adagradselect", residency="banked", steps=6,
          async_swap=True, steps_per_epoch=3):
    return TrainConfig(
        model=TINY, method=method,
        select=SelectConfig(k_percent=40, steps_per_epoch=steps_per_epoch,
                            epsilon_decay=0.1),
        optimizer=OptimizerConfig(
            lr=1e-3, schedule="constant", warmup_steps=0,
            moment_residency=residency,
            offload="host" if residency == "banked" else "none",
            async_swap=async_swap, total_steps=steps),
        seq_len=48, global_batch=4, steps=steps, seed=0, log_every=0)


# --------------------------------------------------------------- histogram
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31),
       n=st.integers(min_value=1, max_value=400),
       scale=st.sampled_from([1e-3, 1.0, 1e3, 1e6]))
def test_histogram_quantiles_match_numpy(seed, n, scale):
    """p50/p95/p99 land within the documented ~4.4% bucket error of the
    nearest-rank (numpy 'lower') order statistic."""
    rng = np.random.default_rng(seed)
    # stay inside the bucketed range [2**-16, 2**48] (values beyond it
    # clamp to the edge buckets; the instrument's unit is microseconds)
    xs = np.clip(rng.lognormal(mean=0.0, sigma=2.0, size=n) * scale,
                 2.0**-12, 2.0**44)
    h = Histogram()
    for x in xs:
        h.record(x)
    srt = np.sort(xs)
    for q in (0.0, 0.5, 0.95, 0.99, 1.0):
        want = srt[int(np.floor(q * (n - 1)))]
        got = h.quantile(q)
        assert got == pytest.approx(want, rel=0.05), (q, got, want)
    assert h.count == n
    assert h.mean == pytest.approx(float(np.mean(xs)), rel=1e-9)
    assert h.min == pytest.approx(float(srt[0]))
    assert h.max == pytest.approx(float(srt[-1]))


def test_histogram_bounded_memory_and_extremes():
    h = Histogram()
    for v in (0.0, -5.0, 1e-30, 1e30, 7.0):
        h.record(v)
    # bucket storage is a fixed-size array regardless of value range
    assert len(h._counts) == Histogram.num_buckets
    assert h.count == 5
    assert h.quantile(0.0) == 0.0  # negatives/zero collapse to zero bucket
    s = h.summary()
    assert set(s) >= {"count", "mean", "p50", "p95", "p99", "min", "max"}
    assert Histogram().summary() == {"count": 0}
    assert Histogram().quantile(0.5) == 0.0


def test_counter_and_histogram_thread_safety():
    c = Counter()
    h = Histogram()
    n, per = 8, 2000

    def work():
        for i in range(per):
            c.inc()
            h.record(float(i + 1))

    threads = [threading.Thread(target=work) for _ in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n * per
    assert h.count == n * per
    assert h.total == pytest.approx(n * per * (per + 1) / 2)


def test_registry_snapshot_shapes_and_register_semantics():
    reg = obs.MetricsRegistry()
    reg.counter("a", subsystem="s1").inc(3)
    reg.gauge("g", subsystem="s1").set(2.5)
    reg.histogram("h", subsystem="s2").record(1.0)
    reg.register("cb", lambda: {"x": 1}, subsystem="s2")
    snap = reg.snapshot()
    assert snap["s1"]["a"] == 3 and snap["s1"]["g"] == 2.5
    assert snap["s2"]["h"]["count"] == 1
    assert snap["s2"]["cb"] == {"x": 1}
    json.dumps(snap)  # JSON-able end to end
    # last-writer-wins + failing callables render as an error value
    reg.register("cb", lambda: 1 / 0, subsystem="s2")
    assert "error" in reg.snapshot()["s2"]["cb"]
    # same key returns the same instrument
    assert reg.counter("a", subsystem="s1") is reg.counter("a",
                                                           subsystem="s1")


# ------------------------------------------------------------------ tracer
def test_trace_events_well_formed(tmp_path):
    tr = obs.enable()
    with obs.span("outer", {"k": 1}):
        with obs.span("inner"):
            pass
        obs.instant("tick", {"n": 2})
    t0 = tr._t0_ns
    tr.complete("retro", t0 + 1000, t0 + 5000, track="lane A")
    path = tmp_path / "t.json"
    obs.export_trace(str(path))
    events = validate_trace_file(str(path))
    by_ph = {}
    for e in events:
        by_ph.setdefault(e["ph"], []).append(e)
    assert [e["name"] for e in by_ph["B"]] == ["outer", "inner"]
    assert [e["name"] for e in by_ph["E"]] == ["inner", "outer"]
    assert by_ph["i"][0]["name"] == "tick"
    (x,) = by_ph["X"]
    assert x["name"] == "retro" and x["dur"] == pytest.approx(4.0)
    # the synthetic track got a thread_name metadata event
    assert any(e["ph"] == "M" and e["args"]["name"] == "lane A"
               for e in events)


def test_validate_trace_rejects_malformed():
    ok = [{"ph": "B", "name": "a", "pid": 0, "tid": 1, "ts": 1.0},
          {"ph": "E", "name": "a", "pid": 0, "tid": 1, "ts": 2.0}]
    validate_trace(ok)
    with pytest.raises(AssertionError):  # unterminated span
        validate_trace(ok[:1])
    with pytest.raises(AssertionError):  # mismatched E name
        validate_trace([ok[0], {**ok[1], "name": "b"}])
    with pytest.raises(AssertionError):  # time going backwards on one tid
        validate_trace([{**ok[0], "ts": 5.0}, ok[1]])
    with pytest.raises(AssertionError):  # unknown phase
        validate_trace([{**ok[0], "ph": "Q"}])


def test_tracer_bounded_buffer_drops_not_grows():
    tr = Tracer(max_events=10)
    for i in range(50):
        tr.instant(f"e{i}")
    assert len(tr.events()) == 10
    assert tr.dropped == 41  # 50 instants + 1 thread_name metadata - 10 kept


def test_noop_span_when_disabled():
    assert not obs.enabled()
    assert obs.span("anything") is obs.NOOP_SPAN
    obs.instant("ignored")  # must not raise
    with pytest.raises(RuntimeError):
        obs.export_trace("/tmp/never.json")


def test_timed_records_histogram_always_and_span_only_when_on():
    h = Histogram()
    with obs.timed(h, "work"):
        pass
    assert h.count == 1  # histogram fed even with tracing off
    tr = obs.enable()
    with obs.timed(h, "work"):
        pass
    assert h.count == 2
    names = [e["name"] for e in tr.events() if e["ph"] == "B"]
    assert names == ["work"]


# ------------------------------------------------- trainer contract + trace
@pytest.mark.parametrize("residency", ["device", "banked"])
def test_obs_on_off_trajectories_bit_identical(residency):
    log_off = Trainer(_tcfg(residency=residency)).train()
    obs.enable()
    log_on = Trainer(_tcfg(residency=residency)).train()
    assert log_on.losses == log_off.losses


def test_selection_trace_reproduces_opt_counts_every_boundary():
    """The telemetry counts must equal state["opt"]["counts"] after EVERY
    step, not just at the end — train one step at a time and compare."""
    obs.enable()
    tr = Trainer(_tcfg(residency="banked", steps=6))
    sel = obs.selection_trace()
    for i in range(6):
        tr.train(steps=1, start_step=i)
        np.testing.assert_array_equal(
            sel.counts, np.asarray(tr.state["opt"]["counts"], np.float64))
    assert len(sel) == 6
    assert sel.masks().shape == (6, sel.num_blocks)


def test_banked_train_trace_has_phases_and_swap_thread(tmp_path):
    obs.enable()
    Trainer(_tcfg(residency="banked", steps=6, async_swap=True)).train()
    path = tmp_path / "train.json"
    obs.export_trace(str(path))
    events = validate_trace_file(str(path))
    b_names = {e["name"] for e in events if e["ph"] == "B"}
    assert {"train_step", "phase_a", "swap", "phase_b"} <= b_names
    # the background boundary dispatch runs on its own named track
    threads = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert any(t.startswith("swap-planner") for t in threads), threads
    planner_tids = {e["tid"] for e in events
                    if e["ph"] == "M"
                    and e["args"]["name"].startswith("swap-planner")}
    assert any(e["ph"] == "B" and e["name"] == "swap_dispatch_job"
               and e["tid"] in planner_tids for e in events)


# -------------------------------------------------------- selection report
@pytest.mark.parametrize("method", ["adagradselect", "lisa", "grass"])
def test_selection_report_renders_per_method(method):
    obs.enable()
    Trainer(_tcfg(method=method, residency="device", steps=8)).train()
    sel = obs.selection_trace()
    assert len(sel) == 8
    out = report.render_selection_trace(sel, bins=4)
    assert "selection heatmap" in out
    assert "entropy" in out
    for b in range(sel.num_blocks):
        assert f"block {b:3d}" in out


def test_report_summarize_and_edge_cases():
    masks = np.zeros((10, 4), bool)
    masks[:5, 0] = True   # block 0 early only
    masks[5:, 1] = True   # block 1 late only
    s = report.summarize(masks, bins=2)
    assert s["rates"].shape == (4, 2)
    assert s["rates"][0].tolist() == [1.0, 0.0]
    assert s["rates"][1].tolist() == [0.0, 1.0]
    with pytest.raises(ValueError):
        report.summarize(np.zeros(3), bins=2)
    empty = report.render_selection_trace(SelectionTrace())
    assert "no steps recorded" in empty


def test_selection_snapshot_roundtrip():
    sel = SelectionTrace()
    rng = np.random.default_rng(0)
    for step in range(5):
        sel.record(step, rng.integers(0, 2, 7).astype(bool),
                   rng.random(7))
    doc = json.loads(json.dumps(sel.snapshot()))
    back = SelectionTrace.from_snapshot(doc)
    np.testing.assert_array_equal(back.counts, sel.counts)
    np.testing.assert_array_equal(back.masks(), sel.masks())
    np.testing.assert_allclose(back.norms(), sel.norms())


# ------------------------------------------------------------------- serve
SERVE_TINY = ModelConfig(name="tiny-serve-obs", family="dense", num_layers=2,
                         d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                         vocab_size=64, dtype="float32", remat="none")


def _serve(new_tokens=10, decode_chunk=4, num_requests=3, **kw):
    from repro.models import registry
    from repro.serve.config import ServeConfig
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import Request

    params = registry.get(SERVE_TINY).init(jax.random.PRNGKey(0), SERVE_TINY)
    rng = np.random.default_rng(1)
    eng = ServeEngine(SERVE_TINY, params,
                      ServeConfig(max_len=64, num_slots=4,
                                  decode_chunk=decode_chunk, **kw))
    reqs = [Request(uid=i,
                    tokens=rng.integers(1, 64, (8 + i,)).astype(np.int32),
                    max_new_tokens=new_tokens, arrival=i)
            for i in range(num_requests)]
    res = eng.run(reqs)
    return eng, res


def test_serve_stats_snapshot_structure():
    eng, res = _serve()
    snap = eng.stats_snapshot()
    assert set(snap) == {"engine", "latency_us", "pages", "scheduler",
                        "prefix_cache", "stream_out", "fn_cache"}
    lat = snap["latency_us"]
    assert set(lat) == {"queue_wait", "ttft", "tpot", "e2e"}
    for h in lat.values():
        assert h["count"] == 3  # one sample per completed request
        assert h["p50"] > 0
    assert snap["engine"]["completed"] == 3
    assert snap["pages"] is None  # dense layout
    assert snap["scheduler"]["pending"] == 0
    assert snap["fn_cache"]["size"] > 0
    json.dumps(snap)


def test_decode_steps_counts_emitted_positions():
    """Prefill emits token 1; decode emits the remaining max_new - 1 — per
    request — regardless of decode_chunk granularity (the old accounting
    added decode_chunk per dispatched chunk)."""
    for chunk in (3, 4):
        eng, res = _serve(new_tokens=10, decode_chunk=chunk,
                          num_requests=3)
        assert all(len(t) == 10 for t in res.values())
        assert eng.stats["decode_steps"] == 3 * 9, (
            chunk, eng.stats["decode_steps"])


def test_serve_trace_per_request_lanes(tmp_path):
    obs.enable(selection=False)
    eng, res = _serve()
    path = tmp_path / "serve.json"
    obs.export_trace(str(path))
    events = validate_trace_file(str(path))
    xs = [e for e in events if e["ph"] == "X"]
    lanes = {e["tid"]: [] for e in xs}
    for e in xs:
        lanes[e["tid"]].append(e["name"])
    # one synthetic lane per request, each carrying ttft + e2e
    assert len(lanes) == 3
    for names in lanes.values():
        assert sorted(names) == ["e2e", "ttft"]
    track_names = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert {"request 0", "request 1", "request 2"} <= track_names
    b_names = {e["name"] for e in events if e["ph"] == "B"}
    assert {"admission", "decode_chunk"} <= b_names


def test_swap_stats_as_dict_views_histograms():
    """SwapStats timing fields are views over the obs histograms (satellite
    1: one timing source of truth, bench JSON schema unchanged)."""
    tr = Trainer(_tcfg(residency="banked", steps=6))
    tr.train()
    stats = tr.step_fn.swap_stats
    d = stats.as_dict()
    assert set(d) >= {"steps", "boundaries", "predicted_hits", "sync_swaps",
                      "dispatches", "phase_a_us", "swap_us", "phase_b_us",
                      "predicted_hit_rate"}
    assert d["steps"] == 6
    assert d["phase_a_us"] == pytest.approx(stats.phase_a.total)
    assert stats.phase_a.count == 6  # one sample per step
    # the active trainer's swap stats are visible in the global snapshot
    snap = obs.metrics.snapshot()
    assert snap["swap"]["banked"]["steps"] == 6
    assert snap["swap"]["phase_a_us"]["count"] == 6
