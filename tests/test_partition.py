"""BlockPartition: the paper's block taxonomy over every arch family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core import partition as pmod
from repro.models import registry


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_partition_covers_params(arch):
    cfg = get_smoke_config(arch)
    part = pmod.build_partition(cfg)
    model = registry.get(cfg)
    params = jax.eval_shape(lambda k: model.init(k, cfg), jax.random.PRNGKey(0))
    # every top-level param group appears in exactly one partition group
    assert {g.key for g in part.groups} == set(params.keys())
    assert part.num_blocks == cfg.num_blocks
    # stacked groups really have the stated leading axis
    for g in part.groups:
        for leaf in jax.tree.leaves(params[g.key]):
            if g.stacked:
                assert leaf.shape[0] == g.length, (g.key, leaf.shape)


def test_block_grad_norms_matches_manual():
    cfg = get_smoke_config("llama3.2-1b")
    part = pmod.build_partition(cfg)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.5, params)
    norms = np.asarray(pmod.block_grad_norms(part, grads))
    counts = pmod.params_per_block(part, params)
    expected = np.sqrt(counts * 0.25)
    np.testing.assert_allclose(norms, expected, rtol=1e-5)


def test_leaf_masks_freeze_alignment():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    part = pmod.build_partition(cfg)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    mask = jnp.zeros(part.num_blocks, bool).at[2].set(True)
    masks = pmod.leaf_masks(part, params, mask)
    for g in part.groups:
        for leaf in jax.tree.leaves(masks[g.key]):
            if g.stacked:
                flat = np.asarray(leaf).reshape(g.length, -1)[:, 0]
                exp = np.asarray(mask[g.start:g.start + g.length])
                np.testing.assert_array_equal(flat.astype(bool), exp)


def test_params_per_block_total():
    cfg = get_smoke_config("mamba2-2.7b")
    part = pmod.build_partition(cfg)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    counts = pmod.params_per_block(part, params)
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert counts.sum() == total


def test_layer_masks_dict_groups():
    cfg = get_smoke_config("zamba2-7b")
    part = pmod.build_partition(cfg)
    mask = jnp.ones(part.num_blocks)
    lm = pmod.layer_masks_dict(part, mask)
    assert set(lm) == {"layers", "shared_attn"}
    assert lm["layers"].shape == (cfg.num_layers,)
