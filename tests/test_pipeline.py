"""Streaming SFT pipeline: packing parity, prefetch determinism, cursor
resume, segment-masked attention, and the dp=8 sharded prefetch path.

The load-bearing pins:
  * packed loss/gradients == the per-example unpacked oracle (block-diagonal
    attention + reset positions make packing exact, not approximate);
  * prefetch on/off trajectories are bit-identical (single-device here,
    dp=8 in the multidevice test);
  * a mid-run checkpoint stores the record cursor and resumes the packed
    stream with no skipped or repeated records.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.data import loader
from repro.data.pipeline import (JsonlSftRecords, Prefetcher, Record,
                                 SFTPipeline, SyntheticMathRecords, packing)
from repro.data.synthetic import MathTaskConfig
from repro.data import tokenizer as tok
from repro.models import lm
from repro.train import step as step_mod
from repro.train.trainer import Trainer

TINY = ModelConfig(name="pipe-tiny", family="dense", num_layers=2,
                   d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                   d_ff=64, vocab_size=32, dtype="float32", remat="none")


def math_records(n=64, seq_len=64):
    return SyntheticMathRecords(MathTaskConfig(digits=3, seq_len=seq_len),
                                num_records=n)


def write_sft_corpus(path, n=24, seed=0):
    """Variable-length prompt/completion jsonl corpus."""
    rng = np.random.default_rng(seed)
    with open(path, "w") as f:
        for i in range(n):
            p = "Q: " + " ".join(str(rng.integers(100))
                                 for _ in range(int(rng.integers(2, 12))))
            c = "A: " + " ".join(str(rng.integers(100))
                                 for _ in range(int(rng.integers(1, 18))))
            f.write(json.dumps({"prompt": p, "completion": c}) + "\n")
    return str(path)


# ------------------------------------------------------------- packer units


def test_pack_batch_layout_invariants():
    src = math_records()
    batch, nxt = packing.pack_batch(src, 0, 4, 128)
    toks, mask = batch["tokens"], batch["loss_mask"]
    segs, pos = batch["segment_ids"], batch["positions"]
    assert toks.shape == mask.shape == segs.shape == pos.shape == (4, 128)
    assert nxt > 4  # multi-segment rows on a 64-token-max corpus
    for r in range(4):
        row_segs = segs[r]
        n_seg = int(row_segs.max())
        assert n_seg >= 1
        # segment ids are 1..n contiguous, pad tail is 0
        nz = row_segs[row_segs != 0]
        assert set(np.unique(nz)) == set(range(1, n_seg + 1))
        for s in range(1, n_seg + 1):
            idx = np.nonzero(row_segs == s)[0]
            assert (np.diff(idx) == 1).all()          # contiguous
            np.testing.assert_array_equal(            # positions reset
                pos[r, idx], np.arange(len(idx)))
            assert mask[r, idx[0]] == 0               # starts loss-masked
        # pad tail carries no loss and PAD tokens
        pad = row_segs == 0
        assert (mask[r, pad] == 0).all() and (toks[r, pad] == tok.PAD).all()


def test_pack_batch_pure_in_cursor():
    src = math_records()
    b1, n1 = packing.pack_batch(src, 7, 3, 96)
    b2, n2 = packing.pack_batch(src, 7, 3, 96)
    assert n1 == n2
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])


def test_pack_batch_no_record_skipped_or_split():
    """Consecutive batches consume a contiguous record range; every
    consumed record appears exactly once, whole."""
    src = math_records(n=50)
    cur = 0
    seen = []
    for _ in range(3):
        batch, nxt = packing.pack_batch(src, cur, 2, 128)
        total_seg = sum(int(batch["segment_ids"][r].max())
                        for r in range(2))
        assert total_seg == nxt - cur
        seen.extend(range(cur, nxt))
        cur = nxt
    assert seen == list(range(cur))


def test_record_longer_than_row_truncates():
    class One:
        num_records = 1

        def record_at(self, i):
            return Record(prompt=np.arange(3, 10, dtype=np.int32),
                          completion=np.arange(10, 60, dtype=np.int32))
    batch, nxt = packing.pack_batch(One(), 0, 1, 16)
    assert nxt == 1  # consumed (not an infinite loop), truncated to L
    assert int(batch["segment_ids"][0].max()) == 1
    assert (batch["segment_ids"][0] == 1).all()


def test_record_requires_nonempty_prompt():
    with pytest.raises(ValueError, match="non-empty"):
        Record(prompt=np.zeros(0, np.int32),
               completion=np.arange(3, dtype=np.int32))


def test_jsonl_sft_records_schema(tmp_path):
    path = write_sft_corpus(tmp_path / "sft.jsonl", n=5)
    src = JsonlSftRecords(path)
    assert src.num_records == 5
    rec = src.record_at(0)
    assert rec.prompt[0] == tok.BOS and rec.completion[-1] == tok.EOS
    # prompt/completion text round-trips
    assert tok.decode(rec.prompt).startswith("Q:")
    assert tok.decode(rec.completion).startswith("A:")
    with pytest.raises(ValueError, match="prompt.*completion|completion"):
        p = tmp_path / "bad.jsonl"
        p.write_text(json.dumps({"text": "nope"}) + "\n")
        JsonlSftRecords(str(p))


# --------------------------------------------------------- packing parity


def test_packed_loss_and_grads_match_unpacked_oracle():
    """The acceptance pin: segment-aware masking + reset positions make the
    packed batch's loss AND gradients equal the per-example oracle."""
    params = lm.init(jax.random.PRNGKey(0), TINY)
    src = math_records()
    packed, nrec = packing.pack_batch(src, 0, 2, 128)
    assert nrec >= 4  # actually multi-segment
    oracle, _ = packing.unpacked_batch(src, 0, nrec, 128)
    plain = {"tokens": oracle["tokens"], "loss_mask": oracle["loss_mask"]}

    def loss(p, b):
        arrs = {k: jnp.asarray(v) for k, v in b.items()}
        return step_mod.model_loss(lm, TINY, p, arrs)[0]

    (l1, g1) = jax.value_and_grad(loss)(params, packed)
    (l2, g2) = jax.value_and_grad(loss)(params, plain)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-6)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-6, rtol=2e-5)


def test_unpacked_batch_is_plain_layout():
    """pack=False emits only the legacy keys — single-segment rows ARE the
    plain causal path, and the batch stays consumable by families that
    reject packed segments (the documented escape hatch)."""
    batch, _ = packing.unpacked_batch(math_records(), 3, 4, 64)
    assert set(batch) == {"tokens", "loss_mask"}


def test_unpacked_pipeline_trains_ssm_family():
    """The escape hatch the packed-reject error points at must actually
    work: an SSM stack trains on a pack=False pipeline."""
    cfg = get_smoke_config("mamba2-2.7b").replace(remat="none")
    tcfg = TrainConfig(
        model=cfg, select=SelectConfig(policy="adagradselect", k_percent=40),
        optimizer=OptimizerConfig(lr=1e-3, schedule="constant",
                                  warmup_steps=0),
        seq_len=64, global_batch=2, steps=2, log_every=0)
    pipe = SFTPipeline(math_records(), seq_len=64, global_batch=2,
                       pack=False)
    log = Trainer(tcfg, data_source=pipe, prefetch_depth=2).train(steps=2)
    assert len(log.losses) == 2 and np.isfinite(log.losses).all()


@pytest.mark.parametrize("cfg,msg", [
    (get_smoke_config("mamba2-2.7b"), "ssm"),
    (TINY.replace(mtp_depth=1), "mtp_depth"),
])
def test_packed_rejected_for_unsupported_configs(cfg, msg):
    params_shape = None  # init not needed — the check fires first
    batch = {"tokens": jnp.zeros((1, 8), jnp.int32),
             "loss_mask": jnp.zeros((1, 8), jnp.float32),
             "segment_ids": jnp.ones((1, 8), jnp.int32),
             "positions": jnp.zeros((1, 8), jnp.int32)}
    with pytest.raises(ValueError, match=msg):
        lm.apply_train(params_shape, cfg, batch)


# ------------------------------------------------------------- prefetcher


def test_prefetcher_preserves_order_and_values():
    def stream():
        for i in range(20):
            yield {"x": np.full((2,), i)}, {"record": i + 1}
    with Prefetcher(stream(), lambda b: b, depth=4) as pf:
        out = list(pf)
    assert [c["record"] for _, c in out] == list(range(1, 21))
    assert all(int(b["x"][0]) == i for i, (b, _) in enumerate(out))


def test_prefetcher_depth0_is_synchronous():
    pf = Prefetcher(iter([({"x": 1}, {"record": 1})]), depth=0)
    assert pf._thread is None
    assert next(pf) == ({"x": 1}, {"record": 1})
    with pytest.raises(StopIteration):
        next(pf)


def test_prefetcher_surfaces_worker_errors():
    def stream():
        yield {"x": 1}, {"record": 1}
        raise RuntimeError("boom")
    with Prefetcher(stream(), depth=2) as pf:
        next(pf)
        with pytest.raises(RuntimeError, match="boom"):
            while True:
                next(pf)


def test_prefetcher_close_unblocks_producer():
    """A full queue + early consumer exit must not deadlock or leak."""
    def stream():
        i = 0
        while True:
            yield {"x": i}, {"record": i}
            i += 1
    pf = Prefetcher(stream(), depth=1)
    next(pf)
    pf.close()
    assert pf._thread is None


def test_pipeline_readahead_does_not_advance_cursor():
    """batches() iterates a local cursor — the committed cursor moves only
    via restore_cursor (what the trainer consumed)."""
    pipe = SFTPipeline(math_records(), seq_len=64, global_batch=2)
    gen = pipe.batches()
    _, c1 = next(gen)
    _, c2 = next(gen)
    assert c2["record"] > c1["record"] > 0
    assert pipe.cursor() == {"record": 0}
    pipe.restore_cursor(c1)
    _, c1b = next(pipe.batches())
    assert c1b["record"] == c2["record"]  # resumed exactly after batch 1


# ------------------------------------------------- trainer integration


def _tcfg(ckdir="", steps=6):
    return TrainConfig(
        model=TINY,
        select=SelectConfig(policy="adagradselect", k_percent=40),
        optimizer=OptimizerConfig(lr=1e-3, schedule="constant",
                                  warmup_steps=0),
        seq_len=64, global_batch=4, steps=steps, log_every=0,
        checkpoint_dir=ckdir, checkpoint_every=3)


def _pipe(seq_len=64, batch=4):
    return loader.make_source("packed_math", seq_len=seq_len,
                              global_batch=batch, num_records=64)


def test_prefetch_on_off_bit_identical_trajectory():
    t_off = Trainer(_tcfg(), data_source=_pipe())
    t_off.train(steps=5)
    t_on = Trainer(_tcfg(), data_source=_pipe(), prefetch_depth=3)
    t_on.train(steps=5)
    assert t_off.data.cursor() == t_on.data.cursor()
    for a, b in zip(jax.tree.leaves(t_off.state["params"]),
                    jax.tree.leaves(t_on.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_source_with_prefetch_bit_identical():
    """The batch_at adapter seam: legacy sources keep working, with or
    without the prefetcher."""
    t_off = Trainer(_tcfg())
    t_off.train(steps=4)
    t_on = Trainer(_tcfg(), prefetch_depth=2)
    t_on.train(steps=4)
    for a, b in zip(jax.tree.leaves(t_off.state["params"]),
                    jax.tree.leaves(t_on.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_cursor_resume_exact(tmp_path):
    """3 + save + restore + 3 == 6 straight, through the PACKED stream
    (cursor in checkpoint meta; prefetch read-ahead must not leak into the
    saved cursor)."""
    t1 = Trainer(_tcfg(), data_source=_pipe(), prefetch_depth=2)
    t1.train(steps=6)

    d = str(tmp_path / "ck")
    t2 = Trainer(_tcfg(d), data_source=_pipe(), prefetch_depth=2)
    t2.train(steps=3)
    saved_cursor = t2.ckpt.load_meta(3)["data_cursor"]
    assert saved_cursor == t2.data.cursor()  # no read-ahead leakage

    t3 = Trainer(_tcfg(d), data_source=_pipe(), prefetch_depth=2)
    start = t3.maybe_restore()
    assert start == 3
    assert t3.data.cursor() == saved_cursor
    t3.train(steps=3, start_step=start)
    assert t3.data.cursor() == t1.data.cursor()  # no skip, no repeat
    for a, b in zip(jax.tree.leaves(t1.state["params"]),
                    jax.tree.leaves(t3.state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_jsonl_sft_end_to_end(tmp_path):
    """Real-corpus path: jsonl_sft records -> packed batches -> train."""
    path = write_sft_corpus(tmp_path / "sft.jsonl", n=32)
    pipe = loader.make_source("jsonl_sft", seq_len=64, global_batch=2,
                              path=path)
    cfg = TINY.replace(vocab_size=tok.VOCAB_SIZE)
    tcfg = TrainConfig(
        model=cfg, select=SelectConfig(policy="adagradselect", k_percent=40),
        optimizer=OptimizerConfig(lr=1e-3, schedule="constant",
                                  warmup_steps=0),
        seq_len=64, global_batch=2, steps=3, log_every=0)
    tr = Trainer(tcfg, data_source=pipe, prefetch_depth=2)
    log = tr.train(steps=3)
    assert len(log.losses) == 3 and np.isfinite(log.losses).all()
    assert pipe.cursor()["record"] > 0


def test_packing_stats_beats_drop_remainder(tmp_path):
    """The bench_data metric on a variable-length corpus: greedy packing
    keeps (supervises) more completion tokens than the legacy
    concat/reshape drop-remainder layout, and fills slots better than
    per-example padding."""
    path = write_sft_corpus(tmp_path / "sft.jsonl", n=40, seed=3)
    stats = packing.packing_stats(JsonlSftRecords(path), seq_len=256,
                                  batch_size=4)
    assert stats["packed_kept"] > stats["drop_remainder_kept"]
    assert stats["packed_kept"] > 0.95
    assert stats["packed_slot_util"] > stats["unpacked_slot_util"]


# --------------------------------------------------------------- dp=8


def test_dp8_sharded_prefetch_bit_identical(multidevice):
    """Packed pipeline + async prefetcher under a dp=8 data mesh: batches
    shard over `data` from the prefetch thread; prefetch on/off and the
    single-device oracle all agree bit-exactly."""
    out = multidevice("""
import jax, numpy as np
from repro.configs.base import ModelConfig, OptimizerConfig, SelectConfig, TrainConfig
from repro.data import loader
from repro.launch.mesh import make_data_mesh
from repro.train.trainer import Trainer

TINY = ModelConfig(name="pipe-tiny", family="dense", num_layers=2,
                   d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                   d_ff=64, vocab_size=32, dtype="float32", remat="none")
tcfg = TrainConfig(model=TINY,
    select=SelectConfig(policy="adagradselect", k_percent=40),
    optimizer=OptimizerConfig(lr=1e-3, schedule="constant", warmup_steps=0),
    seq_len=64, global_batch=8, steps=4, log_every=0)

def pipe():
    return loader.make_source("packed_math", seq_len=64, global_batch=8,
                              num_records=64)

mesh = make_data_mesh()
runs = {}
for name, kw in (("oracle", {}),
                 ("dp8_off", dict(mesh=mesh)),
                 ("dp8_on", dict(mesh=mesh, prefetch_depth=3))):
    t = Trainer(tcfg, data_source=pipe(), **kw)
    t.train(steps=4)
    runs[name] = (jax.tree.map(np.asarray, jax.device_get(t.state["params"])),
                  t.data.cursor())

assert runs["dp8_off"][1] == runs["dp8_on"][1] == runs["oracle"][1]
# prefetch on/off under the SAME topology: bit-identical
for a, b in zip(jax.tree.leaves(runs["dp8_off"][0]),
                jax.tree.leaves(runs["dp8_on"][0])):
    np.testing.assert_array_equal(a, b)
# dp=8 vs the single-device oracle: numerically equal (GSPMD reduction
# order differs in low bits — same tolerance as test_sharded_train)
for a, b in zip(jax.tree.leaves(runs["oracle"][0]),
                jax.tree.leaves(runs["dp8_off"][0])):
    np.testing.assert_allclose(a, b, atol=1e-5)
print("OK", runs["dp8_on"][1])
""")
    assert "OK" in out
