"""Property tests for the radix prefix cache and the refcounting page
allocator: refcounts never go negative, evicted tree-only pages land at
refcount 0 (back on the free list), matches are page-aligned and maximal,
LRU capacity is enforced, and double frees raise instead of silently
corrupting the free list.

Runs under real Hypothesis when installed, else the deterministic shim.
"""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.serve.pages import PageAllocator
from repro.serve.prefix_cache import PrefixCache

PS = 4  # page size for every test


def _longest_match(snapshot, tokens):
    """Brute-force oracle: longest page-aligned cached prefix of tokens."""
    best = []
    for n in range(len(tokens) // PS, 0, -1):
        key = tuple(int(t) for t in tokens[:n * PS])
        if key in snapshot:
            return [snapshot[tuple(int(t) for t in tokens[:i * PS])]
                    for i in range(1, n + 1)]
    return best


def _random_ops(seed, n_ops, capacity):
    """Drive random insert/match/evict against a live allocator; return the
    (cache, alloc, trace) for invariant checks."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages=64, num_slots=8, pages_per_slot=8)
    cache = PrefixCache(PS, capacity, alloc.incref, alloc.decref)
    slot_cycle = 0
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        # small vocab + short prompts force shared prefixes
        tokens = rng.integers(0, 3, int(rng.integers(PS, 5 * PS)))
        if op == 0:  # complete a request: allocate, insert prefix, free slot
            n_pages = -(-len(tokens) // PS)
            if not alloc.can_allocate(n_pages):
                cache.evict(n_pages)
                if not alloc.can_allocate(n_pages):
                    continue
            slot = slot_cycle % 8
            slot_cycle += 1
            if alloc._used[slot]:
                continue
            alloc.allocate(slot, n_pages)
            nfull = len(tokens) // PS
            cache.insert(tokens[:nfull * PS],
                         [int(p) for p in alloc.table[slot, :nfull]])
            alloc.free(slot)
        elif op == 1:
            got = cache.match(tokens)
            want = _longest_match(cache.snapshot(), tokens)
            assert got == want, (got, want)
        else:
            before = cache.snapshot()
            evicted = cache.evict(int(rng.integers(1, 4)))
            gone = set(before.values()) - set(cache.snapshot().values())
            # evicted tree-only pages hit refcount 0 (nothing else held
            # them here: every inserting slot was freed immediately)
            for p in evicted:
                assert alloc.refcount[p] == 0, (p, alloc.refcount[p])
            assert set(evicted) >= gone
        assert (alloc.refcount >= 0).all()
        assert cache.cached_pages <= max(capacity, 0) or op != 0
    return cache, alloc


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10**6), n_ops=st.integers(5, 40),
       capacity=st.integers(0, 32))
def test_radix_invariants_under_random_ops(seed, n_ops, capacity):
    """Insert/match/evict in random order: matches equal the brute-force
    longest page-aligned prefix, refcounts never go negative, evicted
    tree-only pages return to refcount 0, and the LRU cap holds."""
    cache, alloc = _random_ops(seed, n_ops, capacity)
    assert cache.cached_pages <= capacity
    # tree accounting is consistent: every snapshot page is live
    for page in cache.snapshot().values():
        assert alloc.refcount[page] >= 1
    # full teardown drains every reference the tree holds
    cache.evict(cache.cached_pages + 1)
    assert cache.cached_pages == 0
    assert (alloc.refcount == 0).all()
    assert alloc.free_pages == alloc.num_pages


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(1, 6))
def test_match_is_page_aligned_and_maximal(seed, n):
    """Every match covers a whole number of pages and one more page never
    matches (maximality), including after LRU eviction."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(num_pages=64, num_slots=4, pages_per_slot=16)
    cache = PrefixCache(PS, 32, alloc.incref, alloc.decref)
    prompts = [rng.integers(0, 3, 3 * PS) for _ in range(n)]
    for i, toks in enumerate(prompts):
        slot = i % 4
        if alloc._used[slot]:
            alloc.free(slot)
        alloc.allocate(slot, 3)
        cache.insert(toks, [int(p) for p in alloc.table[slot, :3]])
    query = np.concatenate([prompts[0], rng.integers(0, 3, PS // 2)])
    got = cache.match(query)
    snap = cache.snapshot()
    assert got == _longest_match(snap, query)
    if got:  # page-aligned by construction; maximal vs the oracle
        assert tuple(int(t) for t in query[:len(got) * PS]) in snap


def test_lru_eviction_prefers_coldest_leaf():
    """The LRU victim is the least-recently-touched LEAF — interior nodes
    (with cached children) survive so deeper prefixes never dangle."""
    alloc = PageAllocator(num_pages=16, num_slots=2, pages_per_slot=8)
    cache = PrefixCache(PS, 16, alloc.incref, alloc.decref)
    a = np.arange(2 * PS) % 3            # chain of 2 pages
    b = np.concatenate([a[:PS], np.full(PS, 7)])  # shares page 0, forks
    alloc.allocate(0, 2)
    cache.insert(a, [int(p) for p in alloc.table[0, :2]])
    alloc.free(0)
    alloc.allocate(1, 2)
    cache.insert(b, [int(p) for p in alloc.table[1, :2]])
    alloc.free(1)
    cache.match(b)  # touch b's chain: a's leaf is now coldest
    snap_before = cache.snapshot()
    [evicted] = cache.evict(1)
    assert evicted == snap_before[tuple(int(t) for t in a)]
    assert alloc.refcount[evicted] == 0
    # the shared first page (interior node) is still cached
    assert tuple(int(t) for t in a[:PS]) in cache.snapshot()


def test_aliased_page_survives_eviction_until_slot_frees():
    """Refcount-aware eviction: evicting a node whose page a resident slot
    still aliases decrefs but does NOT free the page — it returns to the
    free list only when the slot releases it."""
    alloc = PageAllocator(num_pages=8, num_slots=2, pages_per_slot=4)
    cache = PrefixCache(PS, 8, alloc.incref, alloc.decref)
    toks = np.arange(PS)
    alloc.allocate(0, 1)
    cache.insert(toks, [int(alloc.table[0, 0])])
    alloc.free(0)
    [page] = cache.match(toks)
    alloc.alias(1, [page], 1)  # a resident slot aliases the cached page
    assert alloc.refcount[page] == 2
    [evicted] = cache.evict(1)
    assert evicted == page and alloc.refcount[page] == 1
    assert page not in alloc._free  # still live: the slot holds it
    alloc.free(1)
    assert alloc.refcount[page] == 0 and page in alloc._free


# ---------------------------------------------------- allocator hardening


def test_double_free_slot_raises_with_slot_id():
    alloc = PageAllocator(num_pages=4, num_slots=2, pages_per_slot=2)
    alloc.allocate(1, 2)
    alloc.free(1)
    with pytest.raises(RuntimeError, match="slot 1"):
        alloc.free(1)
    with pytest.raises(RuntimeError, match="slot 0"):
        alloc.free(0)  # never allocated


def test_decref_below_zero_raises_with_page_id():
    alloc = PageAllocator(num_pages=4, num_slots=1, pages_per_slot=2)
    alloc.allocate(0, 1)
    page = int(alloc.table[0, 0])
    alloc.decref(page)
    with pytest.raises(RuntimeError, match=f"page {page}"):
        alloc.decref(page)


def test_incref_free_page_raises():
    alloc = PageAllocator(num_pages=4, num_slots=1, pages_per_slot=2)
    with pytest.raises(RuntimeError, match="page 3"):
        alloc.incref(3)


def test_shared_page_frees_only_at_refcount_zero():
    """alias bumps refcounts; each free decrefs; the page returns to the
    free list only when the LAST holder releases it."""
    alloc = PageAllocator(num_pages=8, num_slots=3, pages_per_slot=4)
    alloc.allocate(0, 2)
    shared = [int(p) for p in alloc.table[0, :2]]
    alloc.alias(1, shared, 1)
    alloc.alias(2, shared, 0)
    assert [alloc.refcount[p] for p in shared] == [3, 3]
    assert alloc.live_pages == 3
    alloc.free(0)
    alloc.free(2)
    assert [alloc.refcount[p] for p in shared] == [1, 1]
    assert alloc.live_pages == 3  # slot 1 still holds both + its fresh page
    alloc.free(1)
    assert alloc.live_pages == 0 and alloc.free_pages == 8


def test_high_water_pages_tracks_peak():
    alloc = PageAllocator(num_pages=8, num_slots=2, pages_per_slot=4)
    alloc.allocate(0, 3)
    alloc.allocate(1, 2)
    alloc.free(1)
    s = alloc.stats()
    assert s["high_water_pages"] == 5 == s["peak_live_pages"]
    assert s["live_pages"] == 3


def test_lru_pages_returns_coldest_leaves_without_touching():
    """lru_pages(n) surfaces the n least-recently-touched LEAF pages (the
    eviction frontier) and match(touch=False) probes without re-warming."""
    alloc = PageAllocator(num_pages=16, num_slots=2, pages_per_slot=8)
    cache = PrefixCache(PS, 16, alloc.incref, alloc.decref)
    a = np.arange(2 * PS) % 3
    b = np.concatenate([a[:PS], np.full(PS, 7)])
    alloc.allocate(0, 2)
    cache.insert(a, [int(p) for p in alloc.table[0, :2]])
    alloc.free(0)
    alloc.allocate(1, 2)
    cache.insert(b, [int(p) for p in alloc.table[1, :2]])
    alloc.free(1)
    cache.match(b)  # warm b: a's leaf is the frontier
    a_leaf = cache.snapshot()[tuple(int(t) for t in a)]
    assert cache.lru_pages(1) == {a_leaf}
    # a touch-free probe must not move a off the frontier...
    cache.match(a, touch=False)
    assert cache.lru_pages(1) == {a_leaf}
    # ...while a touching match re-warms it
    cache.match(a)
    assert cache.lru_pages(1) != {a_leaf}


def test_allocator_resize_slots_requires_idle_pool():
    alloc = PageAllocator(num_pages=16, num_slots=2, pages_per_slot=4)
    alloc.allocate(0, 2)
    with pytest.raises(RuntimeError, match="slot"):
        alloc.resize_slots(4, 4)
    alloc.free(0)
    out = alloc.resize_slots(4, 6)
    assert out is alloc
    assert alloc.table.shape == (4, 6)
    assert not alloc._used.any()


# ------------------------------------------------ cross-engine PrefixStore


def _tiny_serve():
    from repro.configs.base import ModelConfig
    from repro.models import registry
    cfg = ModelConfig(name="tiny-store", family="dense", num_layers=2,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=64, dtype="float32", remat="none")
    import jax
    params = registry.get(cfg).init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _store_cfg(store, **over):
    from repro.serve.config import ServeConfig
    kw = dict(max_len=48, num_slots=2, decode_chunk=4, min_bucket=8,
              kv_layout="paged", page_size=8, num_pages=32,
              prefix_cache=True, prefix_store=store)
    kw.update(over)
    return ServeConfig(**kw)


def _fewshot_requests(vocab, num=4, seed=21):
    from repro.serve.scheduler import Request
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, vocab, (2 * 8,)).astype(np.int32)
    toks = [np.concatenate([prefix,
                            rng.integers(1, vocab, (s,)).astype(np.int32)])
            for s in range(3, 3 + num)]
    return lambda: [Request(uid=i, tokens=toks[i], max_new_tokens=6,
                            arrival=i) for i in range(num)]


def test_prefix_store_cross_engine_adoption_token_exact():
    """A second engine over the same params + store must adopt the first
    engine's radix tree (prefix_hits > 0 from request one), produce
    identical tokens, and prefill suffix-only — with the refcount contract
    (live pages == tree pages) intact through teardown and handoff."""
    from repro.serve.engine import ServeEngine
    from repro.serve.prefix_store import PrefixStore
    cfg, params = _tiny_serve()
    mk = _fewshot_requests(cfg.vocab_size)
    store = PrefixStore()

    eng1 = ServeEngine(cfg, params, _store_cfg(store))
    res1 = eng1.run(mk())
    tree_pages = eng1._prefix.cached_pages
    assert tree_pages > 0
    assert eng1.page_pool_stats()["live_pages"] == tree_pages
    eng1.close()
    assert len(store) == 1 and store.cached_pages() == tree_pages
    assert store.stats["puts"] == 1

    eng2 = ServeEngine(cfg, params, _store_cfg(store))
    assert store.stats["adoptions"] == 1 and len(store) == 0  # single owner
    assert eng2._prefix.cached_pages == tree_pages  # adopted, not rebuilt
    res2 = eng2.run(mk())
    assert set(res2) == set(res1)
    for uid in res1:
        np.testing.assert_array_equal(res2[uid], res1[uid],
                                      err_msg=f"request {uid}")
    # every admission hit the adopted tree; only suffixes were prefilled
    assert eng2.stats["prefix_hits"] == len(res2)
    assert eng2.stats["prefill_tokens"] < eng1.stats["prefill_tokens"]
    assert (eng2.page_pool_stats()["live_pages"]
            == eng2._prefix.cached_pages)
    eng2.close()
    assert store.stats["puts"] == 2 and len(store) == 1


def test_prefix_store_misses_on_different_params_or_geometry():
    """Entries are keyed by params content and pool geometry: a different
    checkpoint or a different page size must NOT adopt cached pages."""
    import jax
    from repro.models import registry
    from repro.serve.engine import ServeEngine
    from repro.serve.prefix_store import PrefixStore
    cfg, params = _tiny_serve()
    params2 = registry.get(cfg).init(jax.random.PRNGKey(1), cfg)
    mk = _fewshot_requests(cfg.vocab_size)
    store = PrefixStore()
    eng1 = ServeEngine(cfg, params, _store_cfg(store))
    eng1.run(mk())
    eng1.close()
    # different checkpoint -> different fingerprint -> cold engine
    eng2 = ServeEngine(cfg, params2, _store_cfg(store))
    assert store.stats["adoptions"] == 0
    assert store.stats["misses"] >= 1
    assert len(store) == 1  # params1's entry still parked
    assert eng2._prefix.cached_pages == 0
    # different pool geometry over the same params -> also a miss
    eng3 = ServeEngine(cfg, params, _store_cfg(store, page_size=16,
                                               min_bucket=16))
    assert store.stats["adoptions"] == 0
    assert eng3._prefix.cached_pages == 0


def test_prefix_store_take_semantics_and_expiry():
    """Host-level contract: take pops (second take misses); an entry whose
    params have been garbage-collected is dropped, not adopted."""
    from repro.serve.prefix_store import PrefixStore

    class Leaf:  # weakref-able stand-in for a params array
        def __init__(self):
            self.shape, self.dtype = (4,), "float32"

        def reshape(self, *_):
            return np.zeros(4, np.float32)

    params = {"w": Leaf()}
    store = PrefixStore()
    key = store.key_for("cfg", params, page_size=8, num_pages=32)
    store.put(key, params, {"k": None, "v": None, "alloc": None,
                            "tree": []})
    assert store.take(key) is not None
    assert store.take(key) is None  # popped: single ownership
    assert store.stats["misses"] == 1
    # expiry: the anchored leaf dies -> entry is dropped at take
    store.put(key, params, {"k": None, "v": None, "alloc": None,
                            "tree": []})
    del params
    assert store.take(key) is None
    assert store.stats["expired"] == 1
