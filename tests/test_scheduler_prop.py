"""Property tests for the serving scheduler and admission sizing policy:
FCFS order is preserved under grouping and backpressure push-front, group
sizes respect the free-slot cap, prefix-aware admission never starves a
request (each is bypassed at most max_skips times) and degrades to strict
FCFS with an empty frontier, pow2 padding is tight, buckets cover every
admissible prompt length, and EP MoE is exempt from pad rows.

Runs under real Hypothesis when installed, else the deterministic shim.
"""
from collections import Counter

import numpy as np
from _hypothesis_shim import given, settings, st

from repro.serve.engine import (_admit_pad_size, _make_buckets, _next_pow2)
from repro.serve.scheduler import (FCFSScheduler, PrefixAwareAdmission,
                                   Request)


def _requests(rnd_seed, n, max_len=24):
    rng = np.random.default_rng(rnd_seed)
    lens = rng.integers(1, max_len + 1, n)
    arrivals = np.sort(rng.integers(0, 4, n))
    return [Request(uid=i, tokens=np.zeros(lens[i], np.int32),
                    max_new_tokens=1, arrival=int(arrivals[i]))
            for i in range(n)]


def _drain(sch, free_slots, key=None):
    groups = []
    while sch.pending:
        g = sch.next_group(free_slots, key=key)
        assert g, "queue non-empty but no group admissible at now=inf"
        groups.append(g)
    return groups


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(1, 20),
       free_slots=st.integers(1, 8))
def test_grouping_preserves_fcfs_order(seed, n, free_slots):
    """Draining the queue group-by-group yields every request exactly once,
    in submission order — grouping never reorders across the FCFS line."""
    reqs = _requests(seed, n)
    sch = FCFSScheduler()
    for r in reqs:
        sch.submit(r)
    groups = _drain(sch, free_slots)
    uids = [r.uid for g in groups for r in g]
    assert uids == [r.uid for r in reqs]


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(1, 20),
       free_slots=st.integers(0, 8),
       bucketed=st.sampled_from([False, True]))
def test_group_respects_cap_and_shares_key(seed, n, free_slots, bucketed):
    """Every group fits the free-slot cap and is key-homogeneous, under
    both the exact-signature key and the coarser bucket key the bucketed
    engine passes."""
    buckets = _make_buckets(32)
    keyf = ((lambda r: next(b for b in buckets if r.prompt_len <= b))
            if bucketed else None)
    sch = FCFSScheduler()
    for r in _requests(seed, n):
        sch.submit(r)
    if free_slots == 0:
        assert sch.next_group(0) == []
        return
    for g in _drain(sch, free_slots, key=keyf):
        assert 1 <= len(g) <= free_slots
        kf = keyf or (lambda r: r.signature())
        assert len({kf(r) for r in g}) == 1


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(2, 20),
       k=st.integers(1, 6))
def test_push_front_restores_fcfs_position(seed, n, k):
    """Backpressure: popping a group and pushing an un-admittable tail back
    leaves the queue exactly as if the tail had never been popped."""
    reqs = _requests(seed, n)
    sch = FCFSScheduler()
    for r in reqs:
        sch.submit(r)
    g = sch.next_group(free_slots=min(k + 1, n))
    keep, tail = g[:1], g[1:]
    sch.push_front(tail)
    rest = [r.uid for r in tail] + [r.uid for gg in _drain(sch, 8)
                                    for r in gg][len(tail):]
    assert [r.uid for r in keep] + rest == [r.uid for r in reqs]


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(2, 20),
       k=st.integers(1, 6), late=st.integers(1, 5))
def test_push_front_preserves_arrival_order(seed, n, k, late):
    """Preempt-and-requeue: a popped head group pushed back to the front
    (preserving each Request's original ``arrival``) drains BEFORE both the
    rest of the queue and any later-arriving submissions — a requeued long
    request never loses its place to later arrivals."""
    reqs = _requests(seed, n)
    sch = FCFSScheduler()
    for r in reqs:
        sch.submit(r)
    g = sch.next_group(free_slots=min(k, n))
    assert all(r.arrival == reqs[i].arrival for i, r in enumerate(g))
    # later arrivals land while the group is out being (p)re-admitted
    newcomers = [Request(uid=1000 + i, tokens=np.zeros(3, np.int32),
                         max_new_tokens=1, arrival=99.0)
                 for i in range(late)]
    for r in newcomers:
        sch.submit(r)
    sch.push_front(g)
    drained = [r.uid for gg in _drain(sch, 8) for r in gg]
    # requeued group first (original order), then the untouched queue,
    # then the late arrivals — exactly the no-preemption FCFS order
    assert drained == ([r.uid for r in g]
                       + [r.uid for r in reqs[len(g):]]
                       + [r.uid for r in newcomers])


# --------------------------------------------- prefix-aware admission


def _counting_policy(policy):
    """Wrap on_admit to count how many times each uid is bypassed."""
    counts = Counter()
    orig = policy.on_admit

    def on_admit(admitted, bypassed):
        for r in bypassed:
            counts[r.uid] += 1
        orig(admitted, bypassed)

    policy.on_admit = on_admit
    return counts


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(1, 24),
       free_slots=st.integers(1, 4), max_skips=st.integers(1, 5),
       hot_frac=st.sampled_from([0.0, 0.3, 0.7, 1.0]))
def test_prefix_aware_never_starves(seed, n, free_slots, max_skips,
                                    hot_frac):
    """Under arbitrary frontier pressure the prefix-aware policy admits
    every request exactly once, and no request is ever bypassed more than
    max_skips times — the aging cap's starvation bound."""
    rng = np.random.default_rng(seed)
    reqs = _requests(seed, n)
    hot = {r.uid for r in reqs if rng.random() < hot_frac}
    policy = PrefixAwareAdmission(
        lambda r: {1} if r.uid in hot else set(),
        lambda: {1},
        max_skips=max_skips)
    counts = _counting_policy(policy)
    sch = FCFSScheduler(policy)
    for r in reqs:
        sch.submit(r)
    drained = [r.uid for g in _drain(sch, free_slots) for r in g]
    assert sorted(drained) == sorted(r.uid for r in reqs)
    assert all(c <= max_skips for c in counts.values()), dict(counts)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), n=st.integers(1, 20),
       free_slots=st.integers(1, 8))
def test_prefix_aware_with_empty_frontier_is_strict_fcfs(seed, n,
                                                         free_slots):
    """With nothing at the eviction frontier the policy must be
    bit-identical to the policy-less scheduler: same groups, same order."""
    reqs = _requests(seed, n)
    plain, aware = FCFSScheduler(), FCFSScheduler(
        PrefixAwareAdmission(lambda r: set(), lambda: set()))
    for r in reqs:
        plain.submit(r)
        aware.submit(r)
    got = [[r.uid for r in g] for g in _drain(aware, free_slots)]
    want = [[r.uid for r in g] for g in _drain(plain, free_slots)]
    assert got == want
    assert aware.policy.stats["bypass_admissions"] == 0


def test_prefix_aware_rescues_frontier_hit_ahead_of_fcfs():
    """A queued request whose cached pages sit at the frontier is admitted
    before earlier cold requests — and the cold requests it bypassed still
    drain in their original relative order."""
    sch = FCFSScheduler(PrefixAwareAdmission(
        lambda r: {7} if r.uid == 2 else set(), lambda: {7}))
    for uid in range(4):
        sch.submit(Request(uid=uid, tokens=np.zeros(8, np.int32),
                           max_new_tokens=1))
    groups = _drain(sch, 1)
    assert [r.uid for g in groups for r in g] == [2, 0, 1, 3]
    assert sch.policy.stats["bypass_admissions"] == 1
    assert sch.policy.stats["bypassed"] == 2


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 4096))
def test_next_pow2_is_tight(n):
    p = _next_pow2(n)
    assert p >= n and p & (p - 1) == 0, (n, p)
    assert p < 2 * n  # tight: halving it would undershoot


@settings(max_examples=30, deadline=None)
@given(max_len=st.integers(2, 4096), min_bucket=st.sampled_from([8, 16, 32]))
def test_buckets_cover_all_prompt_lengths(max_len, min_bucket):
    """Buckets are strictly increasing, end exactly at max_len, and every
    prompt length in [1, max_len] maps to the smallest covering bucket."""
    buckets = _make_buckets(max_len, min_bucket)
    assert list(buckets) == sorted(set(buckets))
    assert buckets[-1] == max_len
    for b in buckets[:-1]:
        assert b & (b - 1) == 0 and b >= min_bucket
    for ln in (1, max_len // 2, max_len):
        b = next(bb for bb in buckets if ln <= bb)
        assert ln <= b
        smaller = [bb for bb in buckets if bb < b]
        assert not smaller or smaller[-1] < ln  # smallest covering bucket


@settings(max_examples=30, deadline=None)
@given(g=st.integers(1, 64),
       moe_impl=st.sampled_from(["dense", "ep"]))
def test_ep_moe_exempt_from_pad_rows(g, moe_impl):
    """Legacy admission pads groups to pow2 — except EP MoE, whose
    expert-capacity buckets depend on the batch token count, so it must
    see exactly the submitted rows."""
    gp = _admit_pad_size(g, moe_impl)
    if moe_impl == "ep":
        assert gp == g
    else:
        assert gp == _next_pow2(g) and gp >= g
