"""Unit + property tests for the AdaGradSelect controller (paper Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.configs.base import SelectConfig
from repro.core import adagradselect, selection


class TestPrimitives:
    def test_topk_mask(self):
        scores = jnp.array([3.0, 1.0, 4.0, 1.5, 9.0])
        mask = selection.topk_mask(scores, 2)
        assert mask.tolist() == [False, False, True, False, True]

    def test_gumbel_without_replacement_exact_k(self):
        probs = jax.random.dirichlet(jax.random.PRNGKey(0), jnp.ones(20))
        for seed in range(5):
            m = selection.sample_without_replacement(
                jax.random.PRNGKey(seed), probs, 7)
            assert int(m.sum()) == 7

    def test_gumbel_sampling_tracks_probs(self):
        """High-probability arms must be drawn far more often."""
        probs = jnp.array([0.70, 0.15, 0.05, 0.04, 0.03, 0.03])
        counts = np.zeros(6)
        for seed in range(400):
            m = selection.sample_without_replacement(
                jax.random.PRNGKey(seed), probs, 1)
            counts += np.asarray(m)
        assert counts[0] > 200, counts
        assert counts[0] > 3 * counts[1]

    def test_dirichlet_probs_normalized(self):
        f = jnp.array([5.0, 0.0, 2.0])
        p = selection.dirichlet_probs(jax.random.PRNGKey(1), f, 1.0)
        assert abs(float(p.sum()) - 1.0) < 1e-5

    def test_always_include(self):
        m = jnp.zeros(5, bool)
        m = selection.apply_always_include(m, (0, 3))
        assert m.tolist() == [True, False, False, True, False]


class TestEpsilon:
    def test_exponential_decay(self):
        cfg = SelectConfig(epsilon0=1.0, epsilon_decay=0.1, steps_per_epoch=100)
        e0 = adagradselect.epsilon(cfg, jnp.asarray(0))
        e10 = adagradselect.epsilon(cfg, jnp.asarray(10))
        assert abs(float(e0) - 1.0) < 1e-6
        assert abs(float(e10) - np.exp(-1.0)) < 1e-5

    def test_epoch2_pure_exploitation(self):
        cfg = SelectConfig(epsilon0=1.0, epsilon_decay=0.0, steps_per_epoch=10)
        assert float(adagradselect.epsilon(cfg, jnp.asarray(10))) == 0.0
        assert float(adagradselect.epsilon(cfg, jnp.asarray(9))) == 1.0


class TestSelect:
    def _run(self, policy, steps=40, nb=10, k=20.0, **kw):
        cfg = SelectConfig(policy=policy, k_percent=k, steps_per_epoch=20, **kw)
        st_ = adagradselect.init_state(nb, seed=3, policy=policy)
        norms = jnp.asarray(np.linspace(2.0, 0.1, nb), jnp.float32)
        masks = []
        for _ in range(steps):
            m, st_ = adagradselect.select(cfg, st_, norms, nb)
            masks.append(np.asarray(m))
        return np.stack(masks), st_, cfg

    @pytest.mark.parametrize("policy", ["adagradselect", "topk_grad", "random",
                                        "lisa", "grass"])
    def test_exact_k_selected(self, policy):
        masks, _, cfg = self._run(policy)
        assert (masks.sum(1) == cfg.num_selected(10)).all()

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError, match="unknown selection policy"):
            adagradselect.get_policy("does_not_exist")

    def test_per_policy_state_pytrees(self):
        s_ada = adagradselect.init_state(6, policy="adagradselect")
        s_rnd = adagradselect.init_state(6, policy="random")
        s_grs = adagradselect.init_state(6, policy="grass")
        assert {"freq", "cum_norms"} <= set(s_ada)
        assert "freq" not in s_rnd and "cum_norms" not in s_rnd
        assert "cum_norms" in s_grs and "freq" not in s_grs

    def test_lisa_resamples_on_interval_only(self):
        masks, _, _ = self._run("lisa", steps=40, k=30.0, lisa_interval=10)
        for t in range(40):
            if t % 10 != 0:  # held fixed inside the interval
                assert (masks[t] == masks[t - 1]).all(), t
        # across 4 resamples of 3-of-10 blocks, at least one change expected
        boundaries = masks[::10]
        assert any((boundaries[i] != boundaries[i - 1]).any()
                   for i in range(1, len(boundaries)))

    def test_grass_tracks_cumulative_signal(self):
        masks, st_, _ = self._run("grass", steps=150, k=20.0)
        counts = masks.sum(0)
        # norms are descending -> top-2 arms should dominate the draws
        assert counts[:2].sum() > counts[5:].sum(), counts
        assert "cum_norms" in st_ and float(st_["cum_norms"][0]) > 0

    def test_all_policy_is_fft(self):
        masks, _, _ = self._run("all")
        assert masks.all()

    def test_topk_grad_matches_alg1(self):
        masks, _, _ = self._run("topk_grad")
        # norms are descending -> always blocks {0, 1}
        assert (masks[:, :2]).all() and not masks[:, 2:].any()

    def test_frequency_counts_match_masks(self):
        masks, st_, _ = self._run("adagradselect")
        np.testing.assert_allclose(np.asarray(st_["freq"]), masks.sum(0))

    def test_exploitation_concentrates_on_high_grad_blocks(self):
        """The bandit should end up favoring the top-gradient arms."""
        masks, st_, _ = self._run("adagradselect", steps=120)
        freq = np.asarray(st_["freq"])
        assert freq[:2].sum() > freq[5:].sum(), freq

    def test_deterministic_in_seed_and_step(self):
        cfg = SelectConfig(policy="adagradselect", k_percent=20)
        norms = jnp.ones(10)
        s1 = adagradselect.init_state(10, seed=5)
        s2 = adagradselect.init_state(10, seed=5)
        m1, _ = adagradselect.select(cfg, s1, norms, 10)
        m2, _ = adagradselect.select(cfg, s2, norms, 10)
        assert (np.asarray(m1) == np.asarray(m2)).all()

    def test_jit_compatible(self):
        cfg = SelectConfig(policy="adagradselect", k_percent=30)
        st_ = adagradselect.init_state(8)
        fn = jax.jit(lambda s, n: adagradselect.select(cfg, s, n, 8))
        m, st2 = fn(st_, jnp.ones(8))
        assert int(m.sum()) == cfg.num_selected(8)


class TestProperties:
    @settings(max_examples=30, deadline=None)
    @given(nb=st.integers(3, 40), kpct=st.floats(1.0, 100.0),
           seed=st.integers(0, 2**30))
    def test_num_selected_bounds(self, nb, kpct, seed):
        cfg = SelectConfig(policy="adagradselect", k_percent=kpct)
        k = cfg.num_selected(nb)
        assert 1 <= k <= nb  # paper guideline: min% >= 100/B
        st_ = adagradselect.init_state(nb, seed=seed)
        m, _ = adagradselect.select(cfg, st_, jnp.ones(nb), nb)
        assert int(m.sum()) == k

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 1000), steps=st.integers(1, 25))
    def test_freq_total_invariant(self, seed, steps):
        """sum(freq) == steps * k after any number of steps."""
        nb = 12
        cfg = SelectConfig(policy="adagradselect", k_percent=25)
        st_ = adagradselect.init_state(nb, seed=seed)
        for _ in range(steps):
            _, st_ = adagradselect.select(cfg, st_, jnp.ones(nb), nb)
        assert int(np.asarray(st_["freq"]).sum()) == steps * cfg.num_selected(nb)
        assert int(st_["step"]) == steps
