"""Serving tests: engine-vs-legacy token-exact parity across model families,
per-slot EOS termination, staggered admission vs solo runs, slot insertion,
scheduler policy, the ServeConfig surface (validation + deprecation shim),
grouped prefix admission, and compile-once behavior of the evaluator."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import ModelConfig
from repro.data.synthetic import MathTaskConfig
from repro.models import registry
from repro.serve import engine as engine_mod
from repro.serve._oracle import generate_legacy
from repro.serve.config import ServeConfig
from repro.serve.engine import ServeEngine, generate
from repro.serve.results import Completion
from repro.serve.scheduler import FCFSScheduler, Request

TINY = ModelConfig(name="tiny-serve", family="dense", num_layers=2,
                   d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                   vocab_size=64, dtype="float32", remat="none")

# one arch per cache family: dense GQA, attention-free ssm, moe
PARITY_ARCHS = ["llama3.2-1b", "mamba2-2.7b", "qwen3-moe-30b-a3b"]


def _params(cfg, seed=0):
    return registry.get(cfg).init(jax.random.PRNGKey(seed), cfg)


def _eng(cfg, params, **kw):
    return ServeEngine(cfg, params, ServeConfig(**kw))


def _prompts(cfg, b, s, seed=1):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)}


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_engine_matches_legacy_generate(arch):
    """Greedy engine decode must be token-for-token identical to the
    pre-engine static-batch loop, with and without EOS termination."""
    cfg = get_smoke_config(arch).replace(ssm_chunk=16)
    params = _params(cfg)
    batch = _prompts(cfg, 3, 16)
    kw = dict(max_new_tokens=10)
    raw_leg = generate_legacy(params, cfg, batch, **kw)
    raw_eng = generate(params, cfg, batch, **kw)
    np.testing.assert_array_equal(raw_eng, raw_leg)
    # pick an EOS id the model actually emits so termination is exercised
    eos = int(raw_leg[0, 4])
    leg = generate_legacy(params, cfg, batch, eos_id=eos, **kw)
    eng = generate(params, cfg, batch, eos_id=eos, **kw)
    np.testing.assert_array_equal(eng, leg)


def test_per_slot_eos_stops_decode_early():
    """EOS terminates a slot on-device: the engine must stop decoding well
    before max_new_tokens when every row hits the attractor token early,
    and still reproduce the legacy (post-hoc masked) outputs."""
    cfg = TINY
    params = _params(cfg)
    # identical prompts -> identical rows -> every slot hits EOS at the
    # same (early) step, so early termination is observable deterministically
    one = _prompts(cfg, 1, 8)["tokens"]
    batch = {"tokens": np.repeat(one, 4, axis=0)}
    raw = generate_legacy(params, cfg, batch, max_new_tokens=32)
    vals, counts = np.unique(raw[0], return_counts=True)
    eos = int(vals[np.argmax(counts)])  # greedy attractor: appears early
    hits = np.flatnonzero(raw[0] == eos)
    assert len(hits) and hits[0] < 16, \
        f"attractor not early enough ({hits[:1]})"

    eng = _eng(cfg, params, max_len=8 + 32, num_slots=4, eos_id=eos,
                      decode_chunk=4)
    out = eng.generate(batch, max_new_tokens=32)
    leg = generate_legacy(params, cfg, batch, max_new_tokens=32, eos_id=eos)
    np.testing.assert_array_equal(out, leg)
    assert eng.stats["decode_steps"] < 32, eng.stats


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-2.7b"])
def test_staggered_admission_matches_solo_runs(arch):
    """Requests with different prompt lengths admitted into free slots as
    others finish must produce exactly the tokens of a solo run."""
    cfg = get_smoke_config(arch).replace(ssm_chunk=16)
    params = _params(cfg)
    rng = np.random.default_rng(3)
    lens = [8, 12, 8, 16, 12]
    arrivals = [0, 0, 1, 3, 4]
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab_size, (lens[i],)),
                    max_new_tokens=9, arrival=arrivals[i])
            for i in range(len(lens))]
    eng = _eng(cfg, params, max_len=32, num_slots=2, decode_chunk=4)
    shared = eng.run([Request(uid=r.uid, tokens=r.tokens, arrival=r.arrival,
                              max_new_tokens=r.max_new_tokens) for r in reqs])
    assert eng.stats["admitted"] == len(reqs)
    for r in reqs:
        solo_eng = _eng(cfg, params, max_len=32, num_slots=1,
                               decode_chunk=4)
        solo = solo_eng.run([Request(uid=0, tokens=r.tokens,
                                     max_new_tokens=r.max_new_tokens)])
        np.testing.assert_array_equal(shared[r.uid], solo[0],
                                      err_msg=f"request {r.uid}")


def test_insert_slots_writes_rows_at_slot_indices():
    from repro.models import lm
    cfg = TINY
    params = _params(cfg)
    batch = _prompts(cfg, 2, 8)
    _, src = lm.prefill(params, cfg, batch, max_len=16)
    cache = lm.init_cache(cfg, 4, 16)
    out = lm.insert_slots(cache, src, np.array([2, 0], np.int32))
    np.testing.assert_array_equal(np.asarray(out["pos"]), [8, 0, 8, 0])
    np.testing.assert_array_equal(np.asarray(out["k"][:, 2]),
                                  np.asarray(src["k"][:, 0]))
    np.testing.assert_array_equal(np.asarray(out["v"][:, 0]),
                                  np.asarray(src["v"][:, 1]))
    # out-of-range slot index is dropped (used to pad admission groups)
    out2 = lm.insert_slots(cache, src, np.array([1, 4], np.int32))
    np.testing.assert_array_equal(np.asarray(out2["pos"]), [0, 8, 0, 0])
    assert not np.asarray(out2["k"][:, 3]).any()


def test_scheduler_fcfs_same_shape_grouping():
    sch = FCFSScheduler()
    tok = lambda n: np.zeros(n, np.int32)  # noqa: E731
    for uid, (ln, arr) in enumerate([(8, 0), (8, 0), (12, 0), (8, 0),
                                     (8, 5)]):
        sch.submit(Request(uid=uid, tokens=tok(ln), max_new_tokens=4,
                           arrival=arr))
    # same-shape grouping never crosses a different-shape head (FCFS)
    g = sch.next_group(free_slots=4, now=0)
    assert [r.uid for r in g] == [0, 1]
    g = sch.next_group(free_slots=4, now=0)
    assert [r.uid for r in g] == [2]
    # arrival gating: uid 4 hasn't arrived at now=0
    g = sch.next_group(free_slots=4, now=0)
    assert [r.uid for r in g] == [3]
    assert sch.next_group(free_slots=4, now=0) == []
    assert sch.next_group(free_slots=4, now=5) != []
    # free-slot cap
    sch2 = FCFSScheduler()
    for uid in range(5):
        sch2.submit(Request(uid=uid, tokens=tok(8), max_new_tokens=4))
    assert len(sch2.next_group(free_slots=3)) == 3
    assert sch2.next_group(free_slots=0) == []


def test_math_accuracy_chunks_and_compiles_once():
    """Two evaluator runs in one process must not rebuild (or recompile)
    any serving closure, and chunked batching must not change the score."""
    from repro.train.evaluate import math_accuracy
    cfg = TINY
    params = _params(cfg)
    task = MathTaskConfig(digits=2, seq_len=40)
    acc1 = math_accuracy(params, cfg, task, num_problems=8, batch_size=4)
    info1 = engine_mod.fn_cache_info()
    acc2 = math_accuracy(params, cfg, task, num_problems=8, batch_size=4)
    info2 = engine_mod.fn_cache_info()
    assert acc2 == acc1
    assert info2["misses"] == info1["misses"], (info1, info2)
    # each cached closure was jit-compiled for exactly one shape set
    for key, fn in engine_mod._FN_CACHE.items():
        if (key[0] in ("admit", "admitb", "chunk", "pchunk", "pfinal")
                and hasattr(fn, "_cache_size")):
            assert fn._cache_size() == 1, key
    # memory scales with batch_size: a different slot count, same answers
    acc3 = math_accuracy(params, cfg, task, num_problems=8, batch_size=8)
    assert acc3 == acc1


def test_generate_temperature_keeps_legacy_rng_stream():
    cfg = TINY
    params = _params(cfg)
    batch = _prompts(cfg, 2, 8)
    a = generate(params, cfg, batch, max_new_tokens=6, temperature=0.7,
                 rng=jax.random.PRNGKey(5))
    b = generate_legacy(params, cfg, batch, max_new_tokens=6, temperature=0.7,
                        rng=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(a, b)


def test_engine_temperature_sampling_is_per_slot():
    """Sampled decoding draws from per-slot key streams: a request's tokens
    must not depend on what else shares the batch."""
    cfg = TINY
    params = _params(cfg)
    rng = np.random.default_rng(9)
    toks = [rng.integers(0, cfg.vocab_size, (8,)) for _ in range(3)]
    eng = _eng(cfg, params, max_len=24, num_slots=3, temperature=0.8,
                      rng=jax.random.PRNGKey(2))
    full = eng.run([Request(uid=i, tokens=toks[i], max_new_tokens=6)
                    for i in range(3)])
    solo_eng = _eng(cfg, params, max_len=24, num_slots=1,
                           temperature=0.8, rng=jax.random.PRNGKey(2))
    solo = solo_eng.run([Request(uid=1, tokens=toks[1], max_new_tokens=6)])
    np.testing.assert_array_equal(full[1], solo[1])


# ----------------------------------------------------- paged KV + bucketing


def _mixed_requests(cfg, seed=7, max_new=8):
    """Mixed-length staggered workload (the paged/bucketed stress shape).

    The ssm prefill scan needs prompt lengths <= ssm_chunk or a multiple of
    it (pre-existing constraint of the exact-length legacy admit path), so
    the ssm arch gets a compatible length mix.
    """
    rng = np.random.default_rng(seed)
    lens = ([8, 16, 8, 12, 32, 5] if cfg.family == "ssm"
            else [8, 21, 8, 16, 30, 5])
    arrivals = [0, 0, 1, 2, 3, 4]
    toks = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]
    return lambda: [Request(uid=i, tokens=toks[i], max_new_tokens=max_new,
                            arrival=arrivals[i]) for i in range(len(lens))]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_paged_engine_matches_dense_engine(arch):
    """kv_layout='paged' must be token-exact vs the dense engine for a
    mixed-length staggered workload — including under pool pressure, where
    admission backpressure queues requests until pages free."""
    cfg = get_smoke_config(arch).replace(ssm_chunk=16)
    params = _params(cfg)
    mk = _mixed_requests(cfg)
    kw = dict(max_len=40, num_slots=3, decode_chunk=4)
    dense = _eng(cfg, params, **kw).run(mk())
    peng = _eng(cfg, params, kv_layout="paged", page_size=4, **kw)
    paged = peng.run(mk())
    assert set(paged) == set(dense)
    for uid in dense:
        np.testing.assert_array_equal(paged[uid], dense[uid],
                                      err_msg=f"request {uid}")
    if cfg.family == "ssm":
        assert peng.page_pool_stats() is None  # paging is a no-op
        return
    assert peng.page_pool_stats()["peak_live_pages"] > 0
    assert peng.page_pool_stats()["live_pages"] == 0  # all freed on finish
    # undersized pool: same tokens, strictly smaller cache, backpressure
    seng = _eng(cfg, params, kv_layout="paged", page_size=4,
                       num_pages=12, **kw)
    small = seng.run(mk())
    for uid in dense:
        np.testing.assert_array_equal(small[uid], dense[uid])
    assert seng.kv_cache_bytes() < _eng(cfg, params, **kw).kv_cache_bytes()
    assert seng.stats["backpressure"] > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-moe-30b-a3b"])
@pytest.mark.parametrize("kv_layout", ["dense", "paged"])
def test_chunked_prefill_matches_single_shot(arch, kv_layout):
    """prefill_chunk=N (interleaved chunked prefill) must reproduce the
    single-shot engine token-for-token."""
    cfg = get_smoke_config(arch).replace(ssm_chunk=16)
    params = _params(cfg)
    mk = _mixed_requests(cfg)
    kw = dict(max_len=40, num_slots=3, decode_chunk=4, kv_layout=kv_layout,
              page_size=4)
    single = _eng(cfg, params, **kw).run(mk())
    ceng = _eng(cfg, params, prefill_chunk=8, **kw)
    chunked = ceng.run(mk())
    assert set(chunked) == set(single)
    for uid in single:
        np.testing.assert_array_equal(chunked[uid], single[uid],
                                      err_msg=f"request {uid}")
    # the len-30 prompt buckets to 32 -> 4 chunks of 8
    assert ceng.stats["prefill_chunks"] >= 4, ceng.stats


def test_prefill_compile_count_bounded_by_buckets():
    """Bucketed admission: many distinct prompt lengths, at most one
    prefill closure per bucket (each jit-compiled for exactly one shape)."""
    cfg = TINY
    params = _params(cfg)
    before = set(engine_mod._FN_CACHE)
    eng = _eng(cfg, params, max_len=48, num_slots=4, decode_chunk=4)
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, tokens=rng.integers(1, cfg.vocab_size, (n,)),
                    max_new_tokens=6)
            for i, n in enumerate([3, 5, 7, 9, 11, 17, 23, 29, 31, 40])]
    eng.run(reqs)
    new_admits = [k for k in engine_mod._FN_CACHE
                  if k not in before and k[0] == "admitb"]
    assert len(new_admits) <= len(eng.prefill_buckets), (
        new_admits, eng.prefill_buckets)
    for k in new_admits:
        assert engine_mod._FN_CACHE[k]._cache_size() == 1, k


def test_submit_rejects_zero_length_prompt():
    eng = _eng(TINY, _params(TINY), max_len=16, num_slots=1)
    req = Request(uid=0, tokens=np.ones(4, np.int32), max_new_tokens=2)
    req.tokens = np.zeros((0,), np.int32)  # bypass Request validation
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(req)


def test_pool_exhausted_vs_backpressure():
    """A request that can NEVER fit raises PoolExhausted at submit;
    transient pressure only queues (and completes)."""
    from repro.serve.pages import PoolExhausted
    cfg = TINY
    params = _params(cfg)
    eng = _eng(cfg, params, max_len=32, num_slots=4,
                      kv_layout="paged", page_size=4, num_pages=5)
    # 8 prompt + 20 new = 28 positions = 7 pages > 5-page pool
    with pytest.raises(PoolExhausted, match="grow num_pages"):
        eng.submit(Request(uid=9, tokens=np.ones(8, np.int32),
                           max_new_tokens=20))
    # three 4-page requests against 5 pages: admitted one at a time
    rng = np.random.default_rng(4)
    toks = [rng.integers(1, cfg.vocab_size, (8,)) for _ in range(3)]
    res = eng.run([Request(uid=i, tokens=toks[i], max_new_tokens=8)
                   for i in range(3)])
    assert eng.stats["backpressure"] > 0
    deng = _eng(cfg, params, max_len=32, num_slots=4)
    dres = deng.run([Request(uid=i, tokens=toks[i], max_new_tokens=8)
                     for i in range(3)])
    for uid in dres:
        np.testing.assert_array_equal(res[uid], dres[uid])


def test_paged_rejects_unsupported_family():
    cfg = TINY.replace(use_mla=True, kv_lora_rank=16, qk_rope_head_dim=8,
                       qk_nope_head_dim=8, v_head_dim=16)
    with pytest.raises(ValueError, match="paged KV cache is not supported"):
        _eng(cfg, None, max_len=16, num_slots=1, kv_layout="paged")


def test_fn_cache_lru_eviction():
    """The compiled-fn cache is a bounded LRU: over-limit inserts evict the
    coldest entry and count it."""
    from repro.serve.engine import make_prefill_fn, set_fn_cache_limit
    old_limit = engine_mod._FN_LIMIT
    try:
        set_fn_cache_limit(2)
        ev0 = engine_mod.fn_cache_info()["evictions"]
        for ml in (101, 102, 103, 104):
            make_prefill_fn(TINY, ml)
        info = engine_mod.fn_cache_info()
        assert info["size"] <= 2
        assert info["evictions"] > ev0
        # most-recent key survives, oldest was evicted
        assert any(k[0] == "prefill" and k[2] == 104
                   for k in engine_mod._FN_CACHE)
        assert not any(k[0] == "prefill" and k[2] == 101
                       for k in engine_mod._FN_CACHE)
    finally:
        set_fn_cache_limit(old_limit)


# ------------------------------------- radix prefix cache + preemption


def _shared_prefix_requests(cfg, page_size=8, seed=11, max_new=6):
    """GSM8K-style workload: one shared few-shot prefix (3 full pages),
    per-request question suffixes, plus an exact page-aligned duplicate of
    the prefix (the full-prompt-match COW case). Arrivals staggered so
    earlier completions populate the radix tree before later admissions."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, (3 * page_size,)).astype(np.int32)
    toks = [np.concatenate([prefix,
                            rng.integers(1, cfg.vocab_size, (s,))
                            .astype(np.int32)])
            for s in (5, 9, 2)]
    toks.append(prefix.copy())  # full-prompt match -> copy-on-write
    return lambda: [Request(uid=i, tokens=toks[i], max_new_tokens=max_new,
                            arrival=i) for i in range(len(toks))]


def test_prefix_cache_token_exact_and_suffix_only_prefill():
    """Prefix-on must be token-exact vs prefix-off (paged) AND vs the dense
    engine on a shared-prefix workload — including the full-prompt-match
    COW case — while prefilling only the uncached suffixes."""
    cfg = TINY
    params = _params(cfg)
    mk = _shared_prefix_requests(cfg)
    kw = dict(max_len=48, num_slots=1, decode_chunk=4, min_bucket=8)
    pkw = dict(kv_layout="paged", page_size=8, num_pages=32, **kw)
    dense = _eng(cfg, params, **kw).run(mk())
    off_eng = _eng(cfg, params, **pkw)
    off = off_eng.run(mk())
    on_eng = _eng(cfg, params, prefix_cache=True, **pkw)
    on = on_eng.run(mk())
    assert set(on) == set(off) == set(dense)
    for uid in dense:
        np.testing.assert_array_equal(on[uid], dense[uid],
                                      err_msg=f"request {uid} (vs dense)")
        np.testing.assert_array_equal(on[uid], off[uid],
                                      err_msg=f"request {uid} (vs off)")
    # num_slots=1 -> each completion lands in the tree before the next
    # admission: uids 1..3 all hit the 24-token cached prefix
    assert on_eng.stats["prefix_hits"] == 3, on_eng.stats
    assert on_eng.stats["prefix_pages_shared"] > 0
    assert off_eng.stats["prefix_hits"] == 0
    # suffix-only prefill: true-token accounting shows the saving (uid 3 is
    # a full-prompt match -> 1-token COW prefill instead of 24)
    assert on_eng.stats["prefill_tokens"] < off_eng.stats["prefill_tokens"]
    saved = sum(3 * 8 for _ in range(2)) + (3 * 8 - 1)  # uids 1,2 + COW uid 3
    assert (off_eng.stats["prefill_tokens"]
            - on_eng.stats["prefill_tokens"]) == saved
    # every slot released its pages: the only live pages left are the
    # radix tree's cached prefixes
    assert (on_eng.page_pool_stats()["live_pages"]
            == on_eng._prefix.cached_pages)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_preempt_and_requeue_token_exact(temperature):
    """Preempted + re-admitted requests must reproduce the never-preempted
    run token-for-token — greedy and sampled (per-slot key streams carry
    across preemption)."""
    cfg = TINY
    params = _params(cfg)
    rng = np.random.default_rng(13)
    lens = [8, 8, 8, 8, 8]
    news = [4, 12, 6, 12, 8]  # uneven budgets: victim = most remaining
    toks = [rng.integers(1, cfg.vocab_size, (n,)).astype(np.int32)
            for n in lens]
    mk = lambda: [Request(uid=i, tokens=toks[i],  # noqa: E731
                          max_new_tokens=news[i]) for i in range(len(lens))]
    kw = dict(max_len=32, num_slots=4, decode_chunk=4, min_bucket=8,
              kv_layout="paged", page_size=4, temperature=temperature,
              rng=jax.random.PRNGKey(6))
    ample = _eng(cfg, params, num_pages=40, **kw).run(mk())
    peng = _eng(cfg, params, num_pages=6, preempt=True, **kw)
    pre = peng.run(mk())
    assert set(pre) == set(ample)
    for uid in ample:
        np.testing.assert_array_equal(pre[uid], ample[uid],
                                      err_msg=f"request {uid}")
    assert peng.stats["preempted"] >= 1, peng.stats
    assert peng.page_pool_stats()["live_pages"] == 0


def test_prefix_cache_with_preemption_token_exact():
    """The combined path — prefix-accelerated re-admission of preempted
    requests over an oversubscribed pool — stays token-exact vs dense."""
    cfg = TINY
    params = _params(cfg)
    mk = _shared_prefix_requests(cfg, max_new=8)
    kw = dict(max_len=48, num_slots=3, decode_chunk=4, min_bucket=8)
    dense = _eng(cfg, params, **kw).run(mk())
    eng = _eng(cfg, params, kv_layout="paged", page_size=8,
                      num_pages=10, prefix_cache=True, preempt=True, **kw)
    out = eng.run(mk())
    for uid in dense:
        np.testing.assert_array_equal(out[uid], dense[uid],
                                      err_msg=f"request {uid}")
    # only the radix tree's cached prefixes remain live, within the pool
    assert (eng.page_pool_stats()["live_pages"]
            == eng._prefix.cached_pages)
    assert eng.page_pool_stats()["high_water_pages"] <= 10


def test_stream_out_matches_run_and_propagates_errors():
    """on_complete fires off the hot loop with a ``Completion`` record for
    every finished request, carrying exactly run()'s tokens; a raising
    callback surfaces from run() (via drain) instead of being swallowed on
    the worker thread."""
    cfg = TINY
    params = _params(cfg)
    mk = _mixed_requests(cfg, max_new=4)
    got = {}
    eng = _eng(cfg, params, max_len=40, num_slots=3, decode_chunk=4,
               on_complete=lambda c: got.__setitem__(c.uid, c))
    res = eng.run(mk())
    assert set(got) == set(res)
    for uid in res:
        assert isinstance(got[uid], Completion)
        np.testing.assert_array_equal(got[uid].tokens, res[uid])
        assert got[uid].finish_reason in ("eos", "length")
        assert got[uid].done_step >= got[uid].first_token_step

    def boom(comp):
        raise RuntimeError("detok failed")

    beng = _eng(cfg, params, max_len=40, num_slots=3, decode_chunk=4,
                on_complete=boom)
    with pytest.raises(RuntimeError, match="detok failed"):
        beng.run(mk())


# ------------------------------- ServeConfig surface + grouped admission


def test_serve_config_validation():
    with pytest.raises(ValueError, match="decode_chunk"):
        ServeConfig(max_len=32, num_slots=2, decode_chunk=0)
    with pytest.raises(ValueError, match="min_bucket"):
        ServeConfig(max_len=32, num_slots=2, min_bucket=12)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServeConfig(max_len=32, num_slots=2, prefix_cache=True)
    with pytest.raises(ValueError, match="admission"):
        ServeConfig(max_len=32, num_slots=2, admission="lifo")
    with pytest.raises(ValueError, match="prefix_aware"):
        ServeConfig(max_len=32, num_slots=2, admission="prefix_aware")
    with pytest.raises(ValueError, match="prefix_store"):
        ServeConfig(max_len=32, num_slots=2,
                    prefix_store=object())


def test_legacy_kwargs_shim_warns_and_matches_new_surface():
    """ServeEngine(cfg, params, **kwargs) still works for one release: it
    warns, builds the same ServeConfig, and wraps a legacy (uid, tokens)
    on_complete callback."""
    cfg = TINY
    params = _params(cfg)
    mk = _mixed_requests(cfg, max_new=4)
    got = {}
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        leg_eng = ServeEngine(cfg, params, max_len=40, num_slots=3,
                              decode_chunk=4,
                              on_complete=lambda uid, t:
                              got.__setitem__(uid, t))
    assert leg_eng.serve_cfg.max_len == 40
    leg = leg_eng.run(mk())
    new = _eng(cfg, params, max_len=40, num_slots=3, decode_chunk=4).run(mk())
    assert set(leg) == set(new) == set(got)
    for uid in new:
        np.testing.assert_array_equal(leg[uid], new[uid])
        np.testing.assert_array_equal(got[uid], new[uid])
    with pytest.raises(TypeError, match="both a ServeConfig"):
        ServeEngine(cfg, params, ServeConfig(max_len=40, num_slots=1),
                    decode_chunk=4)


def test_run_result_carries_completions():
    cfg = TINY
    params = _params(cfg)
    mk = _mixed_requests(cfg, max_new=4)
    res = _eng(cfg, params, max_len=40, num_slots=3, decode_chunk=4).run(mk())
    assert set(res.completions) == set(res)
    for uid, comp in res.completions.items():
        assert comp.uid == uid
        np.testing.assert_array_equal(comp.tokens, res[uid])
        assert comp.finish_reason == "length"  # no eos_id configured


def test_engine_close_is_terminal():
    cfg = TINY
    params = _params(cfg)
    eng = _eng(cfg, params, max_len=16, num_slots=1)
    eng.close()
    eng.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit(Request(uid=0, tokens=np.ones(4, np.int32),
                           max_new_tokens=2))
    with pytest.raises(RuntimeError, match="closed"):
        eng.step()


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_grouped_prefix_admission_token_exact(temperature):
    """Same-start grouped admission (prefill_rows > 1: one [rows, bucket]
    suffix prefill per wave) must reproduce one-request-per-call admission
    token-for-token — greedy AND sampled (per-slot key streams make the
    grouping invisible) — with identical suffix-only prefill_tokens but
    fewer prefill dispatches."""
    cfg = TINY
    params = _params(cfg)
    rng = np.random.default_rng(17)
    prefix = rng.integers(1, cfg.vocab_size, (16,)).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(1, cfg.vocab_size, (5,))
                               .astype(np.int32)])
               for _ in range(8)]
    kw = dict(max_len=32, num_slots=4, decode_chunk=4, min_bucket=8,
              kv_layout="paged", page_size=8, num_pages=32,
              prefix_cache=True, temperature=temperature,
              rng=jax.random.PRNGKey(3))
    mk = lambda: [Request(uid=i, tokens=prompts[i],  # noqa: E731
                          max_new_tokens=6) for i in range(len(prompts))]
    one_eng = _eng(cfg, params, prefill_rows=1, **kw)
    one = one_eng.run(mk())
    grp_eng = _eng(cfg, params, prefill_rows=4, **kw)
    grp = grp_eng.run(mk())
    assert set(grp) == set(one)
    for uid in one:
        np.testing.assert_array_equal(grp[uid], one[uid],
                                      err_msg=f"request {uid}")
    # same suffix-only token accounting, fewer dispatches
    assert grp_eng.stats["prefill_tokens"] == one_eng.stats["prefill_tokens"]
    assert grp_eng.stats["prefills"] < one_eng.stats["prefills"]
    assert grp_eng.stats["prefix_hits"] == one_eng.stats["prefix_hits"] > 0


def test_prefix_aware_admission_token_exact_vs_fcfs():
    """admission='prefix_aware' may only reorder admissions, never change
    tokens: every request must complete with exactly its strict-FCFS
    output (per-slot key streams make order invisible to sampling)."""
    cfg = TINY
    params = _params(cfg)
    rng = np.random.default_rng(23)
    prefix = rng.integers(1, cfg.vocab_size, (16,)).astype(np.int32)
    toks = []
    for i in range(10):
        tail = rng.integers(1, cfg.vocab_size, (3 + (i % 4),))
        toks.append(np.concatenate([prefix, tail]).astype(np.int32)
                    if i % 2 == 0 else
                    rng.integers(1, cfg.vocab_size,
                                 (16 + (i % 5),)).astype(np.int32))
    mk = lambda: [Request(uid=i, tokens=toks[i],  # noqa: E731
                          max_new_tokens=5, arrival=i // 3)
                  for i in range(len(toks))]
    kw = dict(max_len=32, num_slots=2, decode_chunk=4, min_bucket=8,
              kv_layout="paged", page_size=8, num_pages=24,
              prefix_cache=True, prefix_cache_pages=6)
    fcfs = _eng(cfg, params, admission="fcfs", **kw).run(mk())
    pa_eng = _eng(cfg, params, admission="prefix_aware",
                  admission_max_skips=3, **kw)
    pa = pa_eng.run(mk())
    assert set(pa) == set(fcfs)  # nobody starves
    for uid in fcfs:
        np.testing.assert_array_equal(pa[uid], fcfs[uid],
                                      err_msg=f"request {uid}")


def test_insert_slots_paged_routes_through_table():
    """Rows land on their table's pages; pad slots and positions past the
    row's length are dropped; pool pages of other slots are untouched."""
    from repro.models import lm
    cfg = TINY
    params = _params(cfg)
    batch = _prompts(cfg, 2, 8)
    _, src = lm.prefill(params, cfg, batch, max_len=8)
    ps, num_pages = 4, 6
    cache = lm.init_paged_cache(cfg, 3, 16, ps, num_pages)
    table = np.full((3, 4), num_pages, np.int32)
    table[2, :2] = [5, 1]   # slot 2: pages 5 then 1
    table[0, :2] = [0, 3]
    cache = {**cache, "pages": jax.numpy.asarray(table)}
    out = lm.insert_slots_paged(cache, src, np.array([2, 3], np.int32),
                                np.array([6, 8], np.int32))
    np.testing.assert_array_equal(np.asarray(out["pos"]), [0, 0, 6])
    # slot 2 row 0: positions 0..3 -> page 5, positions 4..5 -> page 1
    np.testing.assert_array_equal(np.asarray(out["k"][:, 5]),
                                  np.asarray(src["k"][:, 0, :ps]))
    np.testing.assert_array_equal(np.asarray(out["k"][:, 1, :2]),
                                  np.asarray(src["k"][:, 0, ps:ps + 2]))
    # positions >= length (6,7) dropped; row 1 (pad slot 3) dropped entirely
    assert not np.asarray(out["k"][:, 1, 2:]).any()
    for pg in (0, 2, 3, 4):
        assert not np.asarray(out["k"][:, pg]).any(), pg
