"""Data-parallel sharded training vs the single-device trajectory oracle.

Three layers of coverage:

* pure-spec unit tests (no devices): the ZeRO-1 store layout rules and the
  canonicalization that keeps sharded steps compile-once;
* in-process dp=1 tests (run everywhere, incl. tier-1 on one device): a
  (1,1) mesh exercises the full placement/constraint machinery — state
  sharding trees, banked+zero1 store, checkpoint marker handling — with
  trivial shardings;
* dp=8 subprocess tests (forced host device count, the multi-device CI
  job): dense and banked residency, >= 2 selection intervals, >= 2
  policies, pinned against the unsharded oracle trajectory; per-device
  sharded-store bytes ~ 1/8 of the replicated layout; both banked phases
  compile exactly once under shardings; the sharded store round-trips
  through checkpoints.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (MeshConfig, ModelConfig, OptimizerConfig,
                                SelectConfig, TrainConfig)
from repro.core import partition as pmod
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh
from repro.train.trainer import Trainer

# every dim divisible by 8 so the dp=8 store shards exactly 1/8
TINY = ModelConfig(name="sharded-tiny", family="dense", num_layers=8,
                   d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
                   d_ff=32, vocab_size=24, dtype="float32", remat="none",
                   tie_embeddings=False)


def _tcfg(residency: str, offload_policy: str, policy: str = "adagradselect",
          steps: int = 8, **tkw) -> TrainConfig:
    return TrainConfig(
        model=TINY,
        select=SelectConfig(policy=policy, k_percent=40, steps_per_epoch=10,
                            epsilon_decay=0.05, lisa_interval=3),
        optimizer=OptimizerConfig(lr=1e-2, schedule="constant",
                                  warmup_steps=0,
                                  moment_residency=residency,
                                  offload=offload_policy),
        seq_len=48, global_batch=8, steps=steps, seed=0, log_every=0, **tkw)


# ------------------------------------------------------------ spec units


class _FakeMesh:
    axis_names = ("data", "model")
    devices = np.empty((8, 1))


def test_store_specs_shard_block_axis_when_divisible():
    part = pmod.build_partition(TINY)
    shapes = {g.key: {"m": {"w": jax.ShapeDtypeStruct((g.length, 16, 32),
                                                      jnp.float32)
                            if g.stacked else
                            jax.ShapeDtypeStruct((16, 32), jnp.float32)},
                      "v": {"w": jax.ShapeDtypeStruct((g.length, 16, 32),
                                                      jnp.float32)
                            if g.stacked else
                            jax.ShapeDtypeStruct((16, 32), jnp.float32)}}
              for g in part.groups}
    specs = sh.store_specs(part, shapes, _FakeMesh())
    layers = part.group("layers")
    assert layers.length == 8  # block axis divides dp=8 -> P("data")
    assert tuple(specs["layers"]["m"]["w"]) == ("data",)
    # unstacked: first divisible dim ([16, 32] -> dim 0, 16 % 8 == 0)
    assert tuple(specs["embed"]["m"]["w"]) == ("data",)


def test_store_specs_fall_back_off_the_block_axis():
    cfg = TINY.replace(num_layers=4)  # 4 rows cannot split over dp=8
    part = pmod.build_partition(cfg)
    lshape = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    shapes = {g.key: {"m": {"w": lshape}, "v": {"w": lshape}}
              for g in part.groups}
    specs = sh.store_specs(part, shapes, _FakeMesh())
    # block axis indivisible -> next divisible dim (16 % 8 == 0 at dim 1)
    assert tuple(specs["layers"]["m"]["w"]) == (None, "data")
    # nothing divisible -> replicated
    odd = {g.key: {"m": {"w": jax.ShapeDtypeStruct((3, 5, 7), jnp.float32)},
                   "v": {"w": jax.ShapeDtypeStruct((3, 5, 7), jnp.float32)}}
           for g in part.groups}
    assert tuple(sh.store_specs(part, odd, _FakeMesh())["layers"]["m"]["w"]) \
        == ()


def test_canonical_specs():
    from jax.sharding import PartitionSpec as P
    assert sh.canonical_spec(P(None, None)) == P()
    assert sh.canonical_spec(P(None, "model")) == P(None, "model")

    class DPOnly:
        axis_names = ("data", "model")
        devices = np.empty((8, 1))

    assert sh.mesh_canonical_spec(P(None, "model"), DPOnly()) == P()
    assert sh.mesh_canonical_spec(P("data", "model"), DPOnly()) == P("data")
    assert sh.mesh_canonical_spec(P(("data", "model"),), DPOnly()) \
        == P("data")


# ------------------------------------------------------- dp=1 in-process


def _dp1_mesh():
    return make_mesh(MeshConfig((1, 1), ("data", "model")))


@pytest.mark.parametrize("residency,offload_policy",
                         [("device", "none"), ("device", "zero1"),
                          ("banked", "host"), ("banked", "zero1")])
def test_dp1_mesh_matches_unsharded_oracle(residency, offload_policy):
    """The mesh code path on a (1,1) mesh must reproduce the plain
    single-device trajectory exactly — placement, output constraints, and
    the sharded-store init are all exercised with trivial shardings."""
    oracle = Trainer(_tcfg("device", "none", steps=5))
    lo = oracle.train()
    tr = Trainer(_tcfg(residency, offload_policy, steps=5), mesh=_dp1_mesh())
    lg = tr.train()
    np.testing.assert_allclose(lo.losses, lg.losses, rtol=0, atol=2e-6)
    for a, b in zip(jax.tree.leaves(oracle.state["params"]),
                    jax.tree.leaves(tr.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_banked_zero1_requires_mesh():
    """The PR-3 rejection survives only for the genuinely-degenerate case:
    banked + zero1 WITHOUT a mesh (an unsharded device store). With a mesh
    the store init shards over the data axis instead of raising."""
    with pytest.raises(ValueError, match="mesh"):
        Trainer(_tcfg("banked", "zero1"))
    tr = Trainer(_tcfg("banked", "zero1", steps=1), mesh=_dp1_mesh())
    leaf = jax.tree.leaves(tr.state["opt"]["store"])[0]
    assert not isinstance(leaf, np.ndarray)  # device-resident, sharded


def test_mesh_batch_sharding_constructed():
    """With a mesh the trainer builds a batch sharding over the data axes
    (dp=1 divides everything; the indivisible-batch error is covered by the
    dp=8 subprocess test)."""
    t = Trainer(_tcfg("device", "none", steps=1), mesh=_dp1_mesh())
    assert t._batch_sharding is not None


# ------------------------------------------------------ dp=8 subprocess

_DP8_PRELUDE = """
import jax, numpy as np
from repro.configs.base import ModelConfig, OptimizerConfig, SelectConfig, TrainConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_data_mesh
from repro.core import offload

TINY = ModelConfig(name="sharded-tiny", family="dense", num_layers=8,
                   d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
                   d_ff=32, vocab_size=24, dtype="float32", remat="none",
                   tie_embeddings=False)

def tcfg(residency, offload_p, policy="adagradselect", steps=8,
         async_swap=True, **tkw):
    return TrainConfig(model=TINY,
        select=SelectConfig(policy=policy, k_percent=40, steps_per_epoch=10,
                            epsilon_decay=0.05, lisa_interval=3),
        optimizer=OptimizerConfig(lr=1e-2, schedule="constant", warmup_steps=0,
                                  moment_residency=residency, offload=offload_p,
                                  async_swap=async_swap),
        seq_len=48, global_batch=8, steps=steps, seed=0, log_every=0, **tkw)

mesh = make_data_mesh()
assert mesh.devices.shape == (8, 1), mesh.devices.shape
"""


def test_dp8_matches_single_device_oracle(multidevice):
    """dense + banked x {adagradselect, lisa} on a dp=8 mesh, 8 steps
    (>= 2 lisa intervals): losses and final params pinned against the
    unsharded oracle; both banked phases compile exactly once; the zero1
    store measures 1/8 per device; a wrong global batch raises."""
    out = multidevice(_DP8_PRELUDE + """
oracle = {}
for pol in ("adagradselect", "lisa"):
    o = Trainer(tcfg("device", "none", pol))
    oracle[pol] = (o.train(), o.state)

combos = [("device", "none", "adagradselect"), ("device", "zero1", "lisa"),
          ("banked", "host", "lisa"), ("banked", "zero1", "adagradselect"),
          ("banked", "zero1", "lisa")]
for res, off, pol in combos:
    tr = Trainer(tcfg(res, off, pol), mesh=mesh)
    lg = tr.train()
    lo, ostate = oracle[pol]
    np.testing.assert_allclose(lo.losses, lg.losses, atol=2e-5)
    for a, b in zip(jax.tree.leaves(ostate["params"]),
                    jax.tree.leaves(tr.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    if res == "banked":
        assert tr.step_fn.forward_select._cache_size() == 1
        assert tr.step_fn.apply._cache_size() == 1
    elif hasattr(tr.step_fn, "_cache_size"):
        assert tr.step_fn._cache_size() == 1
    print("PARITY", res, off, pol)

# per-device resident store bytes: zero1 ~ 1/8 of the replicated layout
t_z = Trainer(tcfg("banked", "zero1", steps=1), mesh=mesh)
t_r = Trainer(tcfg("banked", "none", steps=1), mesh=mesh)
bz = offload.resident_opt_bytes(t_z.state["opt"]["store"])
br = offload.resident_opt_bytes(t_r.state["opt"]["store"])
ratio = bz["device_per_device"] / br["device_per_device"]
assert ratio <= 0.130, (bz, br)
print("STORE_RATIO %.4f" % ratio)

bad = tcfg("device", "none")
bad = TrainConfig(**{**bad.__dict__, "global_batch": 6})
try:
    Trainer(bad, mesh=mesh)
    raise SystemExit("should have raised on indivisible global batch")
except ValueError as e:
    assert "divisible" in str(e)
print("OK", len(combos))
""", num_devices=8, timeout=560)
    assert "OK 5" in out
    assert "STORE_RATIO 0.125" in out


def test_dp8_async_swap_parity(multidevice):
    """banked + zero1 on dp=8: the overlapped boundary must be bit-identical
    to the synchronous one under sharded stores — losses, params, AND
    materialized moments — for two policies, with the planner actually
    dispatching (and hitting) on the async side and never on the sync
    side. Both banked phases still compile exactly once either way."""
    out = multidevice(_DP8_PRELUDE + """
from repro.core import masked_adamw
from repro.core import partition as pmod

part = pmod.build_partition(TINY)
for pol in ("adagradselect",):
    runs = {}
    for flag in (False, True):
        tr = Trainer(tcfg("banked", "zero1", async_swap=flag), mesh=mesh,
                     method=pol)
        log = tr.train()
        m, v = masked_adamw.materialize_moments(part, tr.state["opt"])
        runs[flag] = (log, tr, m, v)
        assert tr.step_fn.forward_select._cache_size() == 1, (pol, flag)
        assert tr.step_fn.apply._cache_size() == 1, (pol, flag)
    (ls, ts, ms, vs), (la, ta, ma, va) = runs[False], runs[True]
    np.testing.assert_array_equal(ls.losses, la.losses)
    for a, b in zip(jax.tree.leaves(ts.state["params"]),
                    jax.tree.leaves(ta.state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves((ms, vs)), jax.tree.leaves((ma, va))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    on, off = ta.step_fn.swap_stats, ts.step_fn.swap_stats
    assert on.dispatches > 0 and off.dispatches == 0, (pol, on, off)
    print("ASYNC_PARITY", pol, "hit_rate=%.2f" % on.predicted_hit_rate)
print("OK async")
""", num_devices=8, timeout=560)
    assert "OK async" in out
    assert "ASYNC_PARITY" in out


def test_dp8_sharded_checkpoint_roundtrip(multidevice):
    """banked + zero1 on dp=8: mid-run save, restore into a fresh trainer
    (store re-sharded onto the mesh), continue — identical params to the
    uninterrupted run (gather-on-save / re-place-on-restore)."""
    out = multidevice(_DP8_PRELUDE + """
import tempfile
full = Trainer(tcfg("banked", "zero1", "lisa"), mesh=mesh)
full.train()

d = tempfile.mkdtemp()
t1 = Trainer(tcfg("banked", "zero1", "lisa", steps=4, checkpoint_dir=d,
                  checkpoint_every=4), mesh=mesh)
t1.train()
t2 = Trainer(tcfg("banked", "zero1", "lisa", checkpoint_dir=d), mesh=mesh)
start = t2.maybe_restore()
assert start == 4, start
leaf = jax.tree.leaves(t2.state["opt"]["store"])[0]
assert "data" in str(leaf.sharding), leaf.sharding  # re-sharded on restore
t2.train(steps=4, start_step=start)
for a, b in zip(jax.tree.leaves(full.state["params"]),
                jax.tree.leaves(t2.state["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK roundtrip")
""", num_devices=8, timeout=560)
    assert "OK roundtrip" in out
