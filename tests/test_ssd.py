"""Mamba2 SSD: chunked form vs naive recurrence, decode handoff, chunk-size
invariance (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.configs.base import ModelConfig
from repro.models.layers import ssm


def _naive(xs, dt, a, b, c):
    bsz, s, h, p = xs.shape
    n = b.shape[-1]
    state = jnp.zeros((bsz, h, p, n))
    ys = []
    for t in range(s):
        da = jnp.exp(a[None] * dt[:, t])
        state = state * da[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhpn", dt[:, t], b[:, t], xs[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, c[:, t]))
    return jnp.stack(ys, 1), state


def _rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def test_chunked_matches_naive():
    B, S, H, P, N = 2, 64, 4, 8, 8
    xs, dt = _rand(0, B, S, H, P), jax.nn.softplus(_rand(1, B, S, H))
    a = -jnp.exp(0.3 * _rand(2, H))
    b, c = _rand(3, B, S, H, N), _rand(4, B, S, H, N)
    y_ref, s_ref = _naive(xs, dt, a, b, c)
    y, s = ssm.ssd_chunked(xs, dt, a, b, c, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16, 32, 64]), seed=st.integers(0, 50))
def test_chunk_size_invariance(chunk, seed):
    """The chunked SSD result must not depend on the chunk size."""
    B, S, H, P, N = 1, 64, 2, 4, 4
    xs = _rand(seed, B, S, H, P)
    dt = jax.nn.softplus(_rand(seed + 1, B, S, H))
    a = -jnp.exp(0.3 * _rand(seed + 2, H))
    b, c = _rand(seed + 3, B, S, H, N), _rand(seed + 4, B, S, H, N)
    y64, s64 = ssm.ssd_chunked(xs, dt, a, b, c, chunk=64)
    y, s = ssm.ssd_chunked(xs, dt, a, b, c, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y64), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s64), atol=1e-4)


def test_layer_prefill_decode_consistency():
    cfg = ModelConfig(name="t", family="ssm", d_model=32, ssm_state=8,
                      ssm_head_dim=8, ssm_expand=2, ssm_chunk=16,
                      dtype="float32", num_heads=0, num_kv_heads=0)
    params = ssm.init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * _rand(9, 2, 48, 32)
    full = ssm.apply(params, cfg, x)
    out, (conv, state) = ssm.apply(params, cfg, x[:, :32], return_state=True)
    outs = [out]
    for t in range(32, 48):
        o, conv, state = ssm.apply_decode(params, cfg, x[:, t:t + 1], conv, state)
        outs.append(o)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full), atol=2e-5)


def test_state_decay_stability():
    """Long-run decode must not blow up (A strictly negative)."""
    cfg = ModelConfig(name="t", family="ssm", d_model=16, ssm_state=4,
                      ssm_head_dim=8, ssm_expand=2, ssm_chunk=8,
                      dtype="float32", num_heads=0, num_kv_heads=0)
    params = ssm.init(jax.random.PRNGKey(0), cfg)
    d_inner, nheads, gn = ssm.dims(cfg)
    conv = {"x": jnp.zeros((1, cfg.ssm_conv - 1, d_inner)),
            "b": jnp.zeros((1, cfg.ssm_conv - 1, gn)),
            "c": jnp.zeros((1, cfg.ssm_conv - 1, gn))}
    state = jnp.zeros((1, nheads, cfg.ssm_head_dim, cfg.ssm_state))
    x = 0.5 * _rand(5, 1, 1, 16)
    for _ in range(200):
        o, conv, state = ssm.apply_decode(params, cfg, x, conv, state)
    assert bool(jnp.isfinite(state).all()) and float(jnp.abs(state).max()) < 1e3
