"""Async swap planner + the boundary primitives it composes.

Four layers:

* property tests for the OOB-sentinel contracts the fused kernel and the
  swap lean on — ``gather_rows`` fills out-of-range slots with zeros,
  ``scatter_rows`` drops out-of-range rows;
* property tests for ``adagradselect.predict_next`` — always a subset-legal
  static-shape [k] vector (ascending, padded with num_blocks, never more
  than the slot capacity), deterministic given the state, and *exact* for
  policies whose next selection ignores the next step's norms;
* unit tests for the boundary decomposition (plan/prefetch/writeback/
  commit == the synchronous ``swap_banked``) and the ``StagingPool``;
* planner behavior: prediction hit == synchronous result bit for bit,
  misprediction falls back (and is counted), quiesce drains the in-flight
  job, disabled planner never dispatches.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.configs.base import ModelConfig, SelectConfig
from repro.core import adagradselect, masked_adamw, offload, swap
from repro.core import partition as pmod
from repro.models import registry

TINY = ModelConfig(name="swap-tiny", family="dense", num_layers=4,
                   d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
                   d_ff=32, vocab_size=17, dtype="float32", remat="none",
                   tie_embeddings=False)


# ------------------------------------------------- gather/scatter OOB


@settings(max_examples=20, deadline=None)
@given(length=st.integers(min_value=1, max_value=6),
       cap=st.integers(min_value=1, max_value=6),
       seed=st.integers(min_value=0, max_value=10_000))
def test_gather_rows_fills_oob_with_zeros(length, cap, seed):
    rng = np.random.RandomState(seed)
    leaf = jnp.asarray(rng.randn(length, 3).astype(np.float32))
    slots = jnp.asarray(rng.randint(0, length + 3, size=(cap,)), jnp.int32)
    rows = np.asarray(pmod.gather_rows(leaf, slots))
    for i, s in enumerate(np.asarray(slots)):
        if s < length:
            np.testing.assert_array_equal(rows[i], np.asarray(leaf)[s])
        else:  # sentinel (free slot / padded index) -> fill value
            np.testing.assert_array_equal(rows[i], 0.0)


@settings(max_examples=20, deadline=None)
@given(length=st.integers(min_value=1, max_value=6),
       cap=st.integers(min_value=1, max_value=6),
       seed=st.integers(min_value=0, max_value=10_000))
def test_scatter_rows_drops_oob(length, cap, seed):
    rng = np.random.RandomState(seed)
    leaf = rng.randn(length, 3).astype(np.float32)
    rows = rng.randn(cap, 3).astype(np.float32)
    slots = rng.randint(0, length + 3, size=(cap,)).astype(np.int32)
    out = np.asarray(pmod.scatter_rows(jnp.asarray(leaf),
                                       jnp.asarray(slots),
                                       jnp.asarray(rows)))
    touched = set()
    # later duplicate slots win under .at[].set; iterate in order
    expected = leaf.copy()
    for i, s in enumerate(slots):
        if s < length:
            expected[s] = rows[i]
            touched.add(int(s))
    np.testing.assert_array_equal(out, expected)
    for r in range(length):
        if r not in touched:
            np.testing.assert_array_equal(out[r], leaf[r])


# --------------------------------------------------- predict_next


def _rand_state(policy: str, nb: int, cap: int, seed: int, steps: int):
    """A reachable policy state: init + a few real select iterations."""
    cfg = SelectConfig(policy=policy, k_percent=40, steps_per_epoch=6,
                       epsilon_decay=0.1, lisa_interval=3)
    st_ = adagradselect.init_state(nb, seed=seed, policy=policy, k=cap)
    rng = np.random.RandomState(seed)
    for _ in range(steps):
        norms = jnp.asarray(rng.rand(nb).astype(np.float32))
        _, st_ = adagradselect.select(cfg, st_, norms, nb)
    return cfg, st_


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000),
       steps=st.integers(min_value=0, max_value=4))
def test_predict_next_is_subset_legal_and_deterministic(seed, steps):
    nb, cap = 7, 3
    for policy in adagradselect.available_policies():
        cfg, state = _rand_state(policy, nb, cap, seed, steps)
        pred = np.asarray(adagradselect.predict_next(cfg, state, nb))
        # static-shape [cap] i32, ascending, padded with nb, ids in range
        assert pred.shape == (cap,) and pred.dtype == np.int32, policy
        assert (np.diff(pred) >= 0).all(), policy
        assert (pred >= 0).all() and (pred <= nb).all(), policy
        real = pred[pred < nb]
        assert len(np.unique(real)) == len(real), policy  # no duplicate ids
        assert len(real) <= cap, policy  # never exceeds slot capacity
        # deterministic and pure: same state -> same prediction
        pred2 = np.asarray(adagradselect.predict_next(cfg, state, nb))
        np.testing.assert_array_equal(pred, pred2)


@pytest.mark.parametrize("policy", ("random", "lisa", "all"))
def test_predict_next_exact_for_norm_independent_policies(policy):
    """Policies whose next selection ignores the next step's gradient norms
    must be predicted exactly — the PRNG keys are deterministic in
    (key, step) and predict_next folds them as the next select will."""
    nb, cap = 7, 3
    cfg, state = _rand_state(policy, nb, cap, seed=5, steps=2)
    rng = np.random.RandomState(99)
    for _ in range(5):
        pred = np.asarray(adagradselect.predict_next(cfg, state, nb))
        norms = jnp.asarray(rng.rand(nb).astype(np.float32))
        _, state = adagradselect.select(cfg, state, norms, nb)
        np.testing.assert_array_equal(pred, np.asarray(state["indices"]))


# ----------------------------------------------- boundary decomposition


def _banked_fixture(cap=2, seed=0):
    part = pmod.build_partition(TINY)
    model = registry.get(TINY)
    params = model.init(jax.random.PRNGKey(seed), TINY)
    opt = masked_adamw.init_banked_opt_state(part, params, cap)
    return part, params, opt


def _mask(nb, ids):
    m = np.zeros((nb,), bool)
    m[list(ids)] = True
    return m


def test_plan_swap_disjoint_and_capacity():
    part, _, opt = _banked_fixture(cap=2)
    nb = part.num_blocks
    banks, slot_map, store = masked_adamw.swap_banked(
        part, opt["banks"], opt["store"], opt["slot_map"], _mask(nb, [1, 2]))
    plans = masked_adamw.plan_swap(part, slot_map, _mask(nb, [2, 3]),
                                   masked_adamw.bank_caps(banks))
    for p in plans:
        assert not set(p.ev_blocks) & set(p.ad_blocks)
        cap = masked_adamw.bank_caps(banks)[p.key]
        assert (p.ad_slots < cap).all() and (p.ev_slots < cap).all()
    # unchanged mask -> empty plan (the no-op fast path)
    assert masked_adamw.plan_swap(part, slot_map, _mask(nb, [1, 2]),
                                  masked_adamw.bank_caps(banks)) == []


def test_decomposed_boundary_equals_swap_banked():
    """plan -> prefetch -> writeback -> commit must equal the one-call
    ``swap_banked`` (same banks, slot_map, and store) — the async planner
    stages exactly what the synchronous path would."""
    part, params, opt = _banked_fixture(cap=2)
    nb = part.num_blocks
    banks, slot_map, store = masked_adamw.swap_banked(
        part, opt["banks"], opt["store"], opt["slot_map"], _mask(nb, [1, 2]))
    # write recognizable moments so eviction traffic is observable
    banks = jax.tree.map(
        lambda x: x + 1.0 if x.dtype == jnp.float32 and x.ndim > 1 else x,
        banks)

    import copy
    mask2 = _mask(nb, [2, 3])
    b_ref, sm_ref, st_ref = masked_adamw.swap_banked(
        part, banks, copy.deepcopy(store), slot_map, mask2)

    plans = masked_adamw.plan_swap(part, slot_map, mask2,
                                   masked_adamw.bank_caps(banks))
    staged = masked_adamw.prefetch_admissions(plans, store,
                                              swap.StagingPool())
    store2 = masked_adamw.writeback_evictions(plans, banks, store)
    b_new, sm_new, st_new = masked_adamw.commit_swap(plans, banks, store2,
                                                     slot_map, staged)
    np.testing.assert_array_equal(sm_new, sm_ref)
    for a, b in zip(jax.tree.leaves(b_ref), jax.tree.leaves(b_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(st_ref), jax.tree.leaves(st_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staging_pool_reuses_buffers():
    pool = swap.StagingPool()
    leaf = np.zeros((8, 4), np.float32)
    b1 = pool.take("g", "m", 0, 2, leaf)
    b2 = pool.take("g", "m", 0, 2, leaf)
    assert b1 is b2 and b1.shape == (2, 4)
    b3 = pool.take("g", "m", 0, 3, leaf)  # grow: new allocation
    assert b3.shape == (3, 4) and b3 is not b1
    assert pool.take("g", "m", 0, 2, leaf) is b3  # view served from grown
    assert pool.nbytes() == b3.nbytes
    # store_read_rows honors the pool buffer
    src = np.arange(32, dtype=np.float32).reshape(8, 4)
    out = offload.store_read_rows(src, np.array([1, 3]),
                                  out=pool.take("g", "m", 0, 2, src))
    np.testing.assert_array_equal(out, src[[1, 3]])


# ------------------------------------------------------- planner


def _sel_cfg(policy="random"):
    return SelectConfig(policy=policy, k_percent=40, steps_per_epoch=6,
                        epsilon_decay=0.1, lisa_interval=3)


def test_planner_hit_equals_sync_swap():
    """Dispatch with the state that generates the next selection, resolve
    with that exact selection: the committed banks/slot_map/store must be
    bit-identical to the synchronous swap, and the boundary must count as a
    predicted hit (no sync fallback)."""
    import copy
    part, params, opt = _banked_fixture(cap=3)
    nb = part.num_blocks
    cfg = _sel_cfg("random")
    sel = adagradselect.init_state(nb, seed=1, policy="random", k=3)
    _, sel = adagradselect.select(cfg, sel,
                                  jnp.zeros((nb,), jnp.float32), nb)
    idx0 = np.asarray(sel["indices"])
    banks, slot_map, store = masked_adamw.swap_banked(
        part, opt["banks"], opt["store"], opt["slot_map"],
        _mask(nb, idx0[idx0 < nb]))

    planner = swap.SwapPlanner(part, cfg, nb, enabled=True)
    planner.dispatch(sel, banks, store, slot_map)
    # the actual next selection (what the next phase A will compute)
    _, sel_next = adagradselect.select(cfg, sel,
                                       jnp.zeros((nb,), jnp.float32), nb)
    idx1 = np.asarray(sel_next["indices"])
    ref = masked_adamw.swap_banked(part, banks, copy.deepcopy(store),
                                  slot_map, _mask(nb, idx1[idx1 < nb]))
    got = planner.resolve(idx1, banks, store, slot_map)
    planner.close()
    np.testing.assert_array_equal(got[1], ref[1])
    for a, b in zip(jax.tree.leaves(ref[0]), jax.tree.leaves(got[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(ref[2]), jax.tree.leaves(got[2])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert planner.stats.predicted_hits >= 1
    assert planner.stats.sync_swaps == 0


def test_planner_mispredict_falls_back_and_counts():
    part, params, opt = _banked_fixture(cap=2)
    nb = part.num_blocks
    cfg = _sel_cfg("random")
    sel = adagradselect.init_state(nb, seed=1, policy="random", k=2)
    _, sel = adagradselect.select(cfg, sel,
                                  jnp.zeros((nb,), jnp.float32), nb)
    banks, slot_map, store = opt["banks"], opt["slot_map"], opt["store"]
    planner = swap.SwapPlanner(part, cfg, nb, enabled=True)
    planner.dispatch(sel, banks, store, slot_map)
    # resolve with a selection the policy would never predict here
    pred = np.asarray(adagradselect.predict_next(cfg, sel, nb))
    wrong = np.sort((pred + 1) % nb).astype(np.int32)
    # reference before resolve: the planner's commit donates bank leaves
    import copy
    ref = masked_adamw.swap_banked(part, banks, copy.deepcopy(store),
                                   slot_map, _mask(nb, wrong[wrong < nb]))
    got = planner.resolve(wrong, banks, store, slot_map)
    planner.close()
    assert planner.stats.sync_swaps == 1
    assert planner.stats.predicted_hits == 0
    # fallback result still matches the plain synchronous swap
    np.testing.assert_array_equal(got[1], ref[1])


def test_planner_disabled_never_dispatches():
    part, params, opt = _banked_fixture(cap=2)
    nb = part.num_blocks
    cfg = _sel_cfg("random")
    sel = adagradselect.init_state(nb, seed=0, policy="random", k=2)
    planner = swap.SwapPlanner(part, cfg, nb, enabled=False)
    planner.dispatch(sel, opt["banks"], opt["store"], opt["slot_map"])
    assert planner._pending is None and planner.stats.dispatches == 0
    idx = np.asarray(sel["indices"])
    planner.resolve(idx, opt["banks"], opt["store"], opt["slot_map"])
    assert planner.stats.sync_swaps == 1  # boundary still served, sync
    planner.close()


def test_planner_quiesce_drains_pending():
    part, params, opt = _banked_fixture(cap=2)
    nb = part.num_blocks
    cfg = _sel_cfg("random")
    sel = adagradselect.init_state(nb, seed=0, policy="random", k=2)
    planner = swap.SwapPlanner(part, cfg, nb, enabled=True)
    planner.dispatch(sel, opt["banks"], opt["store"], opt["slot_map"])
    assert planner._pending is not None
    planner.quiesce()
    assert planner._pending is None
    planner.close()
