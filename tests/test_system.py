"""End-to-end behaviour: the paper's qualitative claims on the synthetic
math task, selection dynamics, serving engine, offload accounting."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.configs.base import (ModelConfig, OptimizerConfig, SelectConfig,
                                TrainConfig)
from repro.core import build_partition
from repro.core.offload import optimizer_memory_report
from repro.data.synthetic import EOS
from repro.models import registry
from repro.train.trainer import Trainer

TINY = ModelConfig(name="tiny", family="dense", num_layers=4, d_model=64,
                   num_heads=4, num_kv_heads=2, head_dim=16, d_ff=256,
                   vocab_size=32, dtype="float32", remat="none",
                   tie_embeddings=True)


def _tcfg(steps=40, **kw):
    sel = kw.pop("select", SelectConfig(policy="adagradselect", k_percent=34,
                                        steps_per_epoch=20))
    return TrainConfig(model=kw.pop("model", TINY), select=sel,
                       optimizer=OptimizerConfig(lr=3e-3, schedule="constant",
                                                 warmup_steps=5, **kw),
                       seq_len=64, global_batch=16, steps=steps, log_every=0)


@pytest.mark.parametrize("method", ["adagradselect", "topk_grad", "all"])
def test_training_reduces_loss(method):
    tr = Trainer(_tcfg(40), method=method)
    log = tr.train()
    assert log.losses[-1] < log.losses[0] * 0.6, (method, log.losses[::10])


def test_selection_state_evolves_and_converges():
    tr = Trainer(_tcfg(60), method="adagradselect")
    tr.train()
    freq = np.asarray(tr.state["sel"]["freq"])
    part = build_partition(TINY)
    assert freq.sum() == 60 * tr.sel_cfg.num_selected(part.num_blocks)
    assert (np.asarray(tr.state["sel"]["cum_norms"]) > 0).all()


def test_microbatch_accumulation_matches_full_batch():
    """grad accumulation must give (near-)identical training trajectories."""
    t1 = Trainer(_tcfg(8, microbatch=0), method="all")
    t2 = Trainer(_tcfg(8, microbatch=4), method="all")
    t1.train()
    t2.train()
    for a, b in zip(jax.tree.leaves(t1.state["params"]),
                    jax.tree.leaves(t2.state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4)


def test_generate_respects_eos_and_shapes():
    from repro.serve.engine import generate
    cfg = TINY
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    prompts = {"tokens": np.full((3, 8), 5, np.int32)}
    out = generate(params, cfg, prompts, max_new_tokens=12, eos_id=EOS)
    assert out.shape == (3, 12)
    out_t = generate(params, cfg, prompts, max_new_tokens=4, temperature=0.7,
                     rng=jax.random.PRNGKey(1))
    assert out_t.shape == (3, 4)


def test_offload_memory_model_matches_paper_formula():
    """Mem_selective = 2 * P_selected * B (paper 3.3)."""
    cfg = get_smoke_config("llama3.2-1b")
    part = build_partition(cfg)
    model = registry.get(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    rep = optimizer_memory_report(part, params, k_percent=40,
                                  bytes_per_param=4)
    total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    assert rep.mem_full == 2 * total * 4
    assert rep.mem_selective <= rep.mem_full
    assert 0 <= rep.pct_reduction <= 100
    rep_all = optimizer_memory_report(part, params, k_percent=100)
    assert rep_all.pct_reduction == 0


def test_straggler_watchdog_hook():
    events = []
    tcfg = _tcfg(10)
    tr = Trainer(tcfg, method="all",
                 on_straggler=lambda s, dt, ew: events.append((s, dt, ew)))
    tr._ewma = 1e-9  # force every step to look like a straggler
    tr.train(steps=6)
    assert len(events) >= 1


def test_gate_weight_grads_training_runs():
    """Compute-gated variant (DESIGN 3.3) trains and loss decreases."""
    cfg = TINY.replace(gate_weight_grads=True, remat="none")
    tr = Trainer(_tcfg(30, model=cfg), method="adagradselect")
    log = tr.train()
    assert log.losses[-1] < log.losses[0] * 0.8
